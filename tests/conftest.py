"""Shared fixtures. jax is initialised here with the default (1) device count —
the 512-device dry-run flag is set only inside subprocesses (see test_dryrun.py),
never globally."""
import sys

import jax
import numpy as np
import pytest

jax.devices()  # lock the backend to 1 CPU device before anything else

try:
    import hypothesis  # noqa: F401  (real package preferred when installed)
except ImportError:   # offline container: vendored deterministic fallback
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from _hypothesis_fallback import install_as_hypothesis

    install_as_hypothesis(sys.modules)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_batch(cfg, rng, B=2, S=16):
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_feats"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_frontend)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_tokens, cfg.d_frontend)), jnp.float32)
    return batch
