"""Expert-level co-activation linking (MoE RIPPLE)."""
import numpy as np
import pytest

from repro.core.expert_placement import (expected_reads_per_token,
                                         expert_coactivation,
                                         hierarchical_moe_placement,
                                         routing_masks, search_expert_placement,
                                         synthetic_routing)
from repro.core.placement import identity_placement


def test_routing_masks_shape_and_counts():
    sel = np.array([[0, 2], [1, 3], [0, 1]])
    m = routing_masks(sel, 4)
    assert m.shape == (3, 4)
    assert m.sum() == 6
    assert m[0, 0] and m[0, 2] and not m[0, 1]


def test_expert_placement_reduces_reads():
    sel = synthetic_routing(n_tokens=800, n_experts=32, top_k=8, n_groups=4, seed=0)
    pl = search_expert_placement(sel, 32)
    ident = identity_placement(32)
    serve = synthetic_routing(n_tokens=300, n_experts=32, top_k=8, n_groups=4, seed=7)
    r_ident = expected_reads_per_token(serve, 32, ident)
    r_ripple = expected_reads_per_token(serve, 32, pl)
    # floor analysis: ~6.8/8 same-group picks leave intra-group gaps plus ~1.2
    # stray experts -> ~4.5 reads/token vs ~6.7 scattered; assert the gain
    assert r_ripple < 0.8 * r_ident, (r_ident, r_ripple)
    # the placement must recover the planted groups: adjacent experts in the
    # layout should predominantly share a group (e % 4)
    groups = pl.placement % 4
    same_adj = np.mean(groups[:-1] == groups[1:])
    assert same_adj > 0.7, same_adj


def test_expert_coactivation_symmetric():
    sel = synthetic_routing(200, 16, 2, seed=1)
    stats = expert_coactivation(sel, 16)
    np.testing.assert_array_equal(stats.pair_counts, stats.pair_counts.T)
    assert stats.counts.sum() == 200 * 2


def test_hierarchical_placement_shapes():
    rng = np.random.default_rng(2)
    E, dff = 8, 64
    sel = synthetic_routing(300, E, 2, seed=2)
    neuron_masks = [rng.random((50, dff)) < 0.2 for _ in range(E)]
    expert_pl, neuron_pls = hierarchical_moe_placement(sel, neuron_masks, E)
    assert sorted(expert_pl.placement.tolist()) == list(range(E))
    assert len(neuron_pls) == E
    for pl in neuron_pls:
        assert sorted(pl.placement.tolist()) == list(range(dff))


def test_hierarchical_placement_handles_missing_masks():
    sel = synthetic_routing(100, 4, 2, seed=3)
    expert_pl, neuron_pls = hierarchical_moe_placement(sel, None, 4)
    assert all(p is None for p in neuron_pls)


def test_synthetic_routing_topk_distinct():
    sel = synthetic_routing(100, 16, 4, seed=4)
    for row in sel:
        assert len(set(row.tolist())) == 4
