"""Decode path exactness: prefill + step-by-step decode must reproduce the
teacher-forced forward logits for every architecture family (MoE with a
dropless capacity factor, since capacity dropping is batch-dependent)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

from conftest import tiny_batch

CASES = ["internlm2-20b", "qwen2-7b", "jamba-1.5-large-398b", "xlstm-125m",
         "seamless-m4t-medium", "granite-moe-1b-a400m", "internvl2-26b"]


def _dropless(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k + 0.1))


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch, rng):
    cfg = _dropless(get_config(arch, reduced=True))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    B, S, P = 2, 20, 16
    batch = tiny_batch(cfg, rng, B=B, S=S)
    full = model.forward(params, batch)["logits"]
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :P]
    # VLM prepends n_prefix_tokens image tokens: the cache must cover them too
    cache = model.init_cache(B, S + cfg.n_prefix_tokens + 8)
    lg, cache = model.prefill(params, pb, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - full[:, P - 1])))]
    # decode positions are GLOBAL: VLM text token i sits at n_prefix + i
    off = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    for i in range(P, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      jnp.int32(off + i), cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    scale = max(float(jnp.max(jnp.abs(full))), 1.0)
    assert max(errs) < 2e-3 * scale, (arch, errs)


def test_swa_decode_matches_windowed_forward(rng):
    """SWA ring-buffer decode == teacher-forced forward with the same window."""
    cfg = get_config("qwen2-7b", reduced=True, sliding_window=8)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    B, S, P, W = 2, 24, 12, 8
    batch = tiny_batch(cfg, rng, B=B, S=S)
    full = model.forward(params, batch, window=W)["logits"]
    cache = model.init_cache(B, S, swa=True)
    pb = {"tokens": batch["tokens"][:, :P]}
    lg, cache = model.prefill(params, pb, cache, window=W)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - full[:, P - 1])))]
    for i in range(P, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      jnp.int32(i), cache, window=W)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    scale = max(float(jnp.max(jnp.abs(full))), 1.0)
    assert max(errs) < 2e-3 * scale, errs
