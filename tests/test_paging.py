"""Paged KV cache: PagePool allocator invariants, copy-on-write prefix
sharing, paged-attention kernel equivalence, and server-level token identity.

The contract under test (ISSUE 9 acceptance): decoded tokens through the
paged path are BYTE-FOR-BYTE identical to the contiguous per-slot layout —
including requests sharing a prompt prefix that diverges after forking
(copy-on-write) and the int8 quantised cache; the allocator conserves pages
across any admit/fork/retire interleaving (no double allocation, refcounts
balance, the free list refills after release + registry clear); preemption
and CoW counters are exactly zero when the pool is unconstrained and prompts
are unique; and page pressure under `page_overcommit` preempts rather than
corrupts or deadlocks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels.ops import paged_decode_attention
from repro.models import build_model
from repro.models.kvcache import KVCache, QuantKVCache, attend_full_cache
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.paging import PagePool, cdiv
from repro.serving.server import InferenceServer


def _setup(seed=0, vocab=128, arch="opt-350m", **overrides):
    cfg = get_config(arch, reduced=True, d_model=64, d_ff=256, n_layers=2,
                     vocab_size=vocab, **overrides)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return cfg, model, params


def _tiny_cfg(**overrides):
    """Smallest geometry that still builds real arenas (allocator tests)."""
    return get_config("opt-350m", reduced=True, d_model=16, d_ff=32,
                      n_layers=1, vocab_size=32, **overrides)


def _serve(model, params, reqs, max_slots=4, max_len=48, **kw):
    server = InferenceServer(model, params, max_slots=max_slots,
                             max_len=max_len, **kw)
    try:
        for r in reqs:
            server.submit(r)
        results = {res.uid: res for res in server.drain()}
    finally:
        server.close()
    return results, server


def _clone(reqs):
    return [dataclasses.replace(r, prompt=list(r.prompt)) for r in reqs]


# -- allocator unit tests ------------------------------------------------------

def test_pool_admit_release_roundtrip():
    pool = PagePool(_tiny_cfg(), num_pages=8, page_size=4, max_len=32)
    t, plan = pool.admit(np.arange(6, dtype=np.int32), 4, uid=0)
    assert t is not None and len(t.pages) == 2 and plan.new_now == 2
    assert pool.n_free == 6 and pool.stats.pages_allocated == 2
    assert pool.prepare_append(t, 6)      # offset 2 of page 1: no growth
    assert len(t.pages) == 2
    assert pool.prepare_append(t, 8)      # page boundary: one new page
    assert len(t.pages) == 3
    pool.check()
    pool.release(t)
    pool.release(t)                       # idempotent
    pool.check()
    assert pool.n_free == 8
    assert pool.stats.pages_allocated == pool.stats.pages_freed == 3


def test_registry_shares_full_pages_only_and_evicts_fifo():
    pool = PagePool(_tiny_cfg(), num_pages=6, page_size=4, max_len=32)
    prompt = np.arange(10, dtype=np.int32)          # 2 full pages + 2 tokens
    a, _ = pool.admit(prompt, 4, uid=0)
    pool.register_prefixes(prompt, a)
    pool.release(a)
    # registry pins the two ALIGNED prefixes' pages (4- and 8-token); the
    # partial third page went back to the free list at release
    assert pool.n_free == 4 and pool.n_evictable() == 2
    b, plan = pool.admit(prompt, 4, uid=1)          # registry hit at 8 tokens
    assert plan.shared_len == 8 and plan.n_shared == 2 and plan.new_now == 1
    assert b.pages[:2] == list(a.pages[:2]) if a.pages else True
    assert pool.stats.prefix_hits == 1 and pool.stats.cow_copies == 0
    pool.release(b)
    # pressure: allocating everything forces FIFO registry eviction
    c, _ = pool.admit(np.arange(100, 124, dtype=np.int32), 1, uid=2)
    assert c is not None and len(c.pages) == 6
    assert pool.stats.prefix_evictions == 2 and pool.n_evictable() == 0
    pool.release(c)
    pool.check()
    assert pool.n_free == 6


def test_live_fork_cow_on_partial_page():
    pool = PagePool(_tiny_cfg(), num_pages=8, page_size=4, max_len=32)
    prompt = np.arange(6, dtype=np.int32)           # page 1 is partial
    a, _ = pool.admit(prompt, 4, uid=0)
    b, plan = pool.admit(prompt.copy(), 4, uid=1)   # live fork: shares BOTH
    assert plan.shared_len == 6 and b.pages == a.pages
    assert pool.stats.pages_shared == 2
    # first writer into the shared partial page pays the copy
    assert pool.prepare_append(b, 6)
    assert b.pages[0] == a.pages[0] and b.pages[1] != a.pages[1]
    assert pool.stats.cow_copies == 1
    # the original page is A's alone now: A appends in place, no second copy
    assert pool.prepare_append(a, 6)
    assert pool.stats.cow_copies == 1
    pool.release(a)
    pool.release(b)
    pool.check()
    assert pool.n_free == 8


def test_commitment_gate_strict_vs_overcommit():
    cfg = _tiny_cfg()
    prompt = np.arange(4, dtype=np.int32)
    strict = PagePool(cfg, num_pages=4, page_size=4, max_len=32)
    a, _ = strict.admit(prompt, 12, uid=0)          # budget 4: whole pool
    plan = strict.plan_admit(np.arange(50, 54, dtype=np.int32), 12)
    assert not strict.can_admit(plan)               # nothing left to promise
    over = PagePool(cfg, num_pages=4, page_size=4, max_len=32,
                    overcommit=True)
    over.admit(prompt, 12, uid=0)
    plan = over.plan_admit(np.arange(50, 54, dtype=np.int32), 12)
    assert over.can_admit(plan)                     # immediate need only


def test_gate_prices_pinned_shared_pages():
    """Registry-only pages a candidate would share stop being evictable the
    moment it pins them, so the gate must not count them as available: with
    the whole free list consumed by the shares' sibling, an extending prompt
    that shares both registry pages cannot cover its one new page."""
    pool = PagePool(_tiny_cfg(), num_pages=2, page_size=4, max_len=32,
                    overcommit=True)
    a = np.arange(8, dtype=np.int32)                # exactly 2 full pages
    t, _ = pool.admit(a, 1, uid=0)
    pool.register_prefixes(a, t)
    pool.release(t)
    assert pool.n_free == 0 and pool.n_evictable() == 2
    ext = np.concatenate([a, np.arange(100, 104)]).astype(np.int32)
    plan = pool.plan_admit(ext, 1)                  # shares 2, needs 1 more
    assert plan.n_shared == 2 and plan.new_now == 1
    assert plan.n_shared_evictable == 2
    assert not pool.can_admit(plan)                 # 0 free after the pin
    # once the registry is dropped, a prompt that fits the pool outright
    # (2 pages, nothing pinned) admits again
    pool.clear_prefix_cache()
    assert pool.can_admit(pool.plan_admit(a, 1))


def test_strict_reservations_survive_pinned_shares():
    """Strict mode: admitting a prefix-sharing candidate must not invalidate
    an active request's worst-case reservation by pinning the evictable pages
    that reservation counted on (admitted requests provably finish)."""
    pool = PagePool(_tiny_cfg(), num_pages=4, page_size=4, max_len=32)
    x = np.arange(8, dtype=np.int32)
    t, _ = pool.admit(x, 1, uid=0)
    pool.register_prefixes(x, t)
    pool.release(t)                                 # registry pins 2 pages
    a, _ = pool.admit(np.arange(50, 54, dtype=np.int32), 8, uid=1)
    assert a is not None                            # worst case 3: covered
    plan_b = pool.plan_admit(x, 4)                  # shares both registry pages
    assert plan_b.n_shared_evictable == 2
    # pre-fix gate said yes (1 <= free 1 + evictable 2 - committed 2); the
    # pin would have starved A's reserved growth mid-decode
    assert not pool.can_admit(plan_b)
    pool.release(a)
    assert pool.can_admit(pool.plan_admit(x, 4))    # A gone: B fits
    pool.check()


def test_dry_alloc_skips_pinned_registry_entries():
    """A dry allocation must not drain registry entries whose pages are
    pinned by live tables — evicting them frees nothing and only destroys
    future sharing."""
    pool = PagePool(_tiny_cfg(), num_pages=2, page_size=4, max_len=32,
                    overcommit=True)
    a = np.arange(8, dtype=np.int32)
    t, _ = pool.admit(a, 1, uid=0)
    pool.register_prefixes(a, t)                    # t AND registry hold both
    assert not pool.prepare_append(t, 8)            # dry: no page to evict
    assert pool.stats.prefix_evictions == 0
    assert pool.summary()["registry_entries"] == 2  # registry intact
    pool.release(t)
    pool.check()


def test_failed_admit_leaves_parent_budget_intact():
    """A rolled-back live fork must not leave the parent's budget inflated
    (the +1 CoW charge applies only to admits that complete)."""
    pool = PagePool(_tiny_cfg(), num_pages=2, page_size=4, max_len=32,
                    overcommit=True)
    prompt = np.arange(6, dtype=np.int32)           # 2 pages, second partial
    a, _ = pool.admit(prompt, 1, uid=0)
    budget0 = a.budget
    ext = np.concatenate([prompt, [30, 31]]).astype(np.int32)
    b, _ = pool.admit(ext, 1, uid=1)                # CoW of the partial page
    assert b is None                                # pool dry: rolled back
    assert a.budget == budget0
    pool.check()
    pool.release(a)
    assert pool.n_free == pool.num_pages


def test_live_prompt_repoints_to_surviving_duplicate():
    """When the table holding the live-prompt entry retires, a still-live
    duplicate of the same prompt takes over as the fork source."""
    pool = PagePool(_tiny_cfg(), num_pages=8, page_size=4, max_len=32)
    prompt = np.arange(6, dtype=np.int32)
    a, _ = pool.admit(prompt, 4, uid=0)
    b, _ = pool.admit(prompt.copy(), 4, uid=1)      # duplicate forks a
    pool.release(a)
    ext = np.concatenate([prompt, [30]]).astype(np.int32)
    c, plan = pool.admit(ext, 4, uid=2)
    assert plan.shared_len == 6 and plan.parent is b
    assert c.pages[0] == b.pages[0]
    for t in (b, c):
        pool.release(t)
    pool.clear_prefix_cache()
    pool.check()
    assert pool.n_free == pool.num_pages


def test_pool_rejects_ssm_stacks():
    cfg = get_config("jamba-1.5-large-398b", reduced=True)
    with pytest.raises(ValueError):
        PagePool(cfg, num_pages=8, page_size=4, max_len=32)


# -- allocator property test ---------------------------------------------------

@given(seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_pool_invariants_under_random_interleaving(seed):
    """Random admit/fork/append/retire sequences: after EVERY operation the
    allocator conserves pages (live + free == num_pages, free list
    duplicate-free, registry refs <= total refs); after releasing everything
    and clearing the registry the free list is full again and allocations
    balance frees exactly."""
    rng = np.random.default_rng(seed)
    P, NP = 4, 12
    pool = PagePool(_tiny_cfg(), num_pages=NP, page_size=P, max_len=32,
                    overcommit=True)
    live = []
    prompts = []
    uid = 0
    for _ in range(40):
        op = rng.integers(0, 3)
        if op == 0:                                  # admit (maybe a fork)
            if prompts and rng.random() < 0.4:
                base = prompts[rng.integers(len(prompts))]
                extra = rng.integers(0, 3)
                prompt = np.concatenate(
                    [base, rng.integers(0, 32, extra)]).astype(np.int32)
            else:
                prompt = rng.integers(
                    0, 32, rng.integers(1, 12)).astype(np.int32)
            max_new = int(rng.integers(1, 8))
            if cdiv(len(prompt) + max_new, P) > NP:
                continue
            t, _ = pool.admit(prompt, max_new, uid=uid)
            uid += 1
            if t is not None:
                pool.register_prefixes(prompt, t)
                live.append(t)
                prompts.append(prompt)
        elif op == 1 and live:                       # grow one table
            t = live[rng.integers(len(live))]
            pool.prepare_append(t, t.length)         # may fail dry: fine
        elif op == 2 and live:                       # retire one table
            t = live.pop(rng.integers(len(live)))
            pool.release(t)
        pool.check()
    for t in live:
        pool.release(t)
        pool.check()
    pool.clear_prefix_cache()
    pool.check()
    assert pool.n_free == NP
    assert pool.stats.pages_allocated == pool.stats.pages_freed
    assert pool.n_evictable() == 0


# -- kernel equivalence --------------------------------------------------------

def _page_arena(rng, B, S, KV, hd, P, quant):
    """A contiguous [B, S, KV, hd] cache and its page-arena twin (row b maps
    pages b*S/P .. ), plus the page tables."""
    MP = S // P
    NP = B * MP
    k_all = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v_all = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    pt = (np.arange(B * MP, dtype=np.int32).reshape(B, MP))
    reshape = lambda a: jnp.concatenate(
        [a.reshape((NP, P) + a.shape[2:]),
         jnp.zeros((1, P) + a.shape[2:], a.dtype)])
    if not quant:
        return (KVCache(k=k_all, v=v_all),
                (reshape(k_all), reshape(v_all), None, None),
                jnp.asarray(pt))
    sc_k = jnp.maximum(jnp.abs(k_all).max(-1), 1e-6) / 127.0
    sc_v = jnp.maximum(jnp.abs(v_all).max(-1), 1e-6) / 127.0
    ki = jnp.clip(jnp.round(k_all / sc_k[..., None]), -127, 127).astype(jnp.int8)
    vi = jnp.clip(jnp.round(v_all / sc_v[..., None]), -127, 127).astype(jnp.int8)
    return (QuantKVCache(k=ki, v=vi, k_scale=sc_k, v_scale=sc_v),
            (reshape(ki), reshape(vi), reshape(sc_k), reshape(sc_v)),
            jnp.asarray(pt))


@pytest.mark.parametrize("quant", [False, True])
def test_paged_decode_attention_matches_contiguous(quant):
    """The XLA gather twin is bitwise identical to `attend_full_cache`; the
    Pallas kernel body (interpret-mode oracle) matches to fp32 online-softmax
    tolerance. Rows at different positions exercise the causal mask over
    partially-filled and null pages."""
    rng = np.random.default_rng(3)
    B, KV, G, hd, P, S = 3, 2, 2, 16, 8, 32
    H = KV * G
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    cur = jnp.asarray([5, 17, 31], jnp.int32)
    cont, (ka, va, ksa, vsa), pt = _page_arena(rng, B, S, KV, hd, P, quant)
    ref = np.asarray(attend_full_cache(q, cont, cur[:, None]))
    ref = ref.reshape(B, H, hd)
    out_xla = np.asarray(paged_decode_attention(
        q[:, 0], ka, va, pt, cur, k_scale=ksa, v_scale=vsa))
    assert np.array_equal(out_xla, ref)
    out_pallas = np.asarray(paged_decode_attention(
        q[:, 0], ka, va, pt, cur, k_scale=ksa, v_scale=vsa, interpret=True))
    np.testing.assert_allclose(out_pallas, ref, atol=2e-6, rtol=2e-6)


def test_paged_decode_attention_scale_pairing():
    rng = np.random.default_rng(0)
    _, (ka, va, ksa, vsa), pt = _page_arena(rng, 1, 8, 2, 16, 8, True)
    q = jnp.zeros((1, 4, 16), jnp.float32)
    with pytest.raises(ValueError):
        paged_decode_attention(q, ka, va, pt, jnp.zeros(1, jnp.int32),
                               k_scale=ksa, v_scale=None)


def test_server_decode_routes_through_paged_kernel(monkeypatch):
    """The serving decode path must attend through the paged-attention
    kernel dispatcher (`ops.paged_decode_attention` — XLA gather twin on
    CPU, the Pallas kernel elsewhere), not a full-arena XLA gather of its
    own: one call per attention sublayer per decode trace."""
    rng = np.random.default_rng(17)  # local: keep the session stream intact
    from repro.kernels import ops as kops
    calls = []
    real = kops.paged_decode_attention

    def spy(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(kops, "paged_decode_attention", spy)
    cfg, model, params = _setup()
    reqs = [Request(uid=0, prompt=rng.integers(1, 127, 6).tolist(),
                    max_new_tokens=3)]
    results, _ = _serve(model, params, reqs, max_slots=2,
                        page_size=4, num_pages=16)
    assert results[0].finish_reason == "length"
    # the jitted decode step traces once; the scanned attention sublayer
    # routes through the dispatcher during that trace
    assert calls, "paged decode did not route through the kernel dispatcher"


# -- server-level token identity -----------------------------------------------

def _identity_requests(rng, vocab=128):
    """Mixed lengths + two shared-prefix pairs: one exact duplicate (live
    fork, CoW divergence through temperature sampling), one extension of
    another prompt (registry/fork hit at admission)."""
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, vocab - 1,
                                        int(rng.integers(4, 14))).tolist(),
                    max_new_tokens=int(rng.integers(3, 8)), temperature=0.8)
            for i in range(6)]
    reqs.append(Request(uid=6, prompt=list(reqs[0].prompt),
                        max_new_tokens=5, temperature=0.8))
    reqs.append(Request(uid=7, prompt=list(reqs[1].prompt) + [9, 9, 9],
                        max_new_tokens=4, temperature=0.8))
    return reqs


def test_paged_server_tokens_identical_resident(rng):
    """Paged vs contiguous resident serving: byte-for-byte identical tokens
    for every request, shared-prefix forks included; prefix sharing engaged;
    everything reclaimed at drain."""
    cfg, model, params = _setup()
    reqs = _identity_requests(rng)
    base, _ = _serve(model, params, _clone(reqs), max_slots=3)
    paged, server = _serve(model, params, _clone(reqs), max_slots=3,
                           page_size=4, num_pages=36, seed=0)
    for uid, res in base.items():
        assert paged[uid].tokens == res.tokens, uid
        assert paged[uid].finish_reason == res.finish_reason
    assert server.stats.prefix_hits >= 1
    assert server.stats.preemptions == 0
    pool = server._pool
    assert pool.n_live == pool.n_evictable()   # only the registry holds pages
    pool.clear_prefix_cache()
    pool.check()
    assert pool.n_free == pool.num_pages


def test_paged_server_tokens_identical_offload(rng):
    """The same identity through the offload (layerwise, groups-layout) path
    under the ReLU oracle."""
    cfg, model, params = _setup(seed=1)
    reqs = _identity_requests(rng)[:5]
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(1))
    base, _ = _serve(model, params, _clone(reqs), max_slots=2,
                     mode="offload", offload=rt)
    rt2 = build_offload_runtime(model, params, rng=np.random.default_rng(1))
    paged, server = _serve(model, params, _clone(reqs), max_slots=2,
                           mode="offload", offload=rt2,
                           page_size=4, num_pages=36)
    for uid, res in base.items():
        assert paged[uid].tokens == res.tokens, uid
    assert server.stats.preemptions == 0


def test_paged_server_quant_cache_identity(rng):
    """int8 `QuantKVCache` through the paged arena (per-page scales) matches
    the contiguous quant path bitwise — no silent float fallback."""
    cfg, model, params = _setup(seed=2, kv_quant=True)
    assert cfg.kv_quant
    reqs = _identity_requests(rng)
    base, _ = _serve(model, params, _clone(reqs), max_slots=3)
    paged, server = _serve(model, params, _clone(reqs), max_slots=3,
                           page_size=4, num_pages=36)
    for uid, res in base.items():
        assert paged[uid].tokens == res.tokens, uid
    assert server._pool.quant          # arena really is the int8 layout


def test_live_fork_divergence_identity(rng):
    """An exact-duplicate prompt submitted while its twin is mid-decode forks
    the live pages (partial page included) and diverges through CoW; both
    requests still match their solo references exactly."""
    cfg, model, params = _setup()
    prompt = rng.integers(1, 127, 10).tolist()
    r0 = Request(uid=0, prompt=list(prompt), max_new_tokens=8, temperature=0.7)
    r1 = Request(uid=1, prompt=list(prompt), max_new_tokens=8, temperature=0.7)

    solo = {}
    for r in (r0, r1):
        res, _ = _serve(model, params,
                        [dataclasses.replace(r, prompt=list(prompt))],
                        max_slots=1)
        solo[r.uid] = res[r.uid].tokens

    server = InferenceServer(model, params, max_slots=2, max_len=48,
                             page_size=4, num_pages=24)
    try:
        server.submit(r0)
        server.step()                   # r0 admitted and decoding
        server.submit(r1)               # forks r0's live pages mid-flight
        results = {r.uid: r for r in server.drain()}
    finally:
        server.close()
    assert results[0].tokens == solo[0]
    assert results[1].tokens == solo[1]
    assert server.stats.prefix_hits >= 1
    assert server.stats.pages_shared >= 2      # incl. the partial page
    assert server.stats.cow_copies >= 1        # the divergence paid one copy


def test_clean_path_counters_exactly_zero(rng):
    """Unique prompts on an unconstrained pool: zero CoW copies, zero
    preemptions, zero page deferrals — sharing machinery must not fire."""
    cfg, model, params = _setup()
    reqs = [Request(uid=i, prompt=rng.integers(1, 127, 6 + i).tolist(),
                    max_new_tokens=4) for i in range(4)]
    _, server = _serve(model, params, reqs, max_slots=4,
                       page_size=4, num_pages=48)
    assert server.stats.cow_copies == 0
    assert server.stats.preemptions == 0
    assert server.stats.page_deferrals == 0


# -- pressure: deferral, preemption, reclamation --------------------------------

def test_strict_gate_defers_and_never_preempts(rng):
    """Strict admission on a pool that cannot hold everyone at once: requests
    wait (page_deferrals) but every admitted request runs to completion."""
    cfg, model, params = _setup()
    reqs = [Request(uid=i, prompt=rng.integers(1, 127, 8).tolist(),
                    max_new_tokens=16) for i in range(4)]
    results, server = _serve(model, params, reqs, max_slots=4,
                             page_size=4, num_pages=10)
    assert all(r.finish_reason == "length" for r in results.values())
    assert server.stats.preemptions == 0
    assert server.stats.page_deferrals >= 1


def test_overcommit_preempts_lowest_priority(rng):
    """Overcommitted pool under decode growth: the lowest-priority request is
    preempted (partial tokens preserved), the high-priority one finishes, and
    every page is reclaimed."""
    cfg, model, params = _setup()
    reqs = [Request(uid=i, prompt=rng.integers(1, 127, 8).tolist(),
                    max_new_tokens=16, priority=1 if i == 0 else 0)
            for i in range(4)]
    results, server = _serve(model, params, reqs, max_slots=4,
                             page_size=4, num_pages=10, page_overcommit=True)
    assert results[0].finish_reason == "length"
    preempted = [r for r in results.values() if r.finish_reason == "preempted"]
    assert preempted and server.stats.preemptions == len(preempted)
    assert all(len(r.tokens) >= 1 for r in preempted)
    pool = server._pool
    pool.clear_prefix_cache()
    pool.check()
    assert pool.n_free == pool.num_pages


def test_abort_releases_pages(rng):
    cfg, model, params = _setup()
    server = InferenceServer(model, params, max_slots=2, max_len=48,
                             page_size=4, num_pages=24)
    try:
        for i in range(3):
            server.submit(Request(uid=i,
                                  prompt=rng.integers(1, 127, 9).tolist(),
                                  max_new_tokens=8))
        server.step()
        assert server._pool.n_live > server._pool.n_evictable()
        n = server.abort()
        assert n == 3
    finally:
        server.close()
    pool = server._pool
    assert pool.n_live == pool.n_evictable()   # only registry refs remain
    pool.clear_prefix_cache()
    pool.check()
    assert pool.n_free == pool.num_pages


# -- constructor / submit validation -------------------------------------------

def test_paged_constructor_validation():
    cfg, model, params = _setup()
    with pytest.raises(ValueError, match="both page_size and num_pages"):
        InferenceServer(model, params, max_len=32, page_size=4)
    with pytest.raises(ValueError, match="swa"):
        InferenceServer(model, params, max_len=32, swa=True,
                        page_size=4, num_pages=8)


def test_paged_submit_rejects_oversized_request():
    cfg, model, params = _setup()
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             page_size=4, num_pages=8)   # 32 KV positions
    with pytest.raises(ValueError, match="pages"):
        server.submit(Request(uid=0, prompt=list(range(1, 30)),
                              max_new_tokens=10))
