"""Deliverable (f) contract: input_specs stand-ins for all 40 (arch x shape)
pairs have the assigned shapes, dtypes, and decode/SWA routing — with zero
device allocation (ShapeDtypeStructs only)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_CONFIGS, INPUT_SHAPES, get_config
from repro.launch import specs as specs_lib
from repro.models import build_model


@pytest.mark.parametrize("arch", sorted(ASSIGNED_CONFIGS))
@pytest.mark.parametrize("shape_name", sorted(INPUT_SHAPES))
def test_batch_specs_cover_assigned_shapes(arch, shape_name):
    cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16")
    shape = INPUT_SHAPES[shape_name]
    specs = specs_lib.batch_specs(cfg, shape)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
    toks = specs["tokens"]
    assert toks.dtype == jnp.int32
    assert toks.shape[0] == shape.global_batch
    if cfg.family == "vlm":
        # prefix embeddings + text tokens together span the assigned seq_len
        assert toks.shape[1] + cfg.n_prefix_tokens == shape.seq_len
        pf = specs["patch_feats"]
        assert pf.shape == (shape.global_batch, cfg.n_prefix_tokens, cfg.d_frontend)
    elif cfg.family == "audio":
        assert toks.shape[1] == shape.seq_len
        fr = specs["frames"]
        assert fr.shape == (shape.global_batch, cfg.n_prefix_tokens, cfg.d_frontend)
    else:
        assert toks.shape[1] == shape.seq_len


@pytest.mark.parametrize("arch", sorted(ASSIGNED_CONFIGS))
def test_swa_routing_long_500k(arch):
    """long_500k: SWA ring for attention-dominated families; native for
    SSM/hybrid (sub-quadratic by construction) — per the assignment."""
    cfg = get_config(arch)
    swa = specs_lib.uses_swa_for(cfg, INPUT_SHAPES["long_500k"])
    if cfg.family in ("dense", "vlm", "audio"):
        assert swa
    else:
        assert not swa
    assert not specs_lib.uses_swa_for(cfg, INPUT_SHAPES["decode_32k"])


@pytest.mark.parametrize("arch", ["internlm2-20b", "jamba-1.5-large-398b",
                                  "xlstm-125m", "seamless-m4t-medium"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_struct_is_abstract_and_bounded(arch, shape_name):
    cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16")
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg)
    cache = specs_lib.cache_struct(cfg, shape, model)
    leaves = jax.tree_util.tree_leaves(cache)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(int(jnp.prod(jnp.array(l.shape))) * l.dtype.itemsize for l in leaves)
    if specs_lib.uses_swa_for(cfg, shape):
        # SWA ring: cache bounded by window, not seq_len
        window_cache_elems = shape.global_batch * cfg.sliding_window
        assert total < 64 * cfg.n_layers * window_cache_elems * cfg.n_kv_heads * cfg.head_dim
    # decode token specs
    toks = specs_lib.decode_token_specs(shape)
    assert toks["tokens"].shape == (shape.global_batch, 1)
    assert toks["position"].shape == ()
