"""Family-specific behaviour: encoder-decoder (audio) and VLM prefix handling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model

from conftest import tiny_batch


def test_encoder_is_bidirectional(rng):
    """Perturbing a LATE frame changes EARLY decoder outputs (via cross-attn)."""
    cfg = get_config("seamless-m4t-medium", reduced=True)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, rng, B=1, S=8)
    out1 = m.forward(p, batch)["logits"]
    b2 = dict(batch)
    b2["frames"] = batch["frames"].at[:, -1].set(5.0)
    out2 = m.forward(p, b2)["logits"]
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]), atol=1e-5)


def test_decoder_is_causal_over_tokens(rng):
    cfg = get_config("seamless-m4t-medium", reduced=True)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg, rng, B=1, S=10)
    out1 = m.forward(p, batch)["logits"]
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"].at[:, -1].set(0)
    out2 = m.forward(p, b2)["logits"]
    np.testing.assert_allclose(np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_vlm_prefix_shapes_and_influence(rng):
    cfg = get_config("internvl2-26b", reduced=True)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(2))
    B, S = 2, 12
    batch = tiny_batch(cfg, rng, B=B, S=S)
    out = m.forward(p, batch)["logits"]
    assert out.shape == (B, S, cfg.vocab_size)     # logits only for text positions
    b2 = dict(batch)
    b2["patch_feats"] = batch["patch_feats"] * 2.0
    out2 = m.forward(p, b2)["logits"]
    assert not np.allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


def test_vlm_loss_finite_and_differentiable(rng):
    cfg = get_config("internvl2-26b", reduced=True)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(3))
    batch = tiny_batch(cfg, rng, B=2, S=10)
    loss, _ = m.loss_fn(p, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: m.loss_fn(p, batch)[0])(p)
    proj_g = float(jnp.sum(jnp.abs(g["projector"]["w1"])))
    assert np.isfinite(proj_g) and proj_g > 0      # gradients reach the projector


def test_encdec_prefill_decode_equals_teacher_forced(rng):
    cfg = get_config("seamless-m4t-medium", reduced=True)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(4))
    B, S, P = 2, 14, 10
    batch = tiny_batch(cfg, rng, B=B, S=S)
    full = m.forward(p, batch)["logits"]
    cache = m.init_cache(B, S + 4, n_frames=cfg.n_prefix_tokens)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :P]
    lg, cache = m.prefill(p, pb, cache)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - full[:, P - 1])))]
    for i in range(P, S):
        lg, cache = m.decode_step(p, batch["tokens"][:, i:i + 1], jnp.int32(i), cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    scale = max(float(jnp.max(jnp.abs(full))), 1.0)
    assert max(errs) < 2e-3 * scale
