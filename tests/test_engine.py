"""OffloadEngine end-to-end + the paper's headline orderings."""
import numpy as np
import jax.numpy as jnp

from repro.core import (EngineConfig, FFNWeights, OffloadEngine, dense_ffn,
                        identity_placement, make_bundles, search_placement,
                        sparse_ffn_from_bundles, stats_from_masks,
                        SyntheticTraceConfig, synthetic_masks)


def _setup(n=512, seed=0, tokens=300):
    # cluster STRUCTURE is a model property shared by calibration and serving;
    # only token sampling differs (paper Fig. 15)
    cfg = SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed,
                               structure_seed=seed)
    calib = synthetic_masks(cfg, tokens)
    serve = synthetic_masks(
        SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed + 99,
                             structure_seed=seed), 150)
    placement = search_placement(stats_from_masks(calib).distance_matrix(), mode="exact")
    rng = np.random.default_rng(seed)
    bundles = rng.standard_normal((n, 64)).astype(np.float32)
    return calib, serve, placement, bundles


def test_ripple_beats_naive_io_time():
    calib, serve, placement, bundles = _setup()
    ripple = OffloadEngine(bundles, placement=placement)
    naive = OffloadEngine(bundles, placement=identity_placement(len(bundles)),
                          config=EngineConfig(collapse=False, linking_aligned_cache=False))
    ripple.run_trace(serve)
    naive.run_trace(serve)
    s_r, s_n = ripple.summary(), naive.summary()
    assert s_r["io_seconds_per_token"] < 0.5 * s_n["io_seconds_per_token"]
    assert s_r["mean_run_length"] > 1.5 * s_n["mean_run_length"]
    assert s_r["effective_bandwidth"] > s_n["effective_bandwidth"]


def test_engine_payload_matches_source_rows():
    _, serve, placement, bundles = _setup(seed=1)
    eng = OffloadEngine(bundles, placement=placement)
    ids = np.nonzero(serve[0])[0]
    data, _ = eng.step(ids)
    np.testing.assert_array_equal(data, bundles[np.unique(ids)])


def test_engine_stats_accounting():
    _, serve, placement, bundles = _setup(seed=2)
    eng = OffloadEngine(bundles, placement=placement, config=EngineConfig(cache_ratio=0.2))
    stats = eng.run_trace(serve[:50])
    for ts in stats:
        assert ts.n_hits + ts.n_misses == ts.n_activated
        assert ts.io.bytes_read >= ts.io.bytes_useful
    # cache warms up: later tokens hit more
    early = np.mean([t.n_hits / max(t.n_activated, 1) for t in stats[:10]])
    late = np.mean([t.n_hits / max(t.n_activated, 1) for t in stats[-10:]])
    assert late >= early


def test_sparse_ffn_from_bundles_equals_dense_relu():
    """ReLU sparsity is exact: FFN over the active support == dense FFN."""
    rng = np.random.default_rng(3)
    d, n = 32, 128
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.3, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.3, jnp.float32),
    )
    x = jnp.asarray(rng.standard_normal((4, d)), jnp.float32)
    ref = dense_ffn(x, w, activation="relu")
    pre = np.asarray(x @ w.w_up.T)
    active = np.nonzero(np.any(pre > 0, axis=0))[0]
    bundles = np.asarray(make_bundles(w))[active]
    out = sparse_ffn_from_bundles(x, jnp.asarray(bundles), d, n_mats=2, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_step_batch_single_request_equals_step():
    _, serve, placement, bundles = _setup(seed=5)
    e_loop = OffloadEngine(bundles, placement=placement)
    e_batch = OffloadEngine(bundles, placement=placement)
    for mask in serve[:20]:
        ids = np.nonzero(mask)[0]
        data, ts = e_loop.step(ids)
        res = e_batch.step_batch([ids])
        np.testing.assert_array_equal(res.data, data)
        assert res.merged.n_activated == ts.n_activated
        assert res.merged.n_hits == ts.n_hits
        assert res.merged.io.bytes_useful == ts.io.bytes_useful
        assert res.merged.io.seconds == ts.io.seconds
        [rs] = res.per_request
        assert (rs.n_hits, rs.n_misses) == (ts.n_hits, ts.n_misses)
        assert rs.io_seconds == ts.io.seconds


def test_step_batch_equivalent_to_step_loop():
    """Disjoint request sets: batched payload + useful bytes match a loop of
    per-request steps; the single merged read never costs more I/O time."""
    _, serve, placement, bundles = _setup(seed=6)
    n = len(bundles)
    rng = np.random.default_rng(6)
    perm = rng.permutation(n)
    id_sets = [np.sort(perm[:40]), np.sort(perm[40:90]), np.sort(perm[90:130])]
    e_loop = OffloadEngine(bundles, placement=placement)
    e_batch = OffloadEngine(bundles, placement=placement)
    loop = [e_loop.step(ids) for ids in id_sets]
    res = e_batch.step_batch(id_sets)
    for ids, (data, _) in zip(id_sets, loop):
        np.testing.assert_array_equal(res.data[res.rows_for(ids)], data)
    assert res.merged.io.bytes_useful == sum(ts.io.bytes_useful for _, ts in loop)
    assert sum(rs.bytes_useful for rs in res.per_request) == res.merged.io.bytes_useful
    assert res.merged.io.seconds <= sum(ts.io.seconds for _, ts in loop)
    # attribution conserves the merged read time (all-miss cold start)
    assert abs(sum(rs.io_seconds for rs in res.per_request)
               - res.merged.io.seconds) < 1e-12


def test_step_batch_shared_neurons_read_once():
    _, _, placement, bundles = _setup(seed=7)
    eng = OffloadEngine(bundles, placement=placement)
    shared = np.arange(30)
    res = eng.step_batch([shared, shared, shared])
    # union is read once; each request is billed a third of the one read
    assert res.merged.n_activated == 30
    assert res.merged.io.bytes_useful == 30 * eng.store.bundle_bytes
    for rs in res.per_request:
        assert rs.n_misses == 30
        assert abs(rs.io_seconds - res.merged.io.seconds / 3) < 1e-12


def test_engine_from_store_shares_config_surface():
    """Satellite cleanup: NeuronStore owns placement/device defaulting; the
    engine never re-defaults. An engine built from a prebuilt store sees the
    exact same placement object."""
    from repro.core.storage import NeuronStore
    _, _, placement, bundles = _setup(seed=8)
    store = NeuronStore(bundles, placement)
    eng = OffloadEngine.from_store(store)
    assert eng.placement is store.placement
    assert eng.store is store
    eng2 = OffloadEngine(bundles, placement=placement)
    assert eng2.placement is eng2.store.placement


def test_offline_and_online_stages_compose():
    """Paper Fig. 11: offline-only and online-only each help; combined best."""
    calib, serve, placement, bundles = _setup(seed=4)
    n = len(bundles)

    def run(pl, collapse, link_cache):
        eng = OffloadEngine(bundles, placement=pl, config=EngineConfig(
            collapse=collapse, linking_aligned_cache=link_cache))
        eng.run_trace(serve)
        return eng.summary()["io_seconds_per_token"]

    base = run(identity_placement(n), False, False)
    offline_only = run(placement, False, False)
    online_only = run(identity_placement(n), True, True)
    both = run(placement, True, True)
    assert offline_only < base
    assert online_only < base
    assert both < offline_only
    assert both < online_only
