"""OffloadEngine end-to-end + the paper's headline orderings."""
import numpy as np
import jax.numpy as jnp

from repro.core import (EngineConfig, FFNWeights, OffloadEngine, dense_ffn,
                        identity_placement, make_bundles, search_placement,
                        sparse_ffn_from_bundles, stats_from_masks,
                        SyntheticTraceConfig, synthetic_masks)


def _setup(n=512, seed=0, tokens=300):
    # cluster STRUCTURE is a model property shared by calibration and serving;
    # only token sampling differs (paper Fig. 15)
    cfg = SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed,
                               structure_seed=seed)
    calib = synthetic_masks(cfg, tokens)
    serve = synthetic_masks(
        SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed + 99,
                             structure_seed=seed), 150)
    placement = search_placement(stats_from_masks(calib).distance_matrix(), mode="exact")
    rng = np.random.default_rng(seed)
    bundles = rng.standard_normal((n, 64)).astype(np.float32)
    return calib, serve, placement, bundles


def test_ripple_beats_naive_io_time():
    calib, serve, placement, bundles = _setup()
    ripple = OffloadEngine(bundles, placement=placement)
    naive = OffloadEngine(bundles, placement=identity_placement(len(bundles)),
                          config=EngineConfig(collapse=False, linking_aligned_cache=False))
    ripple.run_trace(serve)
    naive.run_trace(serve)
    s_r, s_n = ripple.summary(), naive.summary()
    assert s_r["io_seconds_per_token"] < 0.5 * s_n["io_seconds_per_token"]
    assert s_r["mean_run_length"] > 1.5 * s_n["mean_run_length"]
    assert s_r["effective_bandwidth"] > s_n["effective_bandwidth"]


def test_engine_payload_matches_source_rows():
    _, serve, placement, bundles = _setup(seed=1)
    eng = OffloadEngine(bundles, placement=placement)
    ids = np.nonzero(serve[0])[0]
    data, _ = eng.step(ids)
    np.testing.assert_array_equal(data, bundles[np.unique(ids)])


def test_engine_stats_accounting():
    _, serve, placement, bundles = _setup(seed=2)
    eng = OffloadEngine(bundles, placement=placement, config=EngineConfig(cache_ratio=0.2))
    stats = eng.run_trace(serve[:50])
    for ts in stats:
        assert ts.n_hits + ts.n_misses == ts.n_activated
        assert ts.io.bytes_read >= ts.io.bytes_useful
    # cache warms up: later tokens hit more
    early = np.mean([t.n_hits / max(t.n_activated, 1) for t in stats[:10]])
    late = np.mean([t.n_hits / max(t.n_activated, 1) for t in stats[-10:]])
    assert late >= early


def test_sparse_ffn_from_bundles_equals_dense_relu():
    """ReLU sparsity is exact: FFN over the active support == dense FFN."""
    rng = np.random.default_rng(3)
    d, n = 32, 128
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.3, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.3, jnp.float32),
    )
    x = jnp.asarray(rng.standard_normal((4, d)), jnp.float32)
    ref = dense_ffn(x, w, activation="relu")
    pre = np.asarray(x @ w.w_up.T)
    active = np.nonzero(np.any(pre > 0, axis=0))[0]
    bundles = np.asarray(make_bundles(w))[active]
    out = sparse_ffn_from_bundles(x, jnp.asarray(bundles), d, n_mats=2, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_offline_and_online_stages_compose():
    """Paper Fig. 11: offline-only and online-only each help; combined best."""
    calib, serve, placement, bundles = _setup(seed=4)
    n = len(bundles)

    def run(pl, collapse, link_cache):
        eng = OffloadEngine(bundles, placement=pl, config=EngineConfig(
            collapse=collapse, linking_aligned_cache=link_cache))
        eng.run_trace(serve)
        return eng.summary()["io_seconds_per_token"]

    base = run(identity_placement(n), False, False)
    offline_only = run(placement, False, False)
    online_only = run(identity_placement(n), True, True)
    both = run(placement, True, True)
    assert offline_only < base
    assert online_only < base
    assert both < offline_only
    assert both < online_only
