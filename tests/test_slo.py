"""Overload robustness: SLO-aware admission, backpressure, and deadlines.

The contract under test (ISSUE 8 acceptance): a full admission queue sheds
the worst strictly-lower-priority request or rejects the newcomer, never
grows past `queue_limit`; admission order is priority then earliest TTFT
deadline; blown TTFT / inter-token deadlines retire requests with
`finish_reason="timeout"` — partial tokens preserved, surviving co-batched
requests token-identical to an unloaded run, per-uid io_seconds attribution
still conserved; the stall watchdog raises a diagnosable error instead of
spinning; `finished_high_water` bounds server-held results; and the
prediction chain (cache peek -> extent pricing -> compute share) is pure.
"""
import numpy as np
import pytest

from repro.core import EngineConfig, OffloadEngine
from repro.core.cache import make_linking_aligned_cache
from repro.core.pipeline import IOScheduler
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.server import (InferenceServer, RequestState,
                                  ServerStalledError)
from tests.test_server import _setup, _solo_tokens


class FakeClock:
    """Deterministic monotonic clock for deadline tests: time moves only
    when the test says so, so 'a second passed' is an assertion, not a
    sleep."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _req(rng, uid, new=4, T=6, **kw):
    return Request(uid=uid, prompt=rng.integers(0, 128, T).astype(np.int32),
                   max_new_tokens=new, **kw)


# -- backpressure -------------------------------------------------------------

def test_queue_full_rejects_equal_priority_newcomer(rng):
    cfg, model, params = _setup(seed=20)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             queue_limit=1)
    h0 = server.submit(_req(rng, 0))
    h1 = server.submit(_req(rng, 1))      # queue full, same priority: bounced
    assert h1.done and h1.finish_reason == "rejected"
    assert h1.result.tokens == [] and h1.result.finish_reason == "rejected"
    assert server.stats.rejected == 1 and server.stats.shed == 0
    assert server.stats.peak_queue_depth == 1
    server.drain()
    assert h0.result.finish_reason == "length"


def test_priority_sheds_lower_class_and_admits_first(rng):
    """A high-priority arrival at a full queue evicts the newest queued
    request of the lowest strictly-lower class (that one comes back
    `rejected`), and admission serves the high class first."""
    cfg, model, params = _setup(seed=21)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             queue_limit=2)
    h0 = server.submit(_req(rng, 0, new=2))
    h1 = server.submit(_req(rng, 1, new=2))
    h2 = server.submit(_req(rng, 2, new=2, priority=1))   # sheds h1, not h0
    assert h1.done and h1.finish_reason == "rejected"
    assert not h0.done and not h2.done
    assert server.stats.shed == 1 and server.stats.rejected == 0
    server.drain()
    assert h0.result.finish_reason == h2.result.finish_reason == "length"
    # 1 slot: the priority-1 request was admitted before the earlier-queued 0
    assert h2.admitted_at < h0.admitted_at
    # conservation: every submission retired exactly once
    assert server.stats.retired == 3


def test_admission_is_earliest_ttft_deadline_first(rng):
    """Within one priority class, free slots go to the tightest TTFT deadline
    (no deadline = infinite slack), not submission order."""
    cfg, model, params = _setup(seed=22)
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    h_none = server.submit(_req(rng, 0, new=2))                  # no deadline
    h_loose = server.submit(_req(rng, 1, new=2, ttft_slo_s=120.0))
    h_tight = server.submit(_req(rng, 2, new=2, ttft_slo_s=60.0))
    server.drain()
    assert h_tight.admitted_at < h_loose.admitted_at < h_none.admitted_at
    assert server.stats.timeouts == 0


# -- deadlines ----------------------------------------------------------------

def test_ttft_deadline_expires_queued_request(rng):
    cfg, model, params = _setup(seed=23)
    clock = FakeClock()
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             clock=clock)
    r0 = _req(rng, 0, new=4)
    h0 = server.submit(r0)
    server.step()                       # h0 takes the only slot
    h1 = server.submit(_req(rng, 1, ttft_slo_s=0.5))
    server.step()
    assert h1.state is RequestState.QUEUED
    clock.advance(1.0)                  # h1's first token is now impossible
    server.step()
    assert h1.done and h1.finish_reason == "timeout"
    assert h1.result.tokens == [] and server.stats.timeouts == 1
    server.drain()                      # h0 is unaffected by the timeout
    assert h0.result.tokens == _solo_tokens(model, params, r0)


def test_itl_deadline_retires_mid_decode_partial_tokens_survivors_exact(rng):
    """A blown inter-token deadline retires the request with its partial
    tokens (a strict prefix of the unloaded run), frees the slot, keeps the
    co-batched survivor token-identical, and conserves io_seconds with the
    timed-out row out of the union."""
    cfg, model, params = _setup(seed=24)
    clock = FakeClock()
    r0, r1 = _req(rng, 0, new=8, T=8), _req(rng, 1, new=8, T=8)
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(24))
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             mode="offload", offload=rt, clock=clock)
    h0 = server.submit(Request(uid=0, prompt=r0.prompt, max_new_tokens=8,
                               itl_slo_s=0.5))
    h1 = server.submit(r1)
    for _ in range(3):
        server.step()                   # both decoding, gaps are 0 fake-time
    assert not h0.done and not h1.done
    clock.advance(1.0)                  # h0's next gap blows its 0.5s SLO
    server.step()
    assert h0.done and h0.finish_reason == "timeout"
    assert server.stats.timeouts == 1
    server.drain()
    rt_solo = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(24))
    solo0 = _solo_tokens(model, params, r0, mode="offload", runtime=rt_solo)
    rt_solo = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(24))
    solo1 = _solo_tokens(model, params, r1, mode="offload", runtime=rt_solo)
    # partial output is a strict prefix of the unloaded run; survivor exact
    n = len(h0.result.tokens)
    assert 0 < n < 8 and h0.result.tokens == solo0[:n]
    assert h1.result.tokens == solo1
    assert h1.result.finish_reason == "length"
    # io attribution still sums to the engines' merged reads, timed-out
    # row's orphan share re-billed to the survivor
    engine_total = sum(t.io.seconds for e in rt.engines for t in e.history)
    attributed = h0.result.io_seconds + h1.result.io_seconds
    assert engine_total > 0
    assert abs(attributed - engine_total) < 1e-9


def test_lifecycle_stamps_are_monotonic(rng):
    cfg, model, params = _setup(seed=25)
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    h0 = server.submit(_req(rng, 0, new=4))
    h1 = server.submit(_req(rng, 1, new=4))    # queued behind h0
    server.drain()
    for h in (h0, h1):
        assert h.queued_at <= h.admitted_at <= h.first_token_at <= h.finished_at
        assert h.first_token_at == h.token_times[0]
        assert h.token_times == sorted(h.token_times)
        assert len(h.token_times) == len(h.tokens)
    assert h0.admitted_at < h1.admitted_at     # 1 slot: strictly staggered


# -- watchdog / memory bounds -------------------------------------------------

def test_stall_watchdog_raises_diagnosable_error(rng, monkeypatch):
    """An admission gate that never opens must not spin drain() forever:
    `stall_limit` no-progress iterations raise with a queue/slot snapshot."""
    cfg, model, params = _setup(seed=26)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             stall_limit=5)
    server.submit(_req(rng, 0))
    monkeypatch.setattr(server, "_next_admission", lambda: None)
    for _ in range(4):
        assert server.step() == 0
    with pytest.raises(ServerStalledError, match="1 queued"):
        server.step()
    # progress resets the counter: the real admission path clears the stall
    monkeypatch.undo()
    server.drain()
    assert server._stall_steps == 0


def test_finished_high_water_bounds_server_memory(rng):
    cfg, model, params = _setup(seed=27)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             finished_high_water=2)
    handles = [server.submit(_req(rng, i, new=2)) for i in range(5)]
    server.drain()
    assert len(server.results()) == 2              # oldest 3 auto-released
    assert server.stats.results_released == 3
    for h in handles:                              # caller handles survive
        assert h.done and len(h.result.tokens) == 2


# -- flash-I/O-aware admission ------------------------------------------------

def test_io_gate_defers_then_admits_without_deadlock(rng):
    """With an active request whose inter-token SLO is unmeetably tight, the
    I/O gate holds the newcomer QUEUED (io_deferrals counts it); once the
    batch drains the gate cannot defer an empty batch, so the newcomer admits
    and finishes exactly."""
    cfg, model, params = _setup(seed=28)
    clock = FakeClock()                 # frozen: the tight SLO never actually
    rt = build_offload_runtime(model, params,       # expires, it only gates
                               rng=np.random.default_rng(28))
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             mode="offload", offload=rt, clock=clock)
    h0 = server.submit(Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                               max_new_tokens=6, itl_slo_s=1e-9))
    for _ in range(2):
        server.step()                   # record masks + compute history
    r1 = _req(rng, 1, new=3, T=8)
    h1 = server.submit(r1)
    server.step()
    assert h1.state is RequestState.QUEUED          # deferred, not admitted
    assert server.stats.io_deferrals >= 1
    server.drain()                                  # no deadlock: h0 retires,
    assert h0.done and h1.done                      # empty batch admits h1
    rt_solo = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(28))
    assert h1.result.tokens == _solo_tokens(model, params, r1,
                                            mode="offload", runtime=rt_solo)


def test_io_gate_headroom_scales_the_budget(rng):
    """io_headroom relaxes the same gate: a huge headroom admits what a 1.0
    headroom would defer."""
    cfg, model, params = _setup(seed=29)
    clock = FakeClock()
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(29))
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             mode="offload", offload=rt, clock=clock,
                             io_headroom=1e12)
    server.submit(Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                          max_new_tokens=6, itl_slo_s=1e-9))
    for _ in range(2):
        server.step()
    h1 = server.submit(_req(rng, 1, new=3, T=8))
    server.step()
    assert h1.state is not RequestState.QUEUED      # admitted despite the SLO
    assert server.stats.io_deferrals == 0
    server.drain()


# -- the prediction chain is pure --------------------------------------------

@pytest.mark.parametrize("impl", ["array", "dict"])
def test_peek_mask_is_pure_and_matches_lookup(rng, impl):
    cache = make_linking_aligned_cache(capacity=64, n_keys=256, impl=impl)
    warm = np.arange(0, 48, dtype=np.int64)
    cache.lookup_mask(warm)
    cache.admit(warm, warm)             # identity placement is fine here
    query = rng.integers(0, 256, 64).astype(np.int64)
    before = (cache.stats.hits, cache.stats.misses)
    peek = cache.peek_mask(query)
    assert (cache.stats.hits, cache.stats.misses) == before   # no mutation
    np.testing.assert_array_equal(peek, cache.lookup_mask(query))
    assert cache.peek_mask(np.zeros(0, dtype=np.int64)).shape == (0,)


def test_predict_read_seconds_matches_the_step_it_predicts(rng):
    """The admission gate's price for a union equals the io seconds the very
    next `step()` on that union reports — and predicting is free: no cache,
    threshold, or history movement."""
    bundles = rng.standard_normal((256, 64)).astype(np.float32)
    eng = OffloadEngine(bundles, config=EngineConfig(cache_ratio=0.25))
    union = np.unique(rng.integers(0, 256, 96)).astype(np.int64)
    pred_cold = eng.predict_read_seconds(union)
    assert eng.predict_read_seconds(union) == pred_cold       # idempotent
    assert eng.history == [] and eng.cache.stats.hits == 0
    _, ts = eng.step(union)
    assert pred_cold > 0
    assert abs(pred_cold - ts.io.seconds) < 1e-12
    # warm now: the same union is partly resident, so the price drops
    assert eng.predict_read_seconds(union) < pred_cold
    assert eng.predict_read_seconds(np.zeros(0, dtype=np.int64)) == 0.0


def test_scheduler_predicted_compute_share():
    sched = IOScheduler(overlap=True)
    assert sched.predicted_compute_seconds_per_token() == 0.0   # cold server
    for io, compute in ((0.004, 0.010), (0.006, 0.020)):
        sched.begin_token()
        sched.record_stage(0, io_seconds=io, flops=1.0)
        sched.end_token(compute_seconds=compute)
    # mean (serial - io) over the window = mean compute
    assert sched.predicted_compute_seconds_per_token() == pytest.approx(0.015)
    assert sched.predicted_compute_seconds_per_token(window=1) == \
        pytest.approx(0.020)
