"""Sharding rules: spec validity on abstract meshes + distributed equivalence
(subprocess with 8 forced host devices, so this process keeps 1 device)."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.distributed.sharding import (abstract_mesh, batch_spec, cache_specs,
                                        param_specs)
from repro.models import build_model


def _abstract_mesh(multi_pod=False):
    if multi_pod:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", sorted(ASSIGNED_CONFIGS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    """Every assigned spec dim must divide by its mesh axis size."""
    cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16")
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    mesh = _abstract_mesh(multi_pod)
    specs = param_specs(params_shape, mesh)
    flat_p = jax.tree_util.tree_leaves(params_shape)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    n_model_sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (leaf.shape, spec)
            if "model" in axes:
                n_model_sharded += 1
    # the bulk of parameters must actually be model-sharded
    assert n_model_sharded >= len(flat_p) // 4


def test_batch_spec_divisibility_fallbacks():
    mesh = _abstract_mesh(multi_pod=True)   # pod*data = 32
    assert batch_spec(mesh, 256, 2)[0] == ("pod", "data")
    # PartitionSpec normalises a 1-tuple to the bare axis name
    assert batch_spec(mesh, 16, 2)[0] in ("data", ("data",))
    assert batch_spec(mesh, 1, 2)[0] is None


@pytest.mark.parametrize("arch", ["internlm2-20b", "jamba-1.5-large-398b", "xlstm-125m"])
def test_cache_specs_valid(arch):
    cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16")
    model = build_model(cfg)
    mesh = _abstract_mesh()
    B = 128
    cache = jax.eval_shape(lambda: model.init_cache(B, 1024))
    specs = cache_specs(cache, mesh, B)
    for leaf, spec in zip(jax.tree_util.tree_leaves(cache),
                          jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (leaf.shape, spec)


_DISTRIBUTED_SCRIPT = r"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"   # libtpu may be installed: never probe TPU
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.distributed.sharding import (param_specs, batch_spec, named,
                                        make_mesh as compat_make_mesh)
from repro.training.optimizer import AdamWConfig, AdamWState, init_adamw
from repro.training.train import TrainState, make_train_step

cfg = get_config("granite-3-2b", reduced=True, d_model=256, n_heads=4, n_kv_heads=2,
                 vocab_size=512, d_ff=512)
model = build_model(cfg)
opt_cfg = AdamWConfig()
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}
step = make_train_step(model, opt_cfg)

# single-device reference
state0 = TrainState(params=model.init_params(jax.random.PRNGKey(0)),
                    opt=init_adamw(model.init_params(jax.random.PRNGKey(0)), opt_cfg))
ref_state, ref_metrics = jax.jit(step)(state0, batch)

# distributed
mesh = compat_make_mesh((2, 4), ("data", "model"))
pspecs = param_specs(jax.eval_shape(model.init_params, jax.random.PRNGKey(0)), mesh)
sspecs = TrainState(params=pspecs, opt=AdamWState(step=P(), mu=pspecs, nu=pspecs))
bspec = {"tokens": batch_spec(mesh, 8, 2)}
state_d = jax.device_put(state0, named(sspecs, mesh))
batch_d = jax.device_put(batch, named(bspec, mesh))
with mesh:
    dist_state, dist_metrics = jax.jit(
        step, in_shardings=(named(sspecs, mesh), named(bspec, mesh)),
        out_shardings=(named(sspecs, mesh),
                       jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), ref_metrics)),
    )(state_d, batch_d)

# sharded chunked-CE reductions reorder f32 sums; match the 2e-3 rel
# tolerance the parameter comparison below already uses
ref_loss, dist_loss = float(ref_metrics["loss"]), float(dist_metrics["loss"])
assert abs(ref_loss - dist_loss) < 2e-3 * max(abs(ref_loss), 1.0), \
    (ref_loss, dist_loss)
for a, b in zip(jax.tree_util.tree_leaves(ref_state.params),
                jax.tree_util.tree_leaves(dist_state.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)),
                               rtol=2e-3, atol=2e-3)
print("DISTRIBUTED_MATCH")
"""


def test_distributed_train_step_matches_single_device():
    res = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "DISTRIBUTED_MATCH" in res.stdout, res.stdout + res.stderr
