"""Serving engine + offloaded FFN runtime."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.placement import identity_placement
from repro.core.sparse_ffn import FFNWeights, dense_ffn, make_bundles
from repro.models import build_model
from repro.serving.engine import (OffloadedFFNRuntime, Request, ServingEngine,
                                  sample_token)


def test_greedy_serving_matches_manual_decode(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    engine = ServingEngine(model, params, max_len=64)
    [res] = engine.serve([Request(uid=0, prompt=prompt, max_new_tokens=5)])
    # manual greedy decode
    cache = model.init_cache(1, 64)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = []
    cur = jnp.argmax(logits[:, -1], -1)
    for i in range(5):
        toks.append(int(cur[0]))
        logits, cache = model.decode_step(params, cur[:, None].astype(jnp.int32),
                                          jnp.int32(8 + i), cache)
        cur = jnp.argmax(logits[:, 0], -1)
    assert res.tokens == toks
    assert res.prefill_seconds > 0 and res.decode_seconds > 0


def test_batched_requests_grouped(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    engine = ServingEngine(model, params, max_len=48)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    results = engine.serve(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2, 3]
    assert all(len(r.tokens) == 3 for r in results)


def test_sample_token_temperature_zero_is_argmax():
    logits = jnp.asarray([[0.1, 2.0, -1.0]])
    assert int(sample_token(logits, 0.0, jax.random.PRNGKey(0))[0]) == 1


def test_offloaded_ffn_matches_dense(rng):
    """The engine's sparse FFN from flash bundles == dense FFN under ReLU."""
    d, n, L = 32, 256, 2
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    ws = []
    bundles = []
    for _ in range(L):
        w = FFNWeights(
            w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
            w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
        ws.append(w)
        bundles.append(np.asarray(make_bundles(w)))
    placements = [identity_placement(n) for _ in range(L)]
    runtime = OffloadedFFNRuntime(cfg, bundles, placements,
                                  engine_cfg=EngineConfig(cache_ratio=0.2))
    h = rng.standard_normal((3, d)).astype(np.float32)
    for layer in range(L):
        pre = h @ np.asarray(ws[layer].w_up).T
        mask = pre > 0
        y, stats = runtime.ffn_apply(layer, h, oracle_mask=mask)
        ref = np.asarray(dense_ffn(jnp.asarray(h), ws[layer], activation="relu"))
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
        assert stats.n_activated == int(np.any(mask, axis=0).sum())
    summ = runtime.io_summary()
    assert summ["io_seconds_per_token"] > 0
    assert summ["ops_per_token"] >= 2   # one read batch per layer minimum
