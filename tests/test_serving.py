"""Serving engine + offloaded FFN runtime."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.pipeline import IOScheduler
from repro.core.placement import identity_placement
from repro.core.sparse_ffn import FFNWeights, dense_ffn, make_bundles
from repro.models import build_model
from repro.serving.engine import (OffloadedFFNRuntime, Request, ServingEngine,
                                  build_offload_runtime, sample_token)


def test_greedy_serving_matches_manual_decode(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    engine = ServingEngine(model, params, max_len=64)
    [res] = engine.serve([Request(uid=0, prompt=prompt, max_new_tokens=5)])
    # manual greedy decode
    cache = model.init_cache(1, 64)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = []
    cur = jnp.argmax(logits[:, -1], -1)
    for i in range(5):
        toks.append(int(cur[0]))
        logits, cache = model.decode_step(params, cur[:, None].astype(jnp.int32),
                                          jnp.int32(8 + i), cache)
        cur = jnp.argmax(logits[:, 0], -1)
    assert res.tokens == toks
    assert res.prefill_seconds > 0 and res.decode_seconds > 0


def test_batched_requests_grouped(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    engine = ServingEngine(model, params, max_len=48)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    results = engine.serve(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2, 3]
    assert all(len(r.tokens) == 3 for r in results)


def test_sample_token_temperature_zero_is_argmax():
    logits = jnp.asarray([[0.1, 2.0, -1.0]])
    assert int(sample_token(logits, 0.0, jax.random.PRNGKey(0))[0]) == 1


def test_offloaded_ffn_matches_dense(rng):
    """The engine's sparse FFN from flash bundles == dense FFN under ReLU."""
    d, n, L = 32, 256, 2
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    ws = []
    bundles = []
    for _ in range(L):
        w = FFNWeights(
            w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
            w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
        ws.append(w)
        bundles.append(np.asarray(make_bundles(w)))
    placements = [identity_placement(n) for _ in range(L)]
    runtime = OffloadedFFNRuntime(cfg, bundles, placements,
                                  engine_cfg=EngineConfig(cache_ratio=0.2))
    h = rng.standard_normal((3, d)).astype(np.float32)
    for layer in range(L):
        pre = h @ np.asarray(ws[layer].w_up).T
        mask = pre > 0
        y, stats = runtime.ffn_apply(layer, h, oracle_mask=mask)
        ref = np.asarray(dense_ffn(jnp.asarray(h), ws[layer], activation="relu"))
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
        assert stats.n_activated == int(np.any(mask, axis=0).sum())
    summ = runtime.io_summary()
    assert summ["io_seconds_per_token"] > 0
    assert summ["ops_per_token"] >= 2   # one read batch per layer minimum


def test_ffn_apply_batch_matches_dense_per_request(rng):
    """Batched apply: per-request masks, one merged read, still exact."""
    d, n = 32, 256
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
    runtime = OffloadedFFNRuntime(cfg, [np.asarray(make_bundles(w))],
                                  [identity_placement(n)])
    h = rng.standard_normal((4, d)).astype(np.float32)
    masks = np.asarray(h @ np.asarray(w.w_up).T > 0)
    y, res = runtime.ffn_apply_batch(0, jnp.asarray(h), masks)
    ref = np.asarray(dense_ffn(jnp.asarray(h), w, activation="relu"))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
    assert len(res.per_request) == 4
    assert res.merged.n_activated == int(np.any(masks, axis=0).sum())
    assert sum(rs.n_misses for rs in res.per_request) >= res.merged.n_misses


def _tiny_offload_setup(seed=0, n_layers=2):
    cfg = get_config("opt-350m", reduced=True, d_model=64, d_ff=256,
                     n_layers=n_layers, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    return cfg, model, params, reqs


def test_offload_serve_token_identical_to_resident():
    """Acceptance: mode='offload' under the oracle mask returns the resident
    path's tokens exactly, with Result.io_seconds > 0."""
    cfg, model, params, reqs = _tiny_offload_setup()
    res_resident = ServingEngine(model, params, max_len=32).serve(reqs)
    runtime = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(1))
    engine = ServingEngine(model, params, max_len=32, mode="offload",
                           offload=runtime, scheduler=IOScheduler(overlap=True))
    res_offload = engine.serve(reqs)
    for a, b in zip(res_resident, res_offload):
        assert a.uid == b.uid
        assert a.tokens == b.tokens
        assert b.io_seconds > 0
        assert b.overlapped_seconds > 0
    p = engine.scheduler.summary()
    # max_new=4 => 3 batched decode iterations: the first token of each
    # request comes from its prefill, and the server never runs the old
    # path's trailing decode step whose sample was discarded
    assert p["tokens"] == 3
    assert p["overlapped_seconds_per_token"] <= p["serial_seconds_per_token"]
    assert runtime.io_summary()["io_seconds_per_token"] > 0


def test_unstack_stack_groups_roundtrip():
    import jax.tree_util as jtu
    from repro.models import transformer
    cfg, model, params, _ = _tiny_offload_setup(seed=4)
    groups = transformer.unstack_groups(params["stack"], cfg)
    assert len(groups) == cfg.n_layers // transformer.stack_period(cfg)
    restacked = transformer.stack_groups(groups)
    for a, b in zip(jtu.tree_leaves(params["stack"]), jtu.tree_leaves(restacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_offload_serve_overlap_off_equals_serial():
    cfg, model, params, reqs = _tiny_offload_setup(seed=3)
    runtime = build_offload_runtime(model, params, use_placement=False,
                                    rng=np.random.default_rng(2))
    engine = ServingEngine(model, params, max_len=32, mode="offload",
                           offload=runtime,
                           scheduler=IOScheduler(overlap=False))
    engine.serve(reqs)
    p = engine.scheduler.summary()
    assert p["overlapped_seconds_per_token"] == p["serial_seconds_per_token"]
    assert p["overlap_efficiency"] == 0.0


def test_mixed_temperature_group_honors_each_request(rng):
    """Satellite fix: both serve paths used group[0].temperature for every
    request. Greedy rows must stay exact argmax even when other rows in the
    same group sample at high temperature."""
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(7))
    prompts = [rng.integers(0, 64, 8).astype(np.int32) for _ in range(3)]
    greedy_only = ServingEngine(model, params, max_len=48).serve(
        [Request(uid=i, prompt=p, max_new_tokens=4)
         for i, p in enumerate(prompts)], seed=0)
    mixed = ServingEngine(model, params, max_len=48).serve(
        [Request(uid=0, prompt=prompts[0], max_new_tokens=4, temperature=5.0),
         Request(uid=1, prompt=prompts[1], max_new_tokens=4),   # greedy
         Request(uid=2, prompt=prompts[2], max_new_tokens=4, temperature=2.0)],
        seed=0)
    # the greedy request is unaffected by its neighbours' temperatures
    assert mixed[1].tokens == greedy_only[1].tokens
    # sampling at high temperature actually samples (not argmax) for at
    # least one of the hot rows on this seed
    assert (mixed[0].tokens != greedy_only[0].tokens
            or mixed[2].tokens != greedy_only[2].tokens)


def test_sample_tokens_vectorized_per_row():
    from repro.serving.engine import sample_tokens
    logits = jnp.asarray([[0.1, 2.0, -1.0], [5.0, 0.0, 0.0]])
    toks = sample_tokens(logits, np.array([0.0, 0.0]), jax.random.PRNGKey(0))
    assert toks.tolist() == [1, 0]
    # greedy rows stay argmax in a mixed batch
    mixed = sample_tokens(logits, np.array([3.0, 0.0]), jax.random.PRNGKey(0))
    assert int(mixed[1]) == 0


def test_segment_kernel_serving_path_matches_bundles_on_permuted_layout(rng):
    """Satellite: EngineConfig.ffn_kernel='segments' routes the serving FFN
    through the Pallas segment-gather kernel (interpret mode on CPU) over the
    PERMUTED physical layout; under the ReLU oracle it must match both the
    bundle-payload path and the dense reference."""
    import numpy as _np
    d, n = 128, 512
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
    bundles = np.asarray(make_bundles(w))
    perm = _np.random.default_rng(5).permutation(n).astype(np.int64)
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    from repro.core.placement import PlacementResult
    pl = PlacementResult(placement=perm, inverse=inv, edges_used=0,
                         search_seconds=0.0, mode="test-perm")
    rt_seg = OffloadedFFNRuntime(
        cfg, [bundles], [pl],
        engine_cfg=EngineConfig(ffn_kernel="segments", kernel_seg_size=128))
    rt_ref = OffloadedFFNRuntime(cfg, [bundles], [pl],
                                 engine_cfg=EngineConfig(ffn_kernel="bundles"))
    # "auto" promotes segments on this permuted (non-identity) layout
    rt_auto = OffloadedFFNRuntime(cfg, [bundles], [pl])
    assert rt_auto.ffn_kernel == "segments"
    h = rng.standard_normal((3, d)).astype(np.float32)
    masks = np.asarray(h @ np.asarray(w.w_up).T > 0)
    y_seg, res_seg = rt_seg.ffn_apply_batch(0, jnp.asarray(h), masks)
    y_ref, res_ref = rt_ref.ffn_apply_batch(0, jnp.asarray(h), masks)
    dense = np.asarray(dense_ffn(jnp.asarray(h), w, activation="relu"))
    np.testing.assert_allclose(np.asarray(y_seg), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_seg), dense, rtol=1e-4, atol=1e-4)
    # the kernel choice must not change the I/O accounting
    assert res_seg.merged.io.seconds == res_ref.merged.io.seconds
    # and it also serves the prefetch pipeline (imperfect speculation)
    spec = masks.copy()
    spec[:, ::4] = False
    rt_seg.start_prefetch()
    try:
        rt_seg.begin_layer(0, spec)
        y_pipe, _, _ = rt_seg.complete_layer(0, jnp.asarray(h), masks)
    finally:
        rt_seg.stop_prefetch()
    np.testing.assert_allclose(np.asarray(y_pipe), dense, rtol=1e-4, atol=1e-4)


def test_segment_kernel_exact_for_gated_silu(rng):
    """The fused segment kernel masks covered-but-not-activated neurons
    in-kernel (per-neuron scale tiles), so the former relu/relu2-only guard
    is gone: a gated silu arch on the segments path must match the bundles
    path AND the dense reference over the same activated set."""
    d, n = 32, 256
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="silu")
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_gate=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
    bundles = np.asarray(make_bundles(w))
    rt_seg = OffloadedFFNRuntime(cfg, [bundles], [identity_placement(n)],
                                 engine_cfg=EngineConfig(ffn_kernel="segments"))
    rt_ref = OffloadedFFNRuntime(cfg, [bundles], [identity_placement(n)],
                                 engine_cfg=EngineConfig(ffn_kernel="bundles"))
    h = rng.standard_normal((3, d)).astype(np.float32)
    # silu has no exact sparse support; serve a sparse activated subset and
    # compare against the masked dense computation over exactly that subset
    masks = rng.random((3, n)) < 0.2
    y_seg, _ = rt_seg.ffn_apply_batch(0, jnp.asarray(h), masks)
    y_ref, _ = rt_ref.ffn_apply_batch(0, jnp.asarray(h), masks)
    np.testing.assert_allclose(np.asarray(y_seg), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    union = np.any(masks, axis=0)
    pre = h @ np.asarray(w.w_up).T
    act = pre / (1 + np.exp(-pre)) * (h @ np.asarray(w.w_gate).T)
    dense_sub = (act * union[None, :]) @ np.asarray(w.w_down)
    np.testing.assert_allclose(np.asarray(y_seg), dense_sub,
                               rtol=1e-4, atol=1e-4)


def test_io_summary_aggregates_from_sums(rng):
    """Satellite fix: effective_bandwidth / cache_hit_rate were means of
    per-layer ratios; they must be traffic-weighted (summed numerators over
    summed denominators)."""
    d, n = 16, 128
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
    bundles = np.asarray(make_bundles(w))
    runtime = OffloadedFFNRuntime(cfg, [bundles, bundles],
                                  [identity_placement(n), identity_placement(n)])
    h = rng.standard_normal((2, d)).astype(np.float32)
    masks = np.asarray(h @ np.asarray(w.w_up).T > 0)
    # drive layer 0 with 5x the traffic of layer 1
    for _ in range(5):
        runtime.ffn_apply_batch(0, jnp.asarray(h), masks)
    runtime.ffn_apply_batch(1, jnp.asarray(h), masks)
    summ = runtime.io_summary()
    tokens = [t for e in runtime.engines for t in e.history]
    io_s = sum(t.io.seconds for t in tokens)
    useful = sum(t.io.bytes_useful for t in tokens)
    hits = sum(e.cache.stats.hits for e in runtime.engines)
    accesses = sum(e.cache.stats.hits + e.cache.stats.misses
                   for e in runtime.engines)
    assert summ["effective_bandwidth"] == (useful / io_s if io_s else 0.0)
    assert summ["cache_hit_rate"] == hits / accesses
