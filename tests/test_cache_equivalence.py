"""Array-native cache == reference dict S3-FIFO, decision for decision.

The tentpole claim of the vectorized hot path: `ArrayLinkingAlignedCache`
makes exactly the decisions of the reference `LinkingAlignedCache` — same
hit/miss masks, same admissions and rejections, same evictions and ghost
promotions, and the same FIFO queue orders (including frequencies), step by
step, on randomized traces. Queue-order equality is the strong form: any
divergence in eviction interleaving would surface there before it could
surface in aggregate stats.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache import (ArrayLinkingAlignedCache, LinkingAlignedCache,
                              make_linking_aligned_cache)
from repro.core.engine import EngineConfig, OffloadEngine
from repro.utils import stable_hash, stable_hash_array, stable_uniform_array


def _drive_pair(rng, n_keys, capacity, steps, seg_p, min_len, aligned):
    ref = LinkingAlignedCache(capacity, segment_min_len=min_len,
                              segment_admit_p=seg_p, linking_aligned=aligned)
    arr = ArrayLinkingAlignedCache(capacity, n_keys, segment_min_len=min_len,
                                   segment_admit_p=seg_p, linking_aligned=aligned)
    perm = rng.permutation(n_keys)   # random physical layout
    for t in range(steps):
        ids = set()
        for _ in range(int(rng.integers(1, 4))):   # contiguous blocks -> runs
            start = int(rng.integers(0, n_keys))
            ids.update(range(start, min(n_keys, start + int(rng.integers(1, 10)))))
        ids.update(rng.choice(n_keys, size=int(rng.integers(1, max(2, n_keys // 4))),
                              replace=False).tolist())
        ids = np.array(sorted(ids), dtype=np.int64)

        m_ref = ref.lookup_mask(ids)
        m_arr = arr.lookup_mask(ids)
        assert np.array_equal(m_ref, m_arr), f"hit-mask divergence at step {t}"
        misses = ids[~m_ref]
        phys = perm[misses]
        ref.admit(misses, phys)
        arr.admit(misses, phys)
        assert ref.cache.queues() == arr.cache.queues(), \
            f"queue divergence at step {t}"
        for f in ("hits", "misses", "admitted", "rejected", "evicted",
                  "ghost_promotions"):
            assert getattr(ref.stats, f) == getattr(arr.stats, f), (t, f)
        assert np.array_equal(ref.resident_ids(), arr.resident_ids())
    return ref, arr


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_decision_equivalence_randomized_traces(seed):
    """Random capacities (incl. tiny, which stress every eviction corner),
    random admission parameters, random id streams with planted runs."""
    rng = np.random.default_rng(seed)
    n_keys = int(rng.integers(30, 800))
    capacity = int(rng.integers(0, max(1, n_keys // 2)))
    _drive_pair(rng, n_keys, capacity, steps=25,
                seg_p=float(rng.uniform(0, 1)),
                min_len=int(rng.integers(2, 8)),
                aligned=bool(rng.integers(0, 2)))


def test_equivalence_steady_state_no_fallback():
    """At serving-like scale the array cache must stay on its bulk path —
    the exact sequential replay is for pathological inputs only."""
    rng = np.random.default_rng(0)
    n_keys, cap = 8192, 819
    _, arr = _drive_pair(rng, n_keys, cap, steps=30, seg_p=0.25,
                         min_len=4, aligned=True)
    assert arr.cache.fallback_batches == 0
    lc = arr.loop_counters
    assert lc.probe == lc.classify == lc.sample == 0


def test_reference_counts_per_neuron_iterations():
    rng = np.random.default_rng(1)
    ref, arr = _drive_pair(rng, 256, 64, steps=10, seg_p=0.5, min_len=3,
                           aligned=True)
    assert ref.loop_counters.probe > 0          # one iteration per probed id
    assert ref.loop_counters.per_neuron_total > 0
    assert arr.loop_counters.per_neuron_total == 0


def test_stable_uniform_array_matches_scalar():
    """Admission sampling must be bitwise-identical across implementations."""
    ids = np.arange(0, 3000, 7, dtype=np.int64)
    assert np.array_equal(
        stable_hash_array(5, 42, ids),
        np.array([stable_hash(5, 42, int(i)) for i in ids], dtype=np.uint64))
    u = stable_uniform_array(5, 42, ids)
    assert np.all((u >= 0) & (u < 1))


def test_factory_returns_decision_identical_impls():
    a = make_linking_aligned_cache(32, n_keys=128, impl="array")
    d = make_linking_aligned_cache(32, n_keys=128, impl="dict")
    assert isinstance(a, ArrayLinkingAlignedCache)
    assert isinstance(d, LinkingAlignedCache)
    ids = np.arange(0, 128, 3)
    ma, md = a.lookup_mask(ids), d.lookup_mask(ids)
    assert np.array_equal(ma, md)
    a.admit(ids, ids.copy())
    d.admit(ids, ids.copy())
    assert np.array_equal(a.resident_ids(), d.resident_ids())


# -- engine-level regressions ------------------------------------------------

def _mask_batches(rng, n, B, steps, p=0.06):
    return [rng.random((B, n)) < p for _ in range(steps)]


def test_engine_array_vs_dict_cache_identical_steps():
    """The whole engine (probe -> collapse read -> admit) makes identical
    decisions under either cache implementation."""
    rng = np.random.default_rng(2)
    n = 512
    bundles = rng.standard_normal((n, 8)).astype(np.float32)
    ea = OffloadEngine(bundles, config=EngineConfig(cache_impl="array"))
    ed = OffloadEngine(bundles, config=EngineConfig(cache_impl="dict"))
    for masks in _mask_batches(rng, n, 3, 20):
        ra = ea.step_masks(masks)
        rd = ed.step_batch([np.flatnonzero(r) for r in masks])
        assert np.array_equal(ra.ids, rd.ids)
        assert ra.merged.n_hits == rd.merged.n_hits
        assert ra.merged.io.seconds == rd.merged.io.seconds
        assert np.array_equal(ra.merged.run_lengths, rd.merged.run_lengths)
        assert np.array_equal(ra.req_n_misses, rd.req_n_misses)
        np.testing.assert_allclose(ra.req_io_seconds, rd.req_io_seconds)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_per_request_io_sums_to_merged_read(seed):
    """Regression: attribution conserves the merged read time exactly, and
    hit/miss counts stay consistent per request."""
    rng = np.random.default_rng(seed)
    n = 256
    bundles = rng.standard_normal((n, 8)).astype(np.float32)
    eng = OffloadEngine(bundles)
    for masks in _mask_batches(rng, n, int(rng.integers(1, 5)), 8, p=0.1):
        res = eng.step_masks(masks)
        assert abs(res.req_io_seconds.sum() - res.merged.io.seconds) < 1e-12
        for rs in res.per_request:
            assert rs.n_hits + rs.n_misses == rs.n_activated
        assert int(res.req_n_activated.sum()) == int(masks.sum())


def test_step_masks_equals_step_batch_payload_and_rows():
    rng = np.random.default_rng(3)
    n = 384
    bundles = rng.standard_normal((n, 8)).astype(np.float32)
    e1 = OffloadEngine(bundles)
    e2 = OffloadEngine(bundles)
    masks = rng.random((4, n)) < 0.08
    r1 = e1.step_masks(masks)
    r2 = e2.step_batch([np.flatnonzero(r) for r in masks])
    np.testing.assert_array_equal(r1.data, r2.data)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    ids0 = np.flatnonzero(masks[0])
    np.testing.assert_array_equal(r1.data[r1.rows_for(ids0)], bundles[ids0])
