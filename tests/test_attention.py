"""Attention: flash == dense, masks, RoPE, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import flash_gqa_attend, gqa_attend, rope


def _qkv(rng, B=2, T=48, S=48, H=4, KV=2, hd=16):
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    return q, k, v


@given(causal=st.booleans(), window=st.sampled_from([0, 7, 16]),
       qc=st.sampled_from([8, 17, 48]), kc=st.sampled_from([8, 13, 48]))
@settings(max_examples=12, deadline=None)
def test_flash_equals_dense(causal, window, qc, kc):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng)
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48)).astype(jnp.int32)
    a = gqa_attend(q, k, v, pos, pos, causal=causal, window=window)
    b = flash_gqa_attend(q, k, v, pos, pos, causal=causal, window=window,
                         q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_causal_mask_blocks_future(rng):
    q, k, v = _qkv(rng)
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48)).astype(jnp.int32)
    out1 = gqa_attend(q, k, v, pos, pos, causal=True)
    k2 = k.at[:, 30:].set(99.0)
    v2 = v.at[:, 30:].set(99.0)
    out2 = gqa_attend(q, k2, v2, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, :30]), np.asarray(out2[:, :30]),
                               rtol=1e-5, atol=1e-5)


def test_window_limits_reach(rng):
    q, k, v = _qkv(rng)
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48)).astype(jnp.int32)
    out_w = gqa_attend(q, k, v, pos, pos, causal=True, window=8)
    # perturbing keys older than the window must not change later outputs
    k2 = k.at[:, :16].set(-50.0)
    v2 = v.at[:, :16].set(50.0)
    out_w2 = gqa_attend(q, k2, v2, pos, pos, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out_w[:, 24:]), np.asarray(out_w2[:, 24:]),
                               rtol=1e-5, atol=1e-5)


def test_gqa_heads_share_kv(rng):
    """All query heads in a group see the same K/V: with identical q rows the
    grouped heads produce identical outputs."""
    B, T, H, KV, hd = 1, 8, 4, 2, 16
    q1 = jnp.asarray(rng.standard_normal((B, T, 1, hd)), jnp.float32)
    q = jnp.tile(q1, (1, 1, H, 1))
    k = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    out = gqa_attend(q, k, v, pos, pos, causal=True).reshape(B, T, H, hd)
    # heads 0,1 share kv head 0; heads 2,3 share kv head 1
    np.testing.assert_allclose(np.asarray(out[:, :, 0]), np.asarray(out[:, :, 1]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[:, :, 2]), np.asarray(out[:, :, 3]),
                               rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm_and_relative_property(rng):
    B, T, H, hd = 1, 16, 2, 32
    x = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    y = rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)

    def dot_at(m, n):
        qm = rope(q, jnp.full((1, 1), m, jnp.int32), 1e4)
        kn = rope(k, jnp.full((1, 1), n, jnp.int32), 1e4)
        return float(jnp.sum(qm * kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(7, 0) == pytest.approx(dot_at(17, 10), rel=1e-4)


@pytest.fixture
def rng():
    return np.random.default_rng(42)
