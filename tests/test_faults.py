"""Fault-tolerant flash serving (ISSUE 7): deterministic fault injection,
I/O retry + pack checksums, prefetch-worker supervision, and per-request
error isolation.

The contract under test (acceptance): under a seeded RECOVERABLE fault
schedule (transient read errors + latency spikes + at least one CRC-caught
corrupt extent), offload decode from a v2 NeuronPack is token-identical to
the fault-free run and `io_summary` reports `retries` / `corrupt_extents`
exactly matching the injected plan; an UNRECOVERABLE per-request fault
retires only that request with `finish_reason="error"` (exception attached
to its Result) while co-batched requests finish with unchanged tokens.
Satellites: the short-read continuation loop and the mmap fallback read
path, `PackFormatError` on malformed files, store/runtime close lifecycle,
and zero fault-counter overhead on the clean path.
"""
import errno
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.placement import identity_placement, search_placement
from repro.core.storage import NeuronStore
from repro.models import build_model
from repro.serving.engine import (OffloadedFFNRuntime, Request, ServingEngine,
                                  build_offload_runtime)
from repro.serving.server import InferenceServer
from repro.store import (CorruptExtentError, FatalFault, FaultEvent,
                         FaultInjectingStore, FaultPlan, FileNeuronStore,
                         NeuronPack, PackFormatError, RetryPolicy,
                         TransientIOError, build_pack, seeded_layer_plans,
                         write_pack)
from repro.store.format import MAGIC

FAST_RETRY = RetryPolicy(backoff_s=0.0)     # retry instantly in tests


# ---------------------------------------------------------------------------
# store-level fixtures
# ---------------------------------------------------------------------------

def _write_tiny_pack(path, n=96, w=16, seed=0, version=2, quantize="none"):
    """One-layer pack with a random (non-identity) placement."""
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((n, w)).astype(np.float32)
    d = rng.random((n, n))
    d = (d + d.T) / 2
    np.fill_diagonal(d, np.inf)
    pl = search_placement(d, mode="exact")
    write_pack(path, [data], [pl], version=version, quantize=quantize)
    return data


def _read_all(store, n, **kw):
    """One store.read over a scattered id subset; returns (data, stats)."""
    ids = np.arange(0, n, 3)
    return store.read(ids, **kw)


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_deterministic():
    kw = dict(transient_rate=0.3, latency_rate=0.2, short_read_rate=0.1,
              corrupt_rate=0.2, fatal_reads=(7,))
    a = FaultPlan.seeded(42, 50, **kw)
    b = FaultPlan.seeded(42, 50, **kw)
    assert a.n_events == b.n_events > 0
    for i in range(50):
        assert [(e.kind, e.times) for e in a.events_at(i)] == \
            [(e.kind, e.times) for e in b.events_at(i)]
    assert any(e.kind == "fatal" for e in a.events_at(7))
    # a different seed draws a different schedule
    c = FaultPlan.seeded(43, 50, **kw)
    assert any([e.kind for e in a.events_at(i)] != [e.kind for e in c.events_at(i)]
               for i in range(50))
    # injected counts only what active() hands out
    assert all(v == 0 for v in a.injected.values())
    a.active(7, 0)
    assert a.injected["fatal"] == 1


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0, "gremlins")


def test_corrupt_payload_replayable():
    plan = FaultPlan(seed=9)
    a, b = bytearray(b"\0" * 64), bytearray(b"\0" * 64)
    plan.corrupt_payload(a, 3)
    plan.corrupt_payload(b, 3)
    assert a == b and a != b"\0" * 64            # damage is exact-replayable
    c = bytearray(b"\0" * 64)
    plan.corrupt_payload(c, 4)                   # but keyed on read_index
    assert c != a


# ---------------------------------------------------------------------------
# retry loop
# ---------------------------------------------------------------------------

def test_transient_retry_recovers_with_exact_counter(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    plan = FaultPlan([FaultEvent(0, "transient", times=2)])
    store = FileNeuronStore(path, retry=FAST_RETRY, fault_plan=plan)
    data, stats = _read_all(store, 96)
    np.testing.assert_array_equal(data, clean)
    assert stats.retries == 2 == plan.injected["transient"]
    assert stats.corrupt_extents == 0
    # subsequent reads are clean and cost nothing extra
    data2, stats2 = _read_all(store, 96)
    np.testing.assert_array_equal(data2, clean)
    assert stats2.retries == 0


def test_retry_budget_exhausted_propagates(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    plan = FaultPlan([FaultEvent(0, "transient", times=99)])
    store = FileNeuronStore(path, retry=RetryPolicy(max_retries=2, backoff_s=0),
                            fault_plan=plan)
    with pytest.raises(TransientIOError):
        _read_all(store, 96)
    assert plan.injected["transient"] == 3       # 1 try + 2 re-reads


def test_non_retryable_oserror_propagates_immediately(tmp_path, monkeypatch):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    store = FileNeuronStore(path, retry=FAST_RETRY)
    calls = {"n": 0}

    def bad_pread(fd, n, off):
        calls["n"] += 1
        raise OSError(errno.ENOENT, "gone")

    monkeypatch.setattr(os, "pread", bad_pread)
    with pytest.raises(OSError) as ei:
        _read_all(store, 96)
    assert ei.value.errno == errno.ENOENT
    assert calls["n"] == 1                       # no retry for a missing file


def test_retry_backoff_schedule():
    p = RetryPolicy(max_retries=4, backoff_s=1e-3, backoff_mult=2.0,
                    max_backoff_s=3e-3)
    assert [p.backoff(i) for i in range(4)] == [1e-3, 2e-3, 3e-3, 3e-3]
    assert RetryPolicy(backoff_s=0).backoff(5) == 0.0


# ---------------------------------------------------------------------------
# short reads + mmap fallback (satellite d)
# ---------------------------------------------------------------------------

def test_short_read_continuation_loop_injected(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    plan = FaultPlan([FaultEvent(0, "short_read"), FaultEvent(1, "short_read")])
    store = FileNeuronStore(path, retry=FAST_RETRY, fault_plan=plan,
                            verify_checksums=True)
    data, stats = _read_all(store, 96)
    np.testing.assert_array_equal(data, clean)   # continuation re-reads the rest
    assert stats.retries == 0                    # a short read is not a failure
    assert plan.injected["short_read"] == 2


def test_short_read_chunked_pread_loop(tmp_path, monkeypatch):
    """OS-level short reads (pread returning < requested) are absorbed by the
    continuation loop without any fault plan."""
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    real_pread = os.pread
    monkeypatch.setattr(os, "pread",
                        lambda fd, n, off: real_pread(fd, min(n, 32), off))
    data, stats = _read_all(FileNeuronStore(path, verify_checksums=True), 96)
    np.testing.assert_array_equal(data, clean)
    assert stats.retries == 0 and stats.corrupt_extents == 0


def test_mmap_fallback_serves_faults_and_verification(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path, use_pread=False), 96)
    plan = FaultPlan([FaultEvent(0, "transient"), FaultEvent(1, "corrupt")])
    store = FileNeuronStore(path, use_pread=False, retry=FAST_RETRY,
                            verify_checksums=True, fault_plan=plan)
    assert store._fd is None                     # really on the mmap path
    data, s1 = _read_all(store, 96)
    _, s2 = _read_all(store, 96)
    np.testing.assert_array_equal(data, clean)
    assert s1.retries + s2.retries == 2          # transient + corrupt re-read
    assert s1.corrupt_extents + s2.corrupt_extents == 1
    assert plan.injected["corrupt"] == 1


# ---------------------------------------------------------------------------
# latency + corruption
# ---------------------------------------------------------------------------

def test_latency_spike_is_correctness_neutral(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    plan = FaultPlan([FaultEvent(0, "latency", delay_s=0.02)])
    store = FileNeuronStore(path, fault_plan=plan)
    t0 = time.perf_counter()
    data, stats = _read_all(store, 96)
    assert time.perf_counter() - t0 >= 0.02
    np.testing.assert_array_equal(data, clean)
    assert stats.retries == 0 and plan.injected["latency"] == 1


def test_corruption_detected_and_recovered(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    plan = FaultPlan([FaultEvent(0, "corrupt")], seed=5)
    store = FileNeuronStore(path, retry=FAST_RETRY, verify_checksums=True,
                            fault_plan=plan)
    data, stats = _read_all(store, 96)
    np.testing.assert_array_equal(data, clean)   # the re-read served clean bytes
    assert stats.corrupt_extents == 1 == plan.injected["corrupt"]
    assert stats.retries == 1


def test_corruption_silent_without_verification(tmp_path):
    """The motivating negative: without checksums the damaged payload is
    served as if nothing happened."""
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    plan = FaultPlan([FaultEvent(0, "corrupt")], seed=5)
    data, stats = _read_all(FileNeuronStore(path, fault_plan=plan), 96)
    assert stats.corrupt_extents == 0 and stats.retries == 0
    assert not np.array_equal(data, clean)       # silent corruption


def test_persistent_corruption_raises_corrupt_extent_error(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    plan = FaultPlan([FaultEvent(0, "corrupt", times=99)])
    store = FileNeuronStore(path, retry=RetryPolicy(max_retries=2, backoff_s=0),
                            verify_checksums=True, fault_plan=plan)
    with pytest.raises(CorruptExtentError, match="still corrupt after 2"):
        _read_all(store, 96)


def test_verify_bundles_detects_real_on_disk_damage(tmp_path):
    """Flip one byte of the bundle region ON DISK: the whole-region CRC fails
    and a verifying store refuses to serve the extent (the damage is
    persistent — every re-read sees it)."""
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    pack = NeuronPack.open(path)
    assert pack.verify_bundles(0)
    off = pack.bundles_file_offset(0)
    with open(path, "r+b") as f:
        f.seek(off + 5)
        byte = f.read(1)
        f.seek(off + 5)
        f.write(bytes([byte[0] ^ 0xFF]))
    assert not NeuronPack(path).verify_bundles(0)
    store = FileNeuronStore(path, retry=FAST_RETRY, verify_checksums=True)
    with pytest.raises(CorruptExtentError):
        store.read(np.arange(96))


# ---------------------------------------------------------------------------
# format v2 / v1 compatibility + PackFormatError (satellite b)
# ---------------------------------------------------------------------------

def test_v1_pack_still_readable_and_payload_identical(tmp_path):
    p1, p2 = tmp_path / "v1.npack", tmp_path / "v2.npack"
    _write_tiny_pack(p1, version=1, seed=3)
    _write_tiny_pack(p2, version=2, seed=3)
    a, b = NeuronPack.open(p1), NeuronPack.open(p2)
    assert (a.version, b.version) == (1, 2)
    assert a.row_crcs(0) is None and b.row_crcs(0) is not None
    assert a.verify_bundles(0)                   # trivially passes
    np.testing.assert_array_equal(a.logical_bundles(0), b.logical_bundles(0))
    d1, s1 = _read_all(FileNeuronStore(p1), 96)
    d2, s2 = _read_all(FileNeuronStore(p2), 96)
    np.testing.assert_array_equal(d1, d2)
    assert s1.n_ops == s2.n_ops and s1.bytes_read == s2.bytes_read


def test_verify_checksums_requires_v2_pack(tmp_path):
    path = tmp_path / "v1.npack"
    _write_tiny_pack(path, version=1)
    with pytest.raises(ValueError, match="needs a v2 pack"):
        FileNeuronStore(path, verify_checksums=True)


def test_quantized_v2_pack_round_trips_with_verification(tmp_path):
    path = tmp_path / "q.npack"
    _write_tiny_pack(path, quantize="int8")
    pack = NeuronPack.open(path)
    assert pack.quantized and pack.verify_bundles(0)
    plan = FaultPlan([FaultEvent(0, "corrupt")])
    store = FileNeuronStore(path, retry=FAST_RETRY, verify_checksums=True,
                            fault_plan=plan)
    clean, _ = _read_all(FileNeuronStore(path), 96)
    data, stats = _read_all(store, 96)
    np.testing.assert_array_equal(data, clean)
    assert stats.corrupt_extents == 1


def test_pack_format_errors_name_path_and_expectation(tmp_path):
    # empty file
    empty = tmp_path / "empty.npack"
    empty.write_bytes(b"")
    with pytest.raises(PackFormatError, match="too short"):
        NeuronPack.open(empty)
    # wrong magic
    garbage = tmp_path / "garbage.npack"
    garbage.write_bytes(b"GARBAGE!" + b"\0" * 64)
    with pytest.raises(PackFormatError, match="magic b'GARBAGE!'"):
        NeuronPack.open(garbage)
    # header claims more bytes than the file holds
    truncated = tmp_path / "trunc.npack"
    truncated.write_bytes(MAGIC + np.array(10 ** 6, dtype="<u8").tobytes())
    with pytest.raises(PackFormatError, match="truncated pack"):
        NeuronPack.open(truncated)
    # unreadable header JSON
    badjson = tmp_path / "badjson.npack"
    blob = b"\xff\xfe not json"
    badjson.write_bytes(MAGIC + np.array(len(blob), dtype="<u8").tobytes() + blob)
    with pytest.raises(PackFormatError, match="header JSON is unreadable"):
        NeuronPack.open(badjson)
    # future version
    futur = tmp_path / "future.npack"
    blob = b'{"version": 99}'
    futur.write_bytes(MAGIC + np.array(len(blob), dtype="<u8").tobytes() + blob)
    with pytest.raises(PackFormatError, match="unsupported NeuronPack version 99"):
        NeuronPack.open(futur)
    # valid v2 file with a corrupted header CRC
    ok = tmp_path / "ok.npack"
    _write_tiny_pack(ok)
    raw = bytearray(ok.read_bytes())
    hlen = int(np.frombuffer(bytes(raw[8:16]), dtype="<u8")[0])
    raw[16 + hlen] ^= 0xFF                       # the stored CRC's first byte
    ok.write_bytes(bytes(raw))
    with pytest.raises(PackFormatError, match="header CRC mismatch"):
        NeuronPack.open(ok)
    # valid header, data region chopped off
    chopped = tmp_path / "chopped.npack"
    _write_tiny_pack(chopped)
    full = chopped.read_bytes()
    chopped.write_bytes(full[:len(full) // 2])
    with pytest.raises(PackFormatError, match="truncated pack data"):
        NeuronPack.open(chopped)


# ---------------------------------------------------------------------------
# lifecycle (satellite a)
# ---------------------------------------------------------------------------

def test_store_close_idempotent_and_context_manager(tmp_path):
    path = tmp_path / "a.npack"
    _write_tiny_pack(path)
    store = FileNeuronStore(path)
    assert not store.closed
    store.close()
    assert store.closed
    store.close()                                # idempotent
    with pytest.raises(ValueError, match="closed"):
        store._read_extent(0, 4)
    with FileNeuronStore(path) as s2:
        d, _ = _read_all(s2, 96)
        assert d.shape[1] == 16
    assert s2.closed


def test_runtime_close_releases_every_layer_store(chaos_env):
    cfg, path = chaos_env["cfg"], chaos_env["path"]
    rt = OffloadedFFNRuntime.from_pack(cfg, path)
    stores = [e.store for e in rt.engines]
    assert all(not s.closed for s in stores)
    rt.close()
    assert all(s.closed for s in stores)
    assert rt._worker is None
    # context-manager form
    with OffloadedFFNRuntime.from_pack(cfg, path) as rt2:
        assert not rt2.engines[0].store.closed
    assert rt2.engines[0].store.closed


# ---------------------------------------------------------------------------
# FaultInjectingStore: the unrecoverable path over ANY store
# ---------------------------------------------------------------------------

def test_fault_injecting_store_surfaces_raw_faults(rng):
    data = rng.standard_normal((64, 8)).astype(np.float32)
    plan = FaultPlan([FaultEvent(0, "transient"), FaultEvent(1, "fatal"),
                      FaultEvent(2, "corrupt")], seed=2)
    store = FaultInjectingStore(NeuronStore(data, identity_placement(64)), plan)
    ids = np.arange(0, 64, 2)
    with pytest.raises(TransientIOError):        # read 0: no retry layer below
        store.read(ids)
    with pytest.raises(FatalFault):              # read 1: BaseException
        store.read(ids)
    clean = NeuronStore(data, identity_placement(64)).read(ids)[0]
    damaged, _ = store.read(ids)                 # read 2: corrupted payload
    assert not np.array_equal(damaged, clean)
    assert plan.injected == {"transient": 1, "latency": 0, "short_read": 0,
                             "corrupt": 1, "fatal": 1}
    # the DRAM-side surface delegates untouched
    np.testing.assert_array_equal(store.fetch(ids), data[ids])


# ===========================================================================
# serving-level chaos (tentpole acceptance)
# ===========================================================================

def _pack_env(tmp_path):
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=128, activation="relu")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "m.npack")
    build_pack(model, params, path, calib_tokens=128, calib_batch=4,
               calib_seqlen=32)
    return cfg, model, params, path


@pytest.fixture(scope="module")
def chaos_env(tmp_path_factory):
    """Tiny model + v2 pack + the fault-free baseline tokens, built once."""
    tmp = tmp_path_factory.mktemp("chaos")
    cfg, model, params, path = _pack_env(tmp)
    reqs = _chaos_requests()
    with OffloadedFFNRuntime.from_pack(cfg, path, verify_checksums=True) as rt:
        eng = ServingEngine(model, params, mode="offload", offload=rt)
        clean = eng.serve(reqs)
        s = rt.io_summary()
        # satellite f gate: the clean path pays ZERO fault-counter overhead
        assert (s["retries"], s["corrupt_extents"], s["degraded_steps"],
                s["worker_restarts"]) == (0, 0, 0, 0)
    return dict(cfg=cfg, model=model, params=params, path=path,
                clean_tokens=[r.tokens for r in clean])


def _chaos_requests():
    rng = np.random.default_rng(3)
    return [Request(uid=i, prompt=rng.integers(0, 128, 12).astype(np.int32),
                    max_new_tokens=8) for i in range(3)]


def _serve_runtime(env, rt, prefetch=False):
    eng = ServingEngine(env["model"], env["params"], mode="offload",
                        offload=rt, prefetch=prefetch,
                        lookahead="oracle" if prefetch else None)
    try:
        return eng.serve(_chaos_requests())
    finally:
        eng.close()


def test_recoverable_chaos_token_identical_with_exact_counters(chaos_env):
    """ACCEPTANCE: explicit recoverable schedule per layer (transient +
    latency spike + short read + a CRC-caught corrupt extent) — decode is
    token-identical to fault-free and the counters equal the plan exactly."""
    plans = [FaultPlan([FaultEvent(0, "transient"),
                        FaultEvent(1, "latency", delay_s=1e-3),
                        FaultEvent(2, "corrupt"),
                        FaultEvent(3, "short_read")], seed=11 + l)
             for l in range(2)]
    with OffloadedFFNRuntime.from_pack(
            chaos_env["cfg"], chaos_env["path"], verify_checksums=True,
            fault_plans=plans, retry=FAST_RETRY) as rt:
        results = _serve_runtime(chaos_env, rt)
        s = rt.io_summary()
    assert [r.tokens for r in results] == chaos_env["clean_tokens"]
    for p in plans:                              # every event actually bit
        assert p.injected["transient"] == p.injected["latency"] == \
            p.injected["corrupt"] == p.injected["short_read"] == 1
    assert s["retries"] == sum(p.injected["transient"] + p.injected["corrupt"]
                               for p in plans)
    assert s["corrupt_extents"] == sum(p.injected["corrupt"] for p in plans)
    assert s["degraded_steps"] == 0 and s["worker_restarts"] == 0


def test_seeded_chaos_schedule_replays_exactly(chaos_env):
    """Rate-drawn schedules: the same seed reproduces the same injected
    counts, the same counters, and the same (clean) tokens, twice."""
    def run():
        plans = seeded_layer_plans(7, 2, 80, transient_rate=0.1,
                                   latency_rate=0.05, delay_s=5e-4,
                                   short_read_rate=0.05, corrupt_rate=0.05)
        with OffloadedFFNRuntime.from_pack(
                chaos_env["cfg"], chaos_env["path"], verify_checksums=True,
                fault_plans=plans, retry=FAST_RETRY) as rt:
            results = _serve_runtime(chaos_env, rt)
            s = rt.io_summary()
        return [r.tokens for r in results], s, [dict(p.injected) for p in plans]

    tok_a, s_a, inj_a = run()
    tok_b, s_b, inj_b = run()
    assert tok_a == tok_b == chaos_env["clean_tokens"]
    assert inj_a == inj_b
    assert sum(d["transient"] + d["corrupt"] for d in inj_a) > 0
    for s, inj in ((s_a, inj_a), (s_b, inj_b)):
        assert s["retries"] == sum(d["transient"] + d["corrupt"] for d in inj)
        assert s["corrupt_extents"] == sum(d["corrupt"] for d in inj)


# ---------------------------------------------------------------------------
# prefetch-worker supervision
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_restarts_and_decode_stays_token_identical(chaos_env):
    """A FatalFault on a worker-issued read kills the thread; supervision
    restarts it and serves the lost layer synchronously — same tokens."""
    plans = [FaultPlan([FaultEvent(4, "fatal")], seed=5), FaultPlan(seed=6)]
    with OffloadedFFNRuntime.from_pack(chaos_env["cfg"], chaos_env["path"],
                                       fault_plans=plans) as rt:
        results = _serve_runtime(chaos_env, rt, prefetch=True)
        s = rt.io_summary()
    assert plans[0].injected["fatal"] == 1
    assert s["worker_restarts"] == 1
    assert s["degraded_steps"] >= 1
    assert [r.tokens for r in results] == chaos_env["clean_tokens"]


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_restart_budget_exhausted_falls_back_to_sync(chaos_env):
    """When every restarted worker dies too, the runtime disables prefetch
    after `max_worker_restarts` and finishes the run on the synchronous
    path — still token-identical."""
    with OffloadedFFNRuntime.from_pack(chaos_env["cfg"], chaos_env["path"],
                                       max_worker_restarts=1) as rt:
        orig = rt.engines[1].begin_step_masks

        def dies_on_worker(masks, fetch_payload=True):
            # only worker-issued reads hit the poisoned path; the serving
            # thread's synchronous fallback reads stay healthy
            if threading.current_thread().name.startswith("ripple-prefetch"):
                raise FatalFault("worker poisoned")
            return orig(masks, fetch_payload)

        rt.engines[1].begin_step_masks = dies_on_worker
        results = _serve_runtime(chaos_env, rt, prefetch=True)
        s = rt.io_summary()
        assert rt.worker_restarts == 1           # budget spent, then disabled
    assert s["worker_restarts"] == 1
    assert s["degraded_steps"] > 0
    assert [r.tokens for r in results] == chaos_env["clean_tokens"]


def test_per_job_failure_degrades_only_that_layer(chaos_env):
    """An ordinary Exception inside a prefetch job (not a thread death) is
    absorbed: the layer is served synchronously, the worker survives."""
    with OffloadedFFNRuntime.from_pack(chaos_env["cfg"],
                                       chaos_env["path"]) as rt:
        orig = rt.engines[1].begin_step_masks
        calls = {"n": 0}

        def flaky(masks, fetch_payload=True):
            if threading.current_thread().name.startswith("ripple-prefetch"):
                calls["n"] += 1
                if calls["n"] == 2:
                    raise RuntimeError("one bad stage")
            return orig(masks, fetch_payload)

        rt.engines[1].begin_step_masks = flaky
        results = _serve_runtime(chaos_env, rt, prefetch=True)
        s = rt.io_summary()
        assert s["worker_restarts"] == 0         # the worker never died
        assert s["degraded_steps"] >= 1
    assert [r.tokens for r in results] == chaos_env["clean_tokens"]


# ---------------------------------------------------------------------------
# per-request error isolation (tentpole, server scope)
# ---------------------------------------------------------------------------

def _server_env():
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 10).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    return model, params, reqs


def test_failing_request_is_isolated_from_its_batch():
    """ACCEPTANCE: an unrecoverable per-request fault (a raising on_token
    sink) retires ONLY that request with finish_reason="error" and the
    exception attached; co-batched requests finish with unchanged tokens."""
    model, params, reqs = _server_env()
    with InferenceServer(model, params, max_slots=3, max_len=32, seed=0) as srv:
        handles = [srv.submit(r) for r in reqs]
        while srv.has_work:
            srv.step()
        clean = {h.uid: h.result.tokens for h in handles}

    def bad_sink(uid, tok):
        if uid == 1:
            raise RuntimeError("sink exploded")

    with InferenceServer(model, params, max_slots=3, max_len=32, seed=0) as srv:
        handles = [srv.submit(r, on_token=bad_sink if r.uid == 1 else None)
                   for r in reqs]
        while srv.has_work:
            srv.step()
        res = {h.uid: h.result for h in handles}
    assert res[1].finish_reason == "error"
    assert isinstance(res[1].error, RuntimeError)
    assert "sink exploded" in str(res[1].error)
    for uid in (0, 2):                           # the rest of the batch: as-if
        assert res[uid].finish_reason != "error"
        assert res[uid].tokens == clean[uid]


def test_prefill_failure_isolated_to_one_request():
    model, params, reqs = _server_env()
    with InferenceServer(model, params, max_slots=3, max_len=32, seed=0) as srv:
        handles = [srv.submit(r) for r in reqs]
        while srv.has_work:
            srv.step()
        clean = {h.uid: h.result.tokens for h in handles}

    # inject through the admission-prefill seam (the `prefill_fn` kwarg —
    # admission runs a jitted prefill, so swapping `srv.model` post-hoc
    # would not reach it): the 2nd prefill (uid=1's admission) fails
    calls = {"n": 0}

    def flaky_prefill(p, toks, c):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("prefill OOM")
        return model.prefill(p, {"tokens": toks}, c)

    with InferenceServer(model, params, max_slots=3, max_len=32, seed=0,
                         prefill_fn=flaky_prefill) as srv:
        handles = [srv.submit(r) for r in reqs]
        while srv.has_work:
            srv.step()
        res = {h.uid: h.result for h in handles}
    assert res[1].finish_reason == "error" and res[1].tokens == []
    assert "prefill OOM" in str(res[1].error)
    for uid in (0, 2):
        assert res[uid].tokens == clean[uid]


def test_batch_scope_store_fault_retires_batch_but_server_survives():
    """A store fault with NO retry layer below it poisons the shared decode
    computation: the whole active batch is error-retired (it cannot be
    attributed to one request) — but the server keeps serving new work."""
    model, params, reqs = _server_env()
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(2))
    plan = FaultPlan([FaultEvent(0, "transient")])
    eng = rt.engines[0]
    wrapped = FaultInjectingStore(eng.store, plan)
    eng.store = wrapped
    eng.reader.store = wrapped
    with InferenceServer(model, params, max_slots=2, max_len=32,
                         mode="offload", offload=rt, seed=0) as srv:
        handles = [srv.submit(r) for r in reqs[:2]]
        while srv.has_work:
            srv.step()
        assert plan.injected["transient"] == 1
        for h in handles:
            assert h.result.finish_reason == "error"
            assert isinstance(h.result.error, TransientIOError)
            assert len(h.result.tokens) >= 1     # the prefill token survived
        # the fault was one-shot: the server admits and completes new work
        late = srv.submit(reqs[2])
        while srv.has_work:
            srv.step()
        assert late.result.finish_reason == "length"
        assert len(late.result.tokens) == 6


def test_abort_retires_queued_and_active_requests():
    model, params, reqs = _server_env()
    with InferenceServer(model, params, max_slots=2, max_len=32, seed=0) as srv:
        handles = [srv.submit(r) for r in reqs]  # 2 slots, 1 queued
        srv.step()
        n = srv.abort("interrupted (KeyboardInterrupt)")
        assert n == 3
        assert not srv.has_work
        for h in handles:
            assert h.result.finish_reason == "error"
            assert "interrupted" in str(h.result.error)
        # partial tokens are preserved on in-flight requests
        assert any(len(h.result.tokens) > 0 for h in handles)
        # still usable afterwards
        again = srv.submit(Request(uid=99, prompt=np.arange(8, dtype=np.int32),
                                   max_new_tokens=3))
        while srv.has_work:
            srv.step()
        assert again.result.finish_reason == "length"
