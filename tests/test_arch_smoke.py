"""Required per-arch smoke tests: reduced variant of each assigned architecture
runs one forward + one train step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.models import build_model
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step

from conftest import tiny_batch

ARCHS = sorted(ASSIGNED_CONFIGS)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    batch = tiny_batch(cfg, rng, B=2, S=16)
    state = init_train_state(model, jax.random.PRNGKey(0), AdamWConfig())
    out = model.forward(state.params, batch)
    logits = out["logits"]
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    step = jax.jit(make_train_step(model, AdamWConfig()))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(new_state.params)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg, rng, B=2, S=8)
    cache = model.init_cache(2, 32 + cfg.n_prefix_tokens)
    logits, cache = model.prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    off = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    logits2, cache = model.decode_step(params, tok, jnp.int32(off + 8), cache)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits2, np.float32)))
