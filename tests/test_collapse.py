"""Access collapse (§5.1): run extraction + merging properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.collapse import (AdaptiveThreshold, BottleneckDetector,
                                 collapse_extents, collapse_positions,
                                 runs_from_positions)

positions_st = st.lists(st.integers(0, 500), min_size=0, max_size=80)


@given(positions_st)
@settings(max_examples=50, deadline=None)
def test_runs_cover_exactly_the_positions(pos):
    pos = np.asarray(pos, np.int64)
    runs = runs_from_positions(pos)
    covered = sorted({p for s, l in runs for p in range(s, s + l)})
    assert covered == sorted(set(pos.tolist()))
    # maximality: runs cannot touch
    for (s1, l1), (s2, l2) in zip(runs, runs[1:]):
        assert s2 > s1 + l1  # gap of at least 1


@given(positions_st, st.integers(0, 50))
@settings(max_examples=50, deadline=None)
def test_collapse_superset_and_fewer_ops(pos, thr):
    pos = np.asarray(pos, np.int64)
    base = runs_from_positions(pos)
    merged = collapse_positions(pos, thr)
    assert len(merged) <= len(base)
    covered = {p for s, l in merged for p in range(s, s + l)}
    assert covered >= set(pos.tolist())
    # waste bound: every merge of gap g <= thr adds at most thr extra neurons
    extra = len(covered) - len(set(pos.tolist()))
    assert extra <= thr * max(len(base) - len(merged), 0)


@given(positions_st, st.integers(0, 20), st.integers(21, 60))
@settings(max_examples=30, deadline=None)
def test_collapse_monotone_in_threshold(pos, t_small, t_big):
    pos = np.asarray(pos, np.int64)
    assert len(collapse_positions(pos, t_big)) <= len(collapse_positions(pos, t_small))


def test_collapse_example_from_paper():
    """Fig. 9: n1, n2, n4 activated; n3 speculatively read -> one op."""
    pos = np.array([0, 1, 3])
    assert collapse_positions(pos, 0) == [(0, 2), (3, 1)]
    assert collapse_positions(pos, 1) == [(0, 4)]


def test_adaptive_threshold_direction():
    at = AdaptiveThreshold(initial=4)
    at.update(op_cost=1.0, byte_cost=0.1)      # IOPS-bound -> raise
    assert at.threshold > 4
    at2 = AdaptiveThreshold(initial=16)
    at2.update(op_cost=0.1, byte_cost=1.0)     # bandwidth-bound -> lower
    assert at2.threshold < 16


def test_bottleneck_detector_disables_collapse():
    det = BottleneckDetector(device_bandwidth=1e9, saturation=0.9, period=4)
    for _ in range(4):
        det.record(nbytes=0.99e9, seconds=1.0)   # ~99% utilisation
    assert not det.collapse_enabled
    for _ in range(4):
        det.record(nbytes=0.2e9, seconds=1.0)
    assert det.collapse_enabled


def test_adaptive_threshold_explicit_initial_wins_over_anchor():
    """Satellite fix: break_even used to clobber an explicit `initial`."""
    at = AdaptiveThreshold(initial=7, break_even=10.0)   # band [5, 20]
    assert (at.lo, at.hi) == (5, 20)
    assert at.threshold == 7
    # explicit values outside the band clamp instead of being discarded
    assert AdaptiveThreshold(initial=1, break_even=10.0).threshold == 5
    assert AdaptiveThreshold(initial=99, break_even=10.0).threshold == 20
    # None -> anchor at the break-even gap (the previous default behaviour)
    assert AdaptiveThreshold(break_even=10.0).threshold == 10
    assert AdaptiveThreshold().threshold == 4
