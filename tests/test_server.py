"""InferenceServer: slot-based continuous batching.

The contract under test (ISSUE 4 acceptance): a request's tokens are
invariant to batch composition — serving a request alone, inside a
mixed-prompt-length continuous batch, or admitted mid-flight produces
identical output (greedy resident AND offload under the ReLU oracle, and
temperature sampling via per-uid streams); per-uid `io_seconds` attribution
sums exactly to the engines' merged reads even as requests retire; retired
slots leave the activation-mask unions; stop tokens and submit-time
validation behave; streaming surfaces every token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import (Request, ServingEngine,
                                  build_offload_runtime)
from repro.serving.server import InferenceServer, RequestState


def _setup(seed=0, vocab=128, arch="opt-350m", **overrides):
    cfg = get_config(arch, reduced=True, d_model=64, d_ff=256, n_layers=2,
                     vocab_size=vocab, **overrides)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return cfg, model, params


def _mixed_requests(rng, vocab=128, lens=(6, 9, 12), new=(3, 5, 7)):
    return [Request(uid=i, prompt=rng.integers(0, vocab, T).astype(np.int32),
                    max_new_tokens=n)
            for i, (T, n) in enumerate(zip(lens, new))]


def _solo_tokens(model, params, req, mode="resident", runtime=None):
    """Reference: the request served entirely alone on a 1-slot server."""
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             mode=mode, offload=runtime)
    try:
        [res] = (server.submit(req), server.drain())[1]
    finally:
        server.close()
    return res.tokens


def test_mixed_length_continuous_batch_matches_solo_resident(rng):
    """Mixed prompt lengths share one continuous batch (2 slots for 3
    requests, so admission is staggered); every request's greedy tokens match
    serving it alone."""
    cfg, model, params = _setup()
    reqs = _mixed_requests(rng)
    server = InferenceServer(model, params, max_slots=2, max_len=64)
    handles = [server.submit(r) for r in reqs]
    results = server.drain()
    assert [r.uid for r in results] == [0, 1, 2]      # submission order
    for h, req in zip(handles, reqs):
        assert h.result.tokens == _solo_tokens(model, params, req)
        assert len(h.result.tokens) == req.max_new_tokens
        assert h.result.finish_reason == "length"
        assert h.state is RequestState.FINISHED


def test_mixed_length_continuous_batch_matches_solo_offload(rng):
    """Same identity through the offload path under the ReLU oracle: the
    activation-mask unions differ per batch composition, but over-coverage
    contributes zero, so tokens are exact."""
    cfg, model, params = _setup(seed=1)
    reqs = _mixed_requests(rng)
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(1))
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             mode="offload", offload=rt)
    handles = [server.submit(r) for r in reqs]
    server.drain()
    for h, req in zip(handles, reqs):
        rt_solo = build_offload_runtime(model, params,
                                        rng=np.random.default_rng(1))
        assert h.result.tokens == _solo_tokens(model, params, req,
                                               mode="offload", runtime=rt_solo)
        assert h.result.io_seconds > 0


def test_mid_flight_admission_identity(rng):
    """Requests submitted while others are decoding produce the same tokens
    as if served alone — admission order is invisible to the output."""
    cfg, model, params = _setup(seed=2)
    reqs = _mixed_requests(rng, lens=(6, 9, 12, 7), new=(3, 6, 6, 4))
    server = InferenceServer(model, params, max_slots=2, max_len=64)
    h_early = [server.submit(r) for r in reqs[:2]]
    for _ in range(2):
        server.step()
    h_late = [server.submit(r) for r in reqs[2:]]     # mid-flight
    assert all(h.state is RequestState.QUEUED for h in h_late)
    server.drain()
    for h, req in zip(h_early + h_late, reqs):
        assert h.result.tokens == _solo_tokens(model, params, req)


def test_per_uid_io_attribution_conserved_under_retirement(rng):
    """Σ per-request io_seconds == Σ engine merged read time, with requests
    retiring at different steps; retired rows leave the mask union, so the
    per-step activated count drops as the batch drains."""
    cfg, model, params = _setup(seed=3)
    reqs = _mixed_requests(rng, lens=(8, 8, 8), new=(2, 5, 9))
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(2))
    server = InferenceServer(model, params, max_slots=3, max_len=64,
                             mode="offload", offload=rt)
    for r in reqs:
        server.submit(r)
    results = server.drain()
    engine_total = sum(t.io.seconds for e in rt.engines for t in e.history)
    assert engine_total > 0
    assert abs(sum(r.io_seconds for r in results) - engine_total) < 1e-9
    # 3 active rows at the start vs 1 at the end: the union shrank
    hist = rt.engines[0].history
    assert hist[-1].n_activated < hist[0].n_activated


def test_submit_validates_prompt_plus_max_new_fits_cache(rng):
    cfg, model, params = _setup(seed=4)
    server = InferenceServer(model, params, max_slots=1, max_len=16)
    prompt = rng.integers(0, 128, 12).astype(np.int32)
    with pytest.raises(ValueError, match="max_len"):
        server.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    with pytest.raises(ValueError, match="max_new_tokens"):
        server.submit(Request(uid=1, prompt=prompt, max_new_tokens=0))
    server.submit(Request(uid=2, prompt=prompt, max_new_tokens=4))
    with pytest.raises(ValueError, match="duplicate"):
        server.submit(Request(uid=2, prompt=prompt, max_new_tokens=4))
    server.drain()


@pytest.mark.parametrize("mode", ["resident", "offload"])
def test_stop_tokens_retire_early_with_stop_reason(rng, mode):
    cfg, model, params = _setup(seed=5)
    prompt = rng.integers(0, 128, 8).astype(np.int32)
    rt = (build_offload_runtime(model, params, rng=np.random.default_rng(3))
          if mode == "offload" else None)
    ref = _solo_tokens(model, params,
                       Request(uid=0, prompt=prompt, max_new_tokens=8),
                       mode=mode, runtime=rt)
    stop = ref[2]
    rt2 = (build_offload_runtime(model, params, rng=np.random.default_rng(3))
           if mode == "offload" else None)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             mode=mode, offload=rt2)
    h = server.submit(Request(uid=0, prompt=prompt, max_new_tokens=8,
                              stop_tokens=(stop,)))
    server.drain()
    # truncated at the FIRST occurrence of the stop token, which is included
    cut = ref.index(stop) + 1
    assert h.result.tokens == ref[:cut]
    assert h.result.finish_reason == "stop"
    server.close()


def test_streaming_callback_and_iterator(rng):
    cfg, model, params = _setup(seed=6)
    reqs = _mixed_requests(rng, lens=(6, 10), new=(4, 6))
    seen = []
    server = InferenceServer(model, params, max_slots=2, max_len=64)
    h0 = server.submit(reqs[0], on_token=lambda uid, tok: seen.append((uid, tok)))
    h1 = server.submit(reqs[1])
    streamed = list(server.stream(h1))                # pumps step() itself
    assert h1.done and streamed == h1.result.tokens
    assert h0.done                                    # shared the same steps
    assert [t for u, t in seen if u == 0] == h0.result.tokens


def test_lifecycle_states_and_queueing(rng):
    cfg, model, params = _setup(seed=7)
    reqs = _mixed_requests(rng, lens=(6, 6), new=(3, 3))
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    h0, h1 = (server.submit(r) for r in reqs)
    assert h0.state is RequestState.QUEUED and h1.state is RequestState.QUEUED
    server.step()
    # one step = admission (prefill emits token 0) + one decode iteration
    assert h0.state is RequestState.DECODE and len(h0.tokens) == 2
    assert h1.state is RequestState.QUEUED            # no free slot yet
    server.drain()
    assert h0.done and h1.done
    assert server.stats.admitted == 2
    assert not server.has_work


def test_temperature_sampling_is_grouping_invariant(rng):
    """Satellite: per-uid sampling streams. A temperature>0 request draws the
    same tokens whether served alone or inside a continuous batch with other
    requests — its stream depends on (seed, uid, t) only."""
    cfg, model, params = _setup(seed=8, vocab=64)
    hot = Request(uid=7, prompt=rng.integers(0, 64, 8).astype(np.int32),
                  max_new_tokens=6, temperature=1.5)
    solo = _solo_tokens(model, params, hot)
    others = [Request(uid=i, prompt=rng.integers(0, 64, T).astype(np.int32),
                      max_new_tokens=5)
              for i, T in ((0, 6), (1, 10))]
    server = InferenceServer(model, params, max_slots=3, max_len=64)
    handles = [server.submit(r) for r in (others[0], hot, others[1])]
    server.drain()
    assert handles[1].result.tokens == solo
    # and the sampled stream actually sampled (differs from greedy)
    greedy = _solo_tokens(model, params,
                          Request(uid=7, prompt=hot.prompt, max_new_tokens=6))
    assert solo != greedy


def test_prefetch_speculation_rejects_non_relu_activations(rng):
    """Speculative lookahead over-predicts by design and the staged FFN
    evaluates the whole speculated union — only exact when act(pre<=0)==0.
    Non-ReLU models must be refused instead of silently diverging from
    serial; the oracle (depth-0) arm stays allowed for any activation."""
    cfg, model, params = _setup(seed=10, arch="granite-3-2b",
                                activation="silu")
    rt = build_offload_runtime(model, params, rng=np.random.default_rng(4),
                               train_lookahead=True)
    with pytest.raises(ValueError, match="relu"):
        InferenceServer(model, params, max_slots=1, max_len=64,
                        mode="offload", offload=rt, prefetch=True)
    server = InferenceServer(model, params, max_slots=1, max_len=64,
                             mode="offload", offload=rt, prefetch=True,
                             lookahead="oracle")
    server.close()


def test_release_finished_bounds_memory_and_frees_uids(rng):
    """A long-lived server must not grow with total requests served: retired
    handles are evicted from the in-flight map (their uid becomes reusable)
    and release_finished() drops the server-side references."""
    cfg, model, params = _setup(seed=11)
    prompt = rng.integers(0, 128, 6).astype(np.int32)
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    h1 = server.submit(Request(uid=0, prompt=prompt, max_new_tokens=2))
    server.drain()
    assert server.release_finished() == 1
    assert server.results() == []                 # server holds nothing now
    assert h1.result.tokens and h1.done           # caller's handle survives
    h2 = server.submit(Request(uid=0, prompt=prompt, max_new_tokens=2))
    server.drain()
    assert h2.result.tokens == h1.result.tokens   # same uid => same stream


def test_serve_wrapper_matches_server_and_preserves_order(rng):
    """ServingEngine.serve is submit-all + drain over InferenceServer:
    mixed-length input comes back in input order with identical tokens."""
    cfg, model, params = _setup(seed=9)
    reqs = _mixed_requests(rng, lens=(12, 6, 9), new=(4, 5, 3))
    results = ServingEngine(model, params, max_len=64).serve(reqs)
    assert [r.uid for r in results] == [0, 1, 2]
    for res, req in zip(results, reqs):
        assert res.tokens == _solo_tokens(model, params, req)
