"""Pallas kernel sweeps: shapes x dtypes vs ref.py oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("D", [128, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_ffn_shape_dtype_sweep(B, D, dtype):
    rng = np.random.default_rng(B * D)
    N, seg = 512, 128
    x = jnp.asarray(rng.standard_normal((B, D)) * 0.5, dtype)
    wu = jnp.asarray(rng.standard_normal((N, D)) * 0.1, dtype)
    wd = jnp.asarray(rng.standard_normal((N, D)) * 0.1, dtype)
    ids = jnp.asarray([1, 2, 3], jnp.int32)
    y = ops.sparse_ffn_segments(x, wu, wd, ids, seg_size=seg, activation="relu")
    yr = ref.sparse_ffn_segments_ref(x, wu, wd, np.array([1, 2, 3]),
                                     seg_size=seg, activation="relu")
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("activation,gated", [("relu", False), ("relu2", False),
                                              ("gelu", False), ("silu", True)])
def test_sparse_ffn_activations(activation, gated):
    rng = np.random.default_rng(7)
    B, D, N, seg = 4, 128, 512, 128
    x = jnp.asarray(rng.standard_normal((B, D)) * 0.5, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32) if gated else None
    ids = jnp.asarray([0, 2], jnp.int32)
    y = ops.sparse_ffn_segments(x, wu, wd, ids, wg, seg_size=seg, activation=activation)
    yr = ref.sparse_ffn_segments_ref(x, wu, wd, np.array([0, 2]), wg,
                                     seg_size=seg, activation=activation)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)


def test_sparse_ffn_padding_ids_contribute_zero():
    rng = np.random.default_rng(8)
    B, D, N, seg = 2, 128, 256, 128
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    y1 = ops.sparse_ffn_segments(x, wu, wd, jnp.asarray([1], jnp.int32), seg_size=seg)
    y2 = ops.sparse_ffn_segments(x, wu, wd, jnp.asarray([1, -1, -1, -1], jnp.int32), seg_size=seg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-6)


def test_sparse_ffn_equals_full_dense_when_all_segments():
    """All segments selected == dense FFN (the paper's exactness property)."""
    rng = np.random.default_rng(9)
    B, D, N, seg = 4, 128, 512, 128
    x = jnp.asarray(rng.standard_normal((B, D)) * 0.5, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((N, D)) * 0.1, jnp.float32)
    ids = jnp.arange(N // seg, dtype=jnp.int32)
    y = ops.sparse_ffn_segments(x, wu, wd, ids, seg_size=seg, activation="relu")
    dense = jnp.maximum(x @ wu.T, 0) @ wd
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,N", [(64, 128), (100, 300), (256, 256), (17, 50)])
def test_coact_sweep(T, N):
    rng = np.random.default_rng(T + N)
    m = (rng.random((T, N)) < 0.25)
    A = ops.coact_accumulate(jnp.asarray(m), tile_n=128, tile_t=64)
    Ar = ref.coact_accumulate_ref(jnp.asarray(m))
    np.testing.assert_array_equal(np.asarray(A), np.asarray(Ar))


def test_coact_symmetry_and_diagonal():
    rng = np.random.default_rng(11)
    m = (rng.random((40, 96)) < 0.3)
    A = np.asarray(ops.coact_accumulate(jnp.asarray(m), tile_n=32, tile_t=32))
    np.testing.assert_array_equal(A, A.T)
    np.testing.assert_array_equal(np.diag(A), m.sum(0))


@pytest.mark.parametrize("B,H,KV,hd,W", [(1, 4, 1, 64, 512), (2, 8, 2, 64, 1024),
                                         (3, 6, 6, 32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_sweep(B, H, KV, hd, W, dtype):
    rng = np.random.default_rng(B * W)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, W, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, W, KV, hd)), dtype)
    cur = W + W // 3
    pos = np.full((B, W), -1, np.int32)
    for p in range(max(0, cur - W + 1), cur + 1):
        pos[:, p % W] = p
    pos = jnp.asarray(pos)
    win = W // 2
    out = ops.swa_decode_attention(q, k, v, pos, jnp.int32(cur), window=win, block_w=128)
    outr = ref.swa_decode_ref(q.reshape(B, KV, H // KV, hd), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2), pos, cur, window=win
                              ).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(outr, np.float32),
                               **_tol(dtype))


def test_swa_decode_empty_cache_returns_zeros():
    B, H, KV, hd, W = 1, 2, 1, 32, 128
    q = jnp.ones((B, H, hd), jnp.float32)
    k = jnp.ones((B, W, KV, hd), jnp.float32)
    v = jnp.ones((B, W, KV, hd), jnp.float32)
    pos = jnp.full((B, W), -1, jnp.int32)
    out = ops.swa_decode_attention(q, k, v, pos, jnp.int32(0), window=64, block_w=64)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
