"""Contract tests: the assigned architecture configs match the assignment."""
import pytest

from repro.configs import ASSIGNED_CONFIGS, INPUT_SHAPES, get_config

EXPECTED = {
    # arch: (L, d_model, H, KV, d_ff, vocab)
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
}

MOE = {
    "granite-moe-1b-a400m": (32, 8),
    "granite-moe-3b-a800m": (40, 8),
    "jamba-1.5-large-398b": (16, 2),
}


def test_all_ten_assigned():
    assert set(ASSIGNED_CONFIGS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_dims(arch):
    cfg = get_config(arch)
    L, d, H, KV, ff, V = EXPECTED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == ff and cfg.vocab_size == V
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("arch", sorted(MOE))
def test_moe_dims(arch):
    cfg = get_config(arch)
    e, k = MOE[arch]
    assert cfg.moe.n_experts == e and cfg.moe.top_k == k


def test_family_specifics():
    assert get_config("qwen2-7b").qkv_bias                       # QKV bias
    assert get_config("granite-34b").n_kv_heads == 1             # MQA
    assert get_config("jamba-1.5-large-398b").attn_period == 8   # 1:7 interleave
    kinds = get_config("jamba-1.5-large-398b").layer_kinds()
    assert kinds.count("attn") * 7 == kinds.count("mamba")
    xl = get_config("xlstm-125m").layer_kinds()
    assert set(xl) == {"mlstm", "slstm"}
    sm = get_config("seamless-m4t-medium")
    assert sm.is_encdec and sm.n_enc_layers == 12
    vl = get_config("internvl2-26b")
    assert vl.d_frontend == 3200 and vl.n_prefix_tokens == 256


def test_input_shapes_exact():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_param_counts_in_expected_band(arch):
    """Sanity: param_count within ~2.5x of the name-plate size."""
    nameplate = {
        "internlm2-20b": 20e9, "internvl2-26b": 20e9, "granite-34b": 34e9,
        "granite-3-2b": 2.5e9, "qwen2-7b": 7.6e9, "xlstm-125m": 125e6,
        "granite-moe-1b-a400m": 1.3e9, "granite-moe-3b-a800m": 3.3e9,
        "jamba-1.5-large-398b": 398e9, "seamless-m4t-medium": 1.2e9,
    }[arch]
    n = get_config(arch).param_count()
    assert nameplate / 2.5 < n < nameplate * 2.5, (arch, n, nameplate)


def test_moe_active_fraction():
    cfg = get_config("granite-moe-1b-a400m")
    act, tot = cfg.active_param_count(), cfg.param_count()
    assert act < tot
    assert act / tot < 0.6   # 8 of 32 experts active
