"""Cross-cutting hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache import FIFOCache, LRUCache, S3FIFOCache
from repro.core.expert_placement import (expected_reads_per_token,
                                         search_expert_placement,
                                         synthetic_routing)
from repro.core.placement import identity_placement
from repro.models.kvcache import _quantize


@given(seed=st.integers(0, 200), scale=st.floats(0.01, 100.0))
@settings(max_examples=40, deadline=None)
def test_int8_quantization_error_bound(seed, scale):
    """Symmetric int8: |x - deq| <= scale_row/2 = max|row|/254 per row."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 3, 2, 8)) * scale, jnp.float32)
    q, s = _quantize(x)
    deq = q.astype(jnp.float32) * s[..., None]
    err = np.abs(np.asarray(x) - np.asarray(deq))
    bound = np.asarray(s)[..., None] / 2 + 1e-7
    assert np.all(err <= bound)
    assert np.asarray(q).dtype == np.int8
    assert np.all(np.abs(np.asarray(q)) <= 127)


@given(capacity=st.integers(1, 64), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_caches_never_exceed_capacity(capacity, seed):
    rng = np.random.default_rng(seed)
    caches = [S3FIFOCache(capacity), LRUCache(capacity), FIFOCache(capacity)]
    for _ in range(300):
        key = int(rng.integers(0, 100))
        for c in caches:
            if not c.access(key):
                c.insert(key)
            assert len(c) <= capacity
    for c in caches:
        stats = c.stats
        assert stats.hits + stats.misses == 300


@given(n_experts=st.sampled_from([8, 16, 32]), top_k=st.integers(1, 4),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_expert_placement_never_hurts_vs_worst_case(n_experts, top_k, seed):
    sel = synthetic_routing(200, n_experts, top_k, seed=seed)
    pl = search_expert_placement(sel, n_experts)
    assert sorted(pl.placement.tolist()) == list(range(n_experts))
    # reads bounded by top_k (each expert its own read at worst)
    reads = expected_reads_per_token(sel, n_experts, pl)
    assert 1.0 - 1e-9 <= reads <= top_k + 1e-9
    # the search never does worse than identity on its own calibration trace
    r_ident = expected_reads_per_token(sel, n_experts, identity_placement(n_experts))
    assert reads <= r_ident + 0.5


@given(seed=st.integers(0, 100), thr=st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_engine_bytes_accounting_consistent(seed, thr):
    """read bytes >= useful bytes; ops >= 1; collapse superset invariant."""
    from repro.core import EngineConfig, OffloadEngine
    rng = np.random.default_rng(seed)
    bundles = np.zeros((128, 16), np.float32)
    eng = OffloadEngine(bundles, config=EngineConfig(
        cache_ratio=0.0, initial_collapse_threshold=thr))
    for _ in range(5):
        ids = rng.choice(128, size=rng.integers(1, 40), replace=False)
        _, ts = eng.step(ids)
        assert ts.io.bytes_read >= ts.io.bytes_useful > 0
        assert ts.io.n_ops >= 1
        assert ts.n_hits + ts.n_misses == ts.n_activated
