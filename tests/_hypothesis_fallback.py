"""Vendored minimal stand-in for `hypothesis` (dev-dependency fallback).

The container this repo is tested in may not have hypothesis installed and
cannot pip-install it. conftest.py registers this module as `hypothesis` in
sys.modules when the real package is missing, so the property-test modules
import unchanged. Only the tiny API surface those tests use is provided:

    @given(*strategies, **kw_strategies)
    @settings(max_examples=N, deadline=None)
    st.integers / st.floats / st.booleans / st.sampled_from / st.lists

Examples are drawn from a deterministic per-test PRNG (seeded by the test
name), so runs are reproducible. This is NOT a shrinking property-based
framework — just enough randomized coverage to keep the invariant tests
meaningful. Install the real `hypothesis` (requirements-dev.txt) for full
shrinking and edge-case generation.
"""
from __future__ import annotations

import types
import zlib

import numpy as np

_MAX_EXAMPLES_CAP = 25   # keep CPU runtime bounded without real shrinking


class SearchStrategy:
    """A strategy is just a sampler: sample(rng) -> value."""

    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value, **_kw):
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def lists(elements, min_size=0, max_size=10):
    def sample(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(size)]
    return SearchStrategy(sample)


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # No functools.wraps: copying __wrapped__ would make pytest introspect
        # the original signature and demand the drawn parameters as fixtures.
        def wrapper():
            n = min(getattr(fn, "_fallback_max_examples", 20), _MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn_args = [s.sample(rng) for s in arg_strategies]
                drawn_kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*drawn_args, **drawn_kw)
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco


def install_as_hypothesis(sys_modules) -> None:
    """Register this module (and a `strategies` submodule) as `hypothesis`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy
    mod.strategies = st_mod
    mod.__fallback__ = True
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = st_mod
