"""Asynchronous layer-ahead prefetch pipeline: split-phase engine steps,
worker/staging-ring serving, top-up correctness, measured overlap.

The contract under test (ISSUE 3 acceptance): pipelined offload decode is
token-identical to serial decode under the oracle mask with equal aggregate
IOStats; lookahead mis-predictions are served by a synchronous top-up read
(never skipped); per-request I/O attribution sums exactly to the merged read
time; and the worker shuts down cleanly even when a layer raises mid-decode.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (OffloadEngine, SyntheticTraceConfig,
                        search_placement, stats_from_masks, synthetic_masks)
from repro.core.pipeline import IOScheduler
from repro.core.sparse_ffn import FFNWeights, dense_ffn, make_bundles
from repro.core.placement import identity_placement
from repro.models import build_model
from repro.configs import get_config
from repro.serving.engine import (OffloadedFFNRuntime, Request, ServingEngine,
                                  build_offload_runtime)


def _trace_setup(n=512, seed=0):
    cfg = SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed,
                               structure_seed=seed)
    calib = synthetic_masks(cfg, 200)
    serve = synthetic_masks(
        SyntheticTraceConfig(n_neurons=n, n_clusters=16, seed=seed + 99,
                             structure_seed=seed), 60)
    placement = search_placement(stats_from_masks(calib).distance_matrix(),
                                 mode="exact")
    bundles = np.random.default_rng(seed).standard_normal((n, 64)).astype(np.float32)
    return serve, placement, bundles


def _batches(serve, batch=3, offset=7):
    return [serve[[(t + r * offset) % len(serve) for r in range(batch)]]
            for t in range(len(serve))]


# ---------------------------------------------------------------------------
# Split-phase engine steps
# ---------------------------------------------------------------------------

def test_begin_complete_identical_to_fused_step_masks():
    """begin_step_masks + complete_step must be provably stats-identical to
    step_masks: same merged stats, same attribution, same cache decisions."""
    serve, placement, bundles = _trace_setup(seed=1)
    fused = OffloadEngine(bundles, placement=placement)
    split = OffloadEngine(bundles, placement=placement)
    for b in _batches(serve):
        r1 = fused.step_masks(b, fetch_payload=True)
        pending = split.begin_step_masks(b, fetch_payload=True)
        r2 = split.complete_step(pending)
        np.testing.assert_array_equal(r1.ids, r2.ids)
        np.testing.assert_array_equal(r1.data, r2.data)
        assert r1.merged.n_activated == r2.merged.n_activated
        assert r1.merged.n_hits == r2.merged.n_hits
        assert r1.merged.io.n_ops == r2.merged.io.n_ops
        assert r1.merged.io.bytes_read == r2.merged.io.bytes_read
        assert r1.merged.io.seconds == r2.merged.io.seconds
        np.testing.assert_array_equal(r1.req_n_misses, r2.req_n_misses)
        np.testing.assert_array_equal(r1.req_io_seconds, r2.req_io_seconds)
        assert r2.topup_ids.size == 0
        assert fused.cache.cache.queues() == split.cache.cache.queues()
    s1, s2 = fused.summary(), split.summary()
    assert s1 == s2


def test_complete_step_with_true_masks_equal_to_speculation_is_fused():
    """Oracle lookahead (speculation == truth) reduces exactly to the fused
    step even when true_masks is passed explicitly."""
    serve, placement, bundles = _trace_setup(seed=2)
    fused = OffloadEngine(bundles, placement=placement)
    split = OffloadEngine(bundles, placement=placement)
    for b in _batches(serve)[:20]:
        r1 = fused.step_masks(b, fetch_payload=False)
        r2 = split.complete_step(split.begin_step_masks(b, fetch_payload=False),
                                 true_masks=b)
        assert r1.merged.io.seconds == r2.merged.io.seconds
        assert r1.merged.n_hits == r2.merged.n_hits
        np.testing.assert_array_equal(r1.req_io_seconds, r2.req_io_seconds)
        assert fused.cache.cache.queues() == split.cache.cache.queues()


def test_topup_read_never_skipped_and_covers_true_union():
    """Under-prediction: every truly activated neuron missing from the
    speculation is served by the synchronous top-up read."""
    serve, placement, bundles = _trace_setup(seed=3)
    eng = OffloadEngine(bundles, placement=placement)
    rng = np.random.default_rng(3)
    for b in _batches(serve)[:20]:
        spec = b & (rng.random(b.shape) < 0.7)      # drop ~30% of true neurons
        pending = eng.begin_step_masks(spec, fetch_payload=False)
        res = eng.complete_step(pending, true_masks=b)
        true_union = np.flatnonzero(b.any(axis=0))
        # served ids cover the true union — mis-predictions were fetched
        assert np.all(np.isin(true_union, res.ids))
        expected_topup = np.setdiff1d(true_union, pending.union)
        np.testing.assert_array_equal(res.topup_ids, expected_topup)
        # attribution conserves the merged read time (spec read + top-up)
        assert abs(res.req_io_seconds.sum() - res.merged.io.seconds) < 1e-12


def test_complete_step_payload_covers_topups_in_ids_order():
    """With fetch_payload=True, complete_step's data must match the widened
    served union ([len(ids), w] in ids order) even after top-up reads."""
    serve, placement, bundles = _trace_setup(seed=8)
    eng = OffloadEngine(bundles, placement=placement)
    rng = np.random.default_rng(8)
    b = _batches(serve)[0]
    spec = b & (rng.random(b.shape) < 0.6)           # heavy under-prediction
    res = eng.complete_step(eng.begin_step_masks(spec, fetch_payload=True),
                            true_masks=b)
    assert res.topup_ids.size > 0
    assert res.data.shape[0] == res.ids.size
    np.testing.assert_array_equal(res.data, eng.store.fetch(res.ids))


def test_over_speculation_attribution_still_sums_to_merged_read():
    """Pure over-prediction (speculated neurons nobody wanted): the read time
    is still attributed in full, split evenly across requests."""
    _, placement, bundles = _trace_setup(seed=4)
    eng = OffloadEngine(bundles, placement=placement)
    n = len(bundles)
    spec = np.zeros((2, n), dtype=bool)
    spec[:, :40] = True                              # speculated...
    true = np.zeros((2, n), dtype=bool)              # ...but nothing activated
    res = eng.complete_step(eng.begin_step_masks(spec, fetch_payload=False),
                            true_masks=true)
    assert res.merged.io.seconds > 0
    assert abs(res.req_io_seconds.sum() - res.merged.io.seconds) < 1e-12
    assert res.req_n_misses.sum() == 0


def test_mixed_speculation_ffn_output_still_exact(rng):
    """Runtime-level: with both under- and over-prediction, the pipelined FFN
    (staged prefetch + top-up append) matches the dense FFN under ReLU."""
    d, n = 32, 256
    cfg = get_config("granite-3-2b", reduced=True, d_model=d, activation="relu")
    w = FFNWeights(
        w_up=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32),
        w_down=jnp.asarray(rng.standard_normal((n, d)) * 0.2, jnp.float32))
    runtime = OffloadedFFNRuntime(cfg, [np.asarray(make_bundles(w))],
                                  [identity_placement(n)])
    h = rng.standard_normal((3, d)).astype(np.float32)
    true = np.asarray(h @ np.asarray(w.w_up).T > 0)
    spec = true.copy()
    spec[:, ::3] = ~spec[:, ::3]                     # corrupt a third of it
    runtime.start_prefetch()
    try:
        runtime.begin_layer(0, spec)
        y, res, meas = runtime.complete_layer(0, jnp.asarray(h), true)
    finally:
        runtime.stop_prefetch()
    ref = np.asarray(dense_ffn(jnp.asarray(h), w, activation="relu"))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
    assert res.topup_ids.size > 0                    # under-predictions existed
    assert meas.io_host_seconds > 0


# ---------------------------------------------------------------------------
# End-to-end pipelined serving
# ---------------------------------------------------------------------------

def _offload_setup(seed=0):
    cfg = get_config("opt-350m", reduced=True, d_model=64, d_ff=256,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    return model, params, reqs


def test_pipelined_decode_token_identical_and_stats_equal_to_serial():
    """Acceptance: under the oracle mask, prefetch=True produces the serial
    path's tokens exactly AND equal aggregate IOStats (n_ops, bytes, hit
    rate) per layer engine, with measured overlapped_seconds > 0."""
    model, params, reqs = _offload_setup()
    rt_serial = build_offload_runtime(model, params, rng=np.random.default_rng(1))
    serial = ServingEngine(model, params, max_len=32, mode="offload",
                           offload=rt_serial, scheduler=IOScheduler(overlap=True))
    res_serial = serial.serve(reqs)

    rt_pipe = build_offload_runtime(model, params, rng=np.random.default_rng(1))
    pipe = ServingEngine(model, params, max_len=32, mode="offload",
                         offload=rt_pipe, scheduler=IOScheduler(overlap=True),
                         prefetch=True, lookahead="oracle")
    res_pipe = pipe.serve(reqs)

    for a, b in zip(res_serial, res_pipe):
        assert a.uid == b.uid
        assert a.tokens == b.tokens
        assert b.overlapped_seconds > 0          # measured wall clock
        assert abs(a.io_seconds - b.io_seconds) < 1e-12
    for es, ep in zip(rt_serial.engines, rt_pipe.engines):
        ss, sp = es.summary(), ep.summary()
        assert ss["tokens"] == sp["tokens"]
        assert ss["io_seconds_per_token"] == sp["io_seconds_per_token"]
        assert ss["ops_per_token"] == sp["ops_per_token"]
        assert ss["cache_hit_rate"] == sp["cache_hit_rate"]
        assert sum(t.io.bytes_read for t in es.history) == \
            sum(t.io.bytes_read for t in ep.history)
    s = pipe.scheduler.summary()
    assert s["measured_wall_seconds_per_token"] > 0
    assert s["measured_io_busy_seconds_per_token"] > 0
    # worker cleanly shut down after serve
    assert rt_pipe._worker is None


def test_trained_lookahead_pipelined_decode_matches_serial_tokens():
    """Real speculation depth: cross-layer lookahead predictors drive the
    prefetch; mis-predictions are topped up, so tokens still match serial."""
    model, params, reqs = _offload_setup(seed=5)
    rt_serial = build_offload_runtime(model, params, rng=np.random.default_rng(2))
    res_serial = ServingEngine(model, params, max_len=32, mode="offload",
                               offload=rt_serial).serve(reqs)
    rt_pipe = build_offload_runtime(model, params, rng=np.random.default_rng(2),
                                    train_lookahead=True)
    assert rt_pipe.lookahead is not None and len(rt_pipe.lookahead) == 1
    pipe = ServingEngine(model, params, max_len=32, mode="offload",
                         offload=rt_pipe, prefetch=True)
    res_pipe = pipe.serve(reqs)
    for a, b in zip(res_serial, res_pipe):
        assert a.tokens == b.tokens
    s = pipe.scheduler.summary()
    assert s["measured_wall_seconds_per_token"] > 0
    assert s["measured_hidden_seconds_per_token"] >= 0


def test_worker_exception_mid_decode_degrades_and_shuts_down_cleanly():
    """A layer engine failing inside the worker no longer aborts the run:
    the failed prefetch job is absorbed (its layer served synchronously,
    `degraded_steps` counting each fallback), tokens match the clean serial
    path, and serve() still joins the worker (no leaked threads, runtime
    reusable afterwards)."""
    model, params, reqs = _offload_setup(seed=7)
    rt_serial = build_offload_runtime(model, params, rng=np.random.default_rng(3))
    res_serial = ServingEngine(model, params, max_len=32, mode="offload",
                               offload=rt_serial).serve(reqs)

    runtime = build_offload_runtime(model, params, rng=np.random.default_rng(3))
    boom = RuntimeError("flash gave up mid-decode")
    calls = {"n": 0}
    orig = runtime.engines[1].begin_step_masks

    def failing(masks, fetch_payload=True):
        # fail the WORKER's 3rd+ begin only: the serving thread's synchronous
        # fallback (which also routes through begin_step_masks) stays healthy
        if threading.current_thread().name.startswith("ripple-prefetch"):
            calls["n"] += 1
            if calls["n"] >= 3:
                raise boom
        return orig(masks, fetch_payload)

    runtime.engines[1].begin_step_masks = failing
    engine = ServingEngine(model, params, max_len=32, mode="offload",
                           offload=runtime, prefetch=True, lookahead="oracle")
    before = threading.active_count()
    results = engine.serve(reqs)                    # absorbed, not raised
    for a, b in zip(res_serial, results):
        assert a.tokens == b.tokens
    assert runtime._worker is None                  # stop_prefetch ran
    assert threading.active_count() == before       # worker joined
    assert runtime.degraded_steps > 0               # sync fallback engaged
    assert runtime.worker_restarts == 0             # the worker never died
    # runtime is reusable: restore the engine and serve again, fault-free
    runtime.engines[1].begin_step_masks = orig
    runtime.reset_stats()
    results = engine.serve(reqs)
    assert all(len(r.tokens) == 4 for r in results)
    assert runtime.degraded_steps == 0
