"""Sequence-pipelined mLSTM (§Perf C4): exactness vs the sequential scan.
Runs in a subprocess with 8 forced host devices."""
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"   # libtpu may be installed: never probe TPU
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import ssm
from repro.distributed.seq_pipeline import pipelined_mlstm_forward
from repro.distributed.sharding import make_mesh as compat_make_mesh

mesh = compat_make_mesh((2, 4), ("data", "model"))
cfg = get_config("xlstm-125m", reduced=True, d_model=64, n_heads=2, n_kv_heads=2)
p = ssm.init_mlstm(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 64, 64)) * 0.5, jnp.float32)
ref = ssm.mlstm_forward(p, x, cfg)
with mesh:
    xd = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
    out = jax.jit(lambda p, x: pipelined_mlstm_forward(p, x, cfg, mesh))(p, xd)
err = float(jnp.max(jnp.abs(ref - jax.device_get(out))))
assert err < 1e-4, err
print("SEQ_PIPELINE_MATCH")
"""


def test_pipelined_mlstm_matches_sequential():
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SEQ_PIPELINE_MATCH" in res.stdout, res.stdout + res.stderr
