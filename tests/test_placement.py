"""Offline placement search (Algorithm 1): unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coactivation import expected_io_ops, stats_from_masks
from repro.core.placement import (frequency_placement, identity_placement,
                                  path_length, search_placement)
from repro.core.trace import SyntheticTraceConfig, synthetic_masks


def _random_dist(rng, n):
    d = rng.random((n, n)).astype(np.float64)
    d = (d + d.T) / 2
    np.fill_diagonal(d, np.inf)
    return d


@given(n=st.integers(1, 40), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_placement_is_permutation(n, seed):
    rng = np.random.default_rng(seed)
    res = search_placement(_random_dist(rng, n), mode="exact")
    assert sorted(res.placement.tolist()) == list(range(n))
    # inverse really is the inverse
    assert np.array_equal(res.placement[res.inverse], np.arange(n))


@given(n=st.integers(4, 30), seed=st.integers(0, 50), k=st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_topk_mode_is_permutation(n, seed, k):
    rng = np.random.default_rng(seed)
    res = search_placement(_random_dist(rng, n), mode="topk", topk=k)
    assert sorted(res.placement.tolist()) == list(range(n))


def test_greedy_recovers_planted_clusters():
    """Neurons from the same planted cluster should end up adjacent."""
    cfg = SyntheticTraceConfig(n_neurons=256, n_clusters=8, noise_p=0.0,
                               member_p=1.0, clusters_per_token=1, seed=3)
    masks = synthetic_masks(cfg, 400)
    stats = stats_from_masks(masks)
    res = search_placement(stats.distance_matrix(), mode="exact")
    io_ident = expected_io_ops([masks], identity_placement(256).placement)
    io_ripple = expected_io_ops([masks], res.placement)
    # perfect clusters, no noise: each token needs exactly 1 run after placement
    assert io_ripple <= 1.5
    assert io_ident > 10 * io_ripple


def test_path_length_not_worse_than_identity():
    rng = np.random.default_rng(5)
    cfg = SyntheticTraceConfig(n_neurons=128, n_clusters=8, seed=5)
    masks = synthetic_masks(cfg, 300)
    dist = stats_from_masks(masks).distance_matrix()
    dist_f = np.where(np.isinf(dist), 1.0, dist)
    res = search_placement(dist, mode="exact")
    assert path_length(dist_f, res.placement) <= path_length(
        dist_f, identity_placement(128).placement) + 1e-9


def test_edges_used_forms_single_path():
    rng = np.random.default_rng(7)
    res = search_placement(_random_dist(rng, 50), mode="exact")
    assert res.edges_used == 49


def test_frequency_placement_sorted():
    rates = np.array([0.1, 0.9, 0.5, 0.7])
    res = frequency_placement(rates)
    assert res.placement.tolist() == [1, 3, 2, 0]


def test_degenerate_sizes():
    for n in (0, 1, 2):
        d = np.ones((n, n))
        np.fill_diagonal(d, np.inf)
        res = search_placement(d, mode="exact")
        assert len(res.placement) == n


def test_batched_greedy_bit_identical_small_random():
    """The array-native merge loop must reproduce the reference per-edge loop
    bit for bit, exact and topk, across random distance matrices."""
    for n in (2, 5, 17, 64, 200):
        for seed in range(3):
            rng = np.random.default_rng(seed)
            d = _random_dist(rng, n)
            for mode, kw in (("exact", {}), ("topk", {"topk": 6})):
                a = search_placement(d, mode=mode, greedy_impl="batched", **kw)
                b = search_placement(d, mode=mode, greedy_impl="loop", **kw)
                assert np.array_equal(a.placement, b.placement), (n, seed, mode)
                assert a.edges_used == b.edges_used


def test_batched_greedy_bit_identical_at_4k():
    """Satellite acceptance: equivalence at n≈4k on a clustered trace (the
    workload shape the offline stage actually faces) — placement arrays equal
    element for element, both exact (n=4096 auto) and topk candidates."""
    cfg = SyntheticTraceConfig(n_neurons=4096, n_clusters=64, seed=11)
    masks = synthetic_masks(cfg, 120)
    dist = stats_from_masks(masks).distance_matrix()
    a = search_placement(dist, mode="exact", greedy_impl="batched")
    b = search_placement(dist, mode="exact", greedy_impl="loop")
    assert np.array_equal(a.placement, b.placement)
    assert np.array_equal(a.inverse, b.inverse)
    assert a.edges_used == b.edges_used
    at = search_placement(dist, mode="topk", topk=48, greedy_impl="batched")
    bt = search_placement(dist, mode="topk", topk=48, greedy_impl="loop")
    assert np.array_equal(at.placement, bt.placement)


def test_topk_matches_exact_on_clustered_data():
    """With strong cluster structure the topk restriction changes nothing."""
    cfg = SyntheticTraceConfig(n_neurons=128, n_clusters=16, noise_p=0.0, seed=11)
    masks = synthetic_masks(cfg, 500)
    dist = stats_from_masks(masks).distance_matrix()
    exact = search_placement(dist, mode="exact")
    topk = search_placement(dist, mode="topk", topk=32)
    io_e = expected_io_ops([masks], exact.placement)
    io_t = expected_io_ops([masks], topk.placement)
    assert io_t <= io_e * 1.25
