"""Roofline analytics: term construction, dominance, and shape logic."""
import pytest

from benchmarks.roofline import CHIPS, HBM_BW, PEAK_FLOPS, analytic_terms, _advice
from repro.configs import INPUT_SHAPES, get_config


def test_terms_positive_and_consistent():
    cfg = get_config("qwen2-7b")
    for name, shape in INPUT_SHAPES.items():
        t = analytic_terms(cfg, shape, swa=(name == "long_500k"))
        assert t["compute_s"] > 0 and t["memory_s"] > 0 and t["collective_s"] > 0
        assert t["compute_s"] == pytest.approx(
            t["hlo_flops_est"] / (CHIPS * PEAK_FLOPS))
        assert 0 < t["useful_ratio"] <= 1.0


def test_swa_reduces_decode_terms():
    cfg = get_config("internlm2-20b")
    shape = INPUT_SHAPES["long_500k"]
    full = analytic_terms(cfg, shape, swa=False)
    swa = analytic_terms(cfg, shape, swa=True)
    assert swa["memory_s"] < full["memory_s"]
    assert swa["compute_s"] < full["compute_s"]


def test_moe_capacity_waste_in_useful_ratio():
    moe = get_config("granite-moe-1b-a400m")
    dense = get_config("granite-3-2b")
    shape = INPUT_SHAPES["train_4k"]
    assert analytic_terms(moe, shape, False)["useful_ratio"] < \
        analytic_terms(dense, shape, False)["useful_ratio"] + 1e-9


def test_train_flops_scale_with_tokens():
    cfg = get_config("granite-3-2b")
    t_train = analytic_terms(cfg, INPUT_SHAPES["train_4k"], False)
    t_decode = analytic_terms(cfg, INPUT_SHAPES["decode_32k"], False)
    # train processes ~1M tokens with backward; decode processes 128
    assert t_train["model_flops"] > 1000 * t_decode["model_flops"]


def test_advice_strings_cover_all_dominants():
    cfg = get_config("granite-3-2b")
    for dom in ("memory", "collective", "compute"):
        s = _advice(dom, cfg, INPUT_SHAPES["train_4k"])
        assert isinstance(s, str) and len(s) > 10
