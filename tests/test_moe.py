"""MoE dispatch: capacity routing vs dense-einsum oracle, load balance, drops."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models.moe import (_capacity, init_moe, moe_forward,
                              moe_forward_dense_einsum)


def _cfg(n_experts=4, top_k=2, cf=8.0, d=64, dff=32):
    base = get_config("granite-moe-1b-a400m", reduced=True, d_model=d)
    return dataclasses.replace(base, moe=MoEConfig(
        n_experts=n_experts, top_k=top_k, d_ff_expert=dff, capacity_factor=cf))


def test_dispatch_matches_dense_oracle_when_dropless():
    cfg = _cfg(cf=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)), jnp.float32)
    y1, a1 = moe_forward(p, x, cfg)
    y2, a2 = moe_forward_dense_einsum(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux ~= 1 (Switch normalisation)."""
    cfg = _cfg(n_experts=4, top_k=1)
    p = init_moe(jax.random.PRNGKey(1), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform logits
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 32, 64)), jnp.float32)
    _, aux = moe_forward(p, x, cfg)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_capacity_drops_reduce_output_norm():
    """With tiny capacity, overflow tokens are dropped -> smaller output."""
    cfg_small = _cfg(cf=0.25)
    cfg_big = _cfg(cf=8.0)
    p = init_moe(jax.random.PRNGKey(2), cfg_big)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 64, 64)), jnp.float32)
    y_small, _ = moe_forward(p, x, cfg_small)
    y_big, _ = moe_forward(p, x, cfg_big)
    n_small = float(jnp.linalg.norm(y_small))
    n_big = float(jnp.linalg.norm(y_big))
    assert n_small < n_big


def test_capacity_formula():
    m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=1.25)
    C = _capacity(128, m)
    assert C >= 128 * 2 * 1.25 / 8
    assert C % 4 == 0


def test_moe_grads_flow_to_experts_and_router():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(3), cfg)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 16, 64)), jnp.float32)

    def loss(p):
        y, aux = moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0, name
