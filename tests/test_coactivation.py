"""Co-activation statistics (Eq. 1-3)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.coactivation import CoActivationStats, expected_io_ops, stats_from_masks


def test_counts_match_bruteforce():
    rng = np.random.default_rng(0)
    masks = rng.random((50, 20)) < 0.3
    s = stats_from_masks(masks)
    ref_counts = masks.sum(0)
    ref_pairs = masks.astype(np.float32).T @ masks.astype(np.float32)
    np.testing.assert_array_equal(s.counts, ref_counts)
    np.testing.assert_allclose(s.pair_counts, ref_pairs, rtol=1e-6)


def test_probabilities_normalised():
    rng = np.random.default_rng(1)
    s = stats_from_masks(rng.random((40, 16)) < 0.4)
    assert abs(s.p_single().sum() - 1.0) < 1e-9
    assert abs(s.p_pair().sum() - 1.0) < 1e-6


def test_distance_definition():
    rng = np.random.default_rng(2)
    s = stats_from_masks(rng.random((30, 8)) < 0.5)
    d = s.distance_matrix()
    p = s.p_pair()
    off = ~np.eye(8, dtype=bool)
    np.testing.assert_allclose(d[off], 1.0 - p[off], rtol=1e-6)
    assert np.all(np.isinf(np.diag(d)))


def test_merge_equals_single_pass():
    rng = np.random.default_rng(3)
    m1 = rng.random((20, 12)) < 0.3
    m2 = rng.random((25, 12)) < 0.3
    merged = stats_from_masks(m1).merge(stats_from_masks(m2))
    direct = stats_from_masks(np.concatenate([m1, m2]))
    np.testing.assert_array_equal(merged.counts, direct.counts)
    np.testing.assert_allclose(merged.pair_counts, direct.pair_counts, rtol=1e-6)
    assert merged.n_tokens == direct.n_tokens


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_expected_io_ops_invariant_under_identity(seed):
    rng = np.random.default_rng(seed)
    masks = rng.random((10, 30)) < 0.3
    ident = np.arange(30)
    runs = expected_io_ops([masks], ident)
    # each token's run count is between 1 and its activation count
    per_tok = masks.sum(1)
    active = per_tok[per_tok > 0]
    if len(active):
        assert runs <= active.mean() + 1e-9
        assert runs >= 1.0 - 1e-9


@given(seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_expected_io_ops_permutation_of_full_mask_is_one(seed):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(16)
    masks = np.ones((3, 16), bool)
    assert expected_io_ops([masks], perm) == 1.0
