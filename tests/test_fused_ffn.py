"""Fused flash-to-FFN hot path (ISSUE 6).

The contract under test: the fused segment kernel (int8 tiles + per-neuron
scale tiles, dequant + masking applied to the weight rows in-kernel) is
equivalent to `dequantize_int8` + `sparse_ffn_from_bundles` on randomized
permuted layouts for all four activations, including covered-but-not-
activated masking; `ffn_kernel="auto"` promotes segments exactly on
physical-placement-ordered layouts and serves tokens identical to the
bundles path (serial AND prefetch, in-memory AND file-backed pack); and the
dtype-faithful staging path never dequantizes int8 rows on the host.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig
from repro.core.placement import PlacementResult, identity_placement
from repro.core.sparse_ffn import sparse_ffn_from_bundles
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serving.engine import OffloadedFFNRuntime, Request, ServingEngine
from repro.store import build_pack, dequantize_int8, quantize_int8
from repro.store.packer import extract_dense_ffn_bundles

SEG = 128


def _perm_placement(rng, n):
    perm = rng.permutation(n).astype(np.int64)
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    return PlacementResult(placement=perm, inverse=inv, edges_used=0,
                           search_seconds=0.0, mode="test-perm")


def _fused_inputs(rng, n, d, n_mats, ids, *, quantize):
    """Random bundles -> (kernel args, dequantized-f32 rows for the oracle).

    The weight tiles handed to the kernel are the RAW physical rows (int8
    when quantized); the scale tiles carry dequant-scale x union-membership.
    """
    bundles = (rng.standard_normal((n, n_mats * d)).astype(np.float32) * 0.1)
    if quantize:
        q, scales = quantize_int8(bundles)
        raw, deq = q, dequantize_int8(q, scales)
    else:
        raw, deq = bundles, bundles
        scales = np.ones(n, np.float32)
    parts = raw.reshape(n, n_mats, d)
    if n_mats == 3:
        wu, wd, wg = parts[:, 1], parts[:, 2], parts[:, 0]
    else:
        wu, wd, wg = parts[:, 0], parts[:, 1], None
    seg_u = np.unique(ids // SEG)
    padded = -(-seg_u.size // 8) * 8
    seg_ids = np.full(padded, -1, np.int32)
    seg_ids[:seg_u.size] = seg_u
    tiles = np.zeros((padded, SEG), np.float32)
    tiles[np.searchsorted(seg_u, ids // SEG), ids % SEG] = scales[ids]
    args = (jnp.asarray(wu), jnp.asarray(wd), jnp.asarray(seg_ids),
            jnp.asarray(tiles), None if wg is None else jnp.asarray(wg))
    return args, deq


@pytest.mark.parametrize("activation,gated", [("relu", False), ("relu2", False),
                                              ("gelu", False), ("silu", True)])
@pytest.mark.parametrize("interpret", [True, None])
@pytest.mark.parametrize("quantize", [False, True])
def test_fused_kernel_matches_dequant_plus_bundles(rng, activation, gated,
                                                   interpret, quantize):
    """Fused int8 kernel == dequantize_int8 + sparse_ffn_from_bundles over
    the exact activated set, on a sparse random set (so segments over-cover
    and the in-kernel masking is exercised). interpret=True runs the Pallas
    interpreter; interpret=None the fused-XLA serving twin."""
    n, d, B = 512, 128, 3
    n_mats = 3 if gated else 2
    x = jnp.asarray(rng.standard_normal((B, d)).astype(np.float32) * 0.5)
    ids = np.sort(rng.choice(n, size=60, replace=False))
    (wu, wd, seg_ids, tiles, wg), deq = _fused_inputs(
        rng, n, d, n_mats, ids, quantize=quantize)
    y = ops.sparse_ffn_segments_fused(x, wu, wd, seg_ids, tiles, wg,
                                      seg_size=SEG, activation=activation,
                                      interpret=interpret)
    y_ref = sparse_ffn_from_bundles(x, jnp.asarray(deq[ids]), d, n_mats,
                                    activation=activation)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    y_py = ref.sparse_ffn_segments_fused_ref(x, wu, wd, np.asarray(seg_ids),
                                             tiles, wg, seg_size=SEG,
                                             activation=activation)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_py),
                               rtol=2e-4, atol=2e-4)


def test_fused_masking_is_load_bearing_for_gelu(rng):
    """Without the 0-scale mask, covered-but-not-activated neurons would
    contribute (gelu(pre) != 0 for pre < 0) — prove the mask is what makes
    the non-ReLU segment path exact."""
    n, d = 256, 128
    x = jnp.asarray(rng.standard_normal((2, d)).astype(np.float32))
    ids = np.array([3, 7, 130])          # 2 segments, heavily over-covered
    (wu, wd, seg_ids, tiles, _), deq = _fused_inputs(
        rng, n, d, 2, ids, quantize=False)
    y = ops.sparse_ffn_segments_fused(x, wu, wd, seg_ids, tiles, None,
                                      seg_size=SEG, activation="gelu")
    y_ref = sparse_ffn_from_bundles(x, jnp.asarray(deq[ids]), d, 2,
                                    activation="gelu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    unmasked = jnp.where(jnp.asarray(seg_ids)[:, None] >= 0,
                         jnp.ones_like(tiles), tiles)
    y_bad = ops.sparse_ffn_segments_fused(x, wu, wd, seg_ids, unmasked, None,
                                          seg_size=SEG, activation="gelu")
    assert float(jnp.abs(y_bad - y_ref).max()) > 1e-3


def test_fused_pad_ids_contribute_zero(rng):
    n, d = 256, 128
    x = jnp.asarray(rng.standard_normal((2, d)).astype(np.float32))
    ids = np.arange(40)
    (wu, wd, seg_ids, tiles, _), _ = _fused_inputs(
        rng, n, d, 2, ids, quantize=True)
    y1 = ops.sparse_ffn_segments_fused(x, wu, wd, seg_ids[:1], tiles[:1],
                                       None, seg_size=SEG)
    # same single live segment + 7 pad entries (garbage scale rows: the
    # wrapper must zero them by seg_id < 0, not trust the caller)
    garbage = np.array(tiles)
    garbage[1:] = 9.0
    y2 = ops.sparse_ffn_segments_fused(x, wu, wd, seg_ids, garbage, None,
                                       seg_size=SEG)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# auto promotion + serving token identity
# ---------------------------------------------------------------------------

def _tiny_model(seed=0):
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return cfg, model, params


def test_auto_resolution_rules(rng):
    """auto -> segments iff every placement is non-identity AND the payload
    is segment-mappable; explicit segments on an unmappable width raises."""
    cfg, model, params = _tiny_model()
    bundles = extract_dense_ffn_bundles(cfg, params)
    n = cfg.d_ff
    perm = [_perm_placement(rng, n) for _ in range(2)]
    rt = OffloadedFFNRuntime(cfg, bundles, perm)
    assert rt.ffn_kernel == "segments"
    assert "placement-ordered" in rt.ffn_kernel_reason
    # one identity layer demotes the whole runtime
    rt = OffloadedFFNRuntime(cfg, bundles, [perm[0], identity_placement(n)])
    assert rt.ffn_kernel == "bundles"
    assert "identity" in rt.ffn_kernel_reason
    # accounting-only payload (width != n_mats*d_model) demotes too
    thin = [b[:, :8].copy() for b in bundles]
    rt = OffloadedFFNRuntime(cfg, thin, perm, bundle_bytes=4096)
    assert rt.ffn_kernel == "bundles"
    assert "segment-mappable" in rt.ffn_kernel_reason
    with pytest.raises(ValueError, match="bundle_width"):
        OffloadedFFNRuntime(cfg, thin, perm, bundle_bytes=4096,
                            engine_cfg=EngineConfig(ffn_kernel="segments"))
    summary_keys = OffloadedFFNRuntime(cfg, bundles, perm).io_summary()
    assert summary_keys["ffn_kernel"] == "segments"
    assert "ffn_kernel_decision" in summary_keys


@pytest.mark.parametrize("prefetch", [False, True])
def test_auto_serving_token_identical_to_bundles_in_memory(rng, prefetch):
    """ISSUE 6 acceptance: ffn_kernel='auto' (promoted to segments on the
    permuted layout) serves tokens bit-identical to the bundles path under
    the ReLU oracle — serial and prefetch."""
    cfg, model, params = _tiny_model()
    bundles = extract_dense_ffn_bundles(cfg, params)
    perm = [_perm_placement(rng, cfg.d_ff) for _ in range(2)]

    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 6 + i).astype(np.int32),
                    max_new_tokens=4) for i in range(2)]

    def serve(ecfg):
        rt = OffloadedFFNRuntime(cfg, bundles, perm, engine_cfg=ecfg)
        res = ServingEngine(model, params, max_len=32, mode="offload",
                            offload=rt, prefetch=prefetch).serve(reqs)
        return rt, [r.tokens for r in res], [r.io_seconds for r in res]

    rt_auto, toks_auto, io_auto = serve(None)
    rt_bund, toks_bund, io_bund = serve(EngineConfig(ffn_kernel="bundles"))
    assert rt_auto.ffn_kernel == "segments"
    assert rt_bund.ffn_kernel == "bundles"
    assert toks_auto == toks_bund
    assert io_auto == pytest.approx(io_bund, abs=1e-12)


@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_auto_serving_token_identical_to_bundles_from_pack(tmp_path, rng,
                                                           quantize):
    """Same acceptance on the file-backed pack path, float32 AND int8: the
    fused kernel's in-VMEM dequant (raw int8 tiles x staged scales) must
    reproduce the bundles path's device-side dequant bit-for-bit at the
    token level."""
    cfg, model, params = _tiny_model()
    path = tmp_path / "m.npack"
    build_pack(model, params, path, calib_tokens=32, calib_batch=2,
               calib_seqlen=8, quantize=quantize)
    reqs = [Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=4)]

    def serve(ecfg):
        rt = OffloadedFFNRuntime.from_pack(cfg, path, engine_cfg=ecfg)
        res = ServingEngine(model, params, max_len=32, mode="offload",
                            offload=rt).serve(reqs)
        return rt, res[0].tokens, res[0].io_seconds

    rt_auto, toks_auto, io_auto = serve(None)
    rt_bund, toks_bund, io_bund = serve(EngineConfig(ffn_kernel="bundles"))
    assert rt_auto.ffn_kernel == "segments"   # pack placements are searched
    assert toks_auto == toks_bund
    assert io_auto == pytest.approx(io_bund, abs=1e-12)


def test_int8_pack_serving_never_dequantizes_on_host(tmp_path, rng,
                                                     monkeypatch):
    """Dtype-faithful staging: serving an int8 pack (either kernel) must not
    call the host dequantizer — int8 rows ride the ring and dequantize on
    device. The staged ring slots must actually BE int8."""
    import repro.store.file_store as fs

    cfg, model, params = _tiny_model()
    path = tmp_path / "q.npack"
    build_pack(model, params, path, calib_tokens=32, calib_batch=2,
               calib_seqlen=8, quantize="int8")
    calls = []
    monkeypatch.setattr(fs, "dequantize_int8",
                        lambda *a, **k: calls.append(1) or
                        dequantize_int8(*a, **k))
    reqs = [Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=3)]
    for ecfg in (EngineConfig(ffn_kernel="bundles"), None):
        rt = OffloadedFFNRuntime.from_pack(cfg, path, engine_cfg=ecfg)
        calls.clear()
        ServingEngine(model, params, max_len=32, mode="offload",
                      offload=rt).serve(reqs)
        assert not calls, f"host dequant on the {rt.ffn_kernel} path"
        if rt.ffn_kernel == "bundles":
            ring = [b for k, b in rt._staging.items()
                    if isinstance(k[0], int) and b.ndim == 2]
            assert ring and all(b.dtype == np.int8 for b in ring)


def test_file_store_raw_fetch_into_and_scales(tmp_path, rng):
    """fetch_into dispatches on the OUT buffer dtype: int8 buffers receive
    raw stored rows, float32 buffers the dequantized ones (back-compat);
    fetch_scales_into gathers the logical-order scales."""
    from repro.store import FileNeuronStore, write_pack

    n, w = 64, 12
    data = rng.standard_normal((n, w)).astype(np.float32)
    pl = _perm_placement(rng, n)
    path = tmp_path / "q.npack"
    write_pack(path, [data], [pl], quantize="int8")
    st = FileNeuronStore(path, 0)
    assert st.stored_dtype == np.int8 and st.payload_dtype == np.float32
    ids = rng.choice(n, size=10, replace=False)
    phys = pl.physical_of(ids.astype(np.int64))
    q, scales = quantize_int8(data[pl.placement])

    raw = np.zeros((16, w), np.int8)
    st.fetch_into(ids, raw)
    np.testing.assert_array_equal(raw[:10], q[phys])
    f32 = np.zeros((16, w), np.float32)
    st.fetch_into(ids, f32)
    np.testing.assert_array_equal(f32[:10], dequantize_int8(q[phys],
                                                            scales[phys]))
    sc = np.zeros(16, np.float32)
    st.fetch_scales_into(ids, sc)
    np.testing.assert_array_equal(sc[:10], scales[phys])
    # physical surfaces
    np.testing.assert_array_equal(st.physical_payload(dequantize=False), q)
    np.testing.assert_array_equal(st.physical_scales(), scales)
    with pytest.raises(ValueError, match="cannot serve"):
        st.fetch_into(ids, np.zeros((16, w), np.float64))
