"""Dry-run machinery: HLO collective parser (unit) + a reduced-mesh compile in
a subprocess (keeps this process at 1 device, per the assignment's carve-out
that only dryrun.py forces 512 host devices)."""
import subprocess
import sys

from repro.launch.dryrun import _shape_bytes, parse_collective_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[16,1024]") == 16 * 1024 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


def test_parse_collective_bytes():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(%y), dimensions={0}
  %p = f32[4]{0} add(%a, %b)
  %rs = (f32[32]{0}, f32[32]{0}) reduce-scatter(%z, %w)
  %a2a = f32[16,16]{1,0} all-to-all(%q)
  %cp-start = bf16[8]{0} collective-permute-start(%r)
  %cp-done = bf16[8]{0} collective-permute-done(%cp-start)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 128
    assert out["reduce-scatter"] == 256
    assert out["all-to-all"] == 1024
    assert out["collective-permute"] == 16
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


_SUBPROCESS = r"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"   # libtpu may be installed: never probe TPU
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.distributed.sharding import make_mesh as compat_make_mesh
from repro.launch.dryrun import run_case
mesh = compat_make_mesh((2, 4), ("data", "model"))
r = run_case("xlstm-125m", "decode_32k", save_dir="", mesh=mesh)
assert r["cost_analysis"].get("flops", 0) > 0
assert r["collective_bytes"]["total"] > 0, "model-parallel decode must communicate"
print("DRYRUN_CASE_OK")
"""


def test_dryrun_case_compiles_on_reduced_mesh():
    res = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "DRYRUN_CASE_OK" in res.stdout, res.stdout + res.stderr
