"""UFS device model + placement-aware neuron store."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.placement import identity_placement, search_placement
from repro.core.storage import UFS31, UFS40, ManagedReader, NeuronStore, UFSDevice


def test_bandwidth_curve_iops_bound_then_flat():
    """Paper Fig. 4: linear growth until ~24KB, then saturation."""
    dev = UFSDevice(**UFS40)
    bw_small = dev.bandwidth_at_io_size(4 * 1024)
    bw_mid = dev.bandwidth_at_io_size(16 * 1024)
    bw_cross = dev.bandwidth_at_io_size(dev.crossover_bytes())
    bw_large = dev.bandwidth_at_io_size(1024 * 1024)
    assert bw_small < bw_mid < bw_cross < bw_large
    # near-linear in the IOPS-bound regime
    assert bw_mid / bw_small == pytest.approx(4.0, rel=0.35)
    # saturates near bandwidth_max
    assert bw_large > 0.9 * dev.bandwidth_max
    assert dev.crossover_bytes() == pytest.approx(24e3, rel=0.05)


def test_read_time_additive():
    dev = UFSDevice()
    t1 = dev.read_time(1, 4096)
    t2 = dev.read_time(2, 8192)
    assert t2 - dev.base_latency == pytest.approx(2 * (t1 - dev.base_latency), rel=1e-6)
    assert dev.read_time(0, 0) == 0.0


@given(seed=st.integers(0, 50), thr=st.integers(0, 8))
@settings(max_examples=20, deadline=None)
def test_store_payload_independent_of_layout_and_collapse(seed, thr):
    """The bytes returned must always be the requested neurons, in order."""
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((64, 8)).astype(np.float32)
    d = rng.random((64, 64)); d = (d + d.T) / 2; np.fill_diagonal(d, np.inf)
    placement = search_placement(d, mode="exact")
    store = NeuronStore(data, placement)
    ids = rng.choice(64, size=rng.integers(1, 20), replace=False)
    payload, stats = store.read(ids, collapse_threshold=thr)
    np.testing.assert_array_equal(payload, data[ids])
    assert stats.n_ops >= 1
    assert stats.bytes_read >= stats.bytes_useful


def test_fewer_ops_with_good_placement():
    rng = np.random.default_rng(1)
    data = np.zeros((32, 4), np.float32)
    store = NeuronStore(data, identity_placement(32))
    scattered = np.arange(0, 32, 2)          # every other neuron
    _, s_scatter = store.read(scattered)
    _, s_contig = store.read(np.arange(16))  # contiguous block
    assert s_contig.n_ops == 1
    assert s_scatter.n_ops == 16
    assert s_scatter.seconds > s_contig.seconds


def test_reads_per_bundle_multiplier():
    data = np.zeros((16, 4), np.float32)
    bundled = NeuronStore(data, reads_per_bundle=1)
    split = NeuronStore(data, reads_per_bundle=3)   # llama.cpp separate matrices
    ids = np.array([0, 5, 9])
    _, s1 = bundled.read(ids)
    _, s3 = split.read(ids)
    assert s3.n_ops == 3 * s1.n_ops
    assert s3.bytes_read == 3 * s1.bytes_read


def test_managed_reader_adapts():
    rng = np.random.default_rng(2)
    data = np.zeros((1024, 256), np.float32)   # 1KB bundles -> IOPS-bound
    reader = ManagedReader(NeuronStore(data), initial_threshold=1)
    for _ in range(30):
        ids = np.sort(rng.choice(1024, 128, replace=False))
        reader.read(ids)
    # device is IOPS-bound at 1KB reads -> threshold must have grown
    assert reader.threshold.threshold > 1
    assert reader.total.n_requests == 30


def test_ufs31_slower_than_ufs40():
    d40, d31 = UFSDevice(**UFS40), UFSDevice(**UFS31)
    assert d31.read_time(100, 10 << 20) > d40.read_time(100, 10 << 20)


def test_managed_reader_honors_explicit_initial_threshold():
    """Satellite fix: `initial_threshold` used to be silently overwritten by
    the break-even anchor. An explicit value must now win (clamped to the
    anchor-derived adaptation band); None keeps the anchor start."""
    from repro.core.collapse import AdaptiveThreshold

    data = np.zeros((256, 256), np.float32)     # 1KB bundles
    store = NeuronStore(data)
    break_even = store.device.bandwidth_max / (
        store.device.iops_max * store.bundle_bytes)
    anchored = ManagedReader(store)             # default: anchor at break-even
    assert anchored.threshold.threshold == max(int(break_even), 0)
    explicit = ManagedReader(store, initial_threshold=int(break_even) + 3)
    assert explicit.threshold.threshold == int(break_even) + 3
    # out-of-band values clamp to the adaptation band instead of vanishing
    low = ManagedReader(store, initial_threshold=0)
    assert low.threshold.threshold == low.threshold.lo
    high = ManagedReader(store, initial_threshold=10 ** 9)
    assert high.threshold.threshold == high.threshold.hi
    # EngineConfig.initial_collapse_threshold is live config again
    from repro.core.engine import EngineConfig, OffloadEngine
    eng = OffloadEngine(data, config=EngineConfig(
        initial_collapse_threshold=int(break_even) + 3))
    assert eng.reader.threshold.threshold == int(break_even) + 3


def test_read_reports_precollapse_run_lengths():
    """`NeuronStore.read` computes run lengths from its already-sorted
    positions; the engine reuses them instead of re-deriving runs."""
    data = np.zeros((64, 4), np.float32)
    store = NeuronStore(data)                   # identity placement
    ids = np.array([0, 1, 2, 10, 20, 21])
    _, stats = store.read(ids, collapse_threshold=50)   # collapse merges ops
    np.testing.assert_array_equal(np.sort(stats.run_lengths), [1, 2, 3])
    assert stats.n_ops == 1                     # collapsed into one extent


def test_fetch_into_matches_fetch():
    rng = np.random.default_rng(5)
    data = rng.standard_normal((64, 8)).astype(np.float32)
    store = NeuronStore(data)
    ids = np.array([3, 9, 11, 40])
    buf = np.zeros((16, 8), np.float32)
    store.fetch_into(ids, buf)
    np.testing.assert_array_equal(buf[:4], store.fetch(ids))
    assert np.all(buf[4:] == 0)
