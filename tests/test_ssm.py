"""SSM mixers: sequence forward == step-by-step recurrence; chunking exact."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MambaConfig
from repro.models import ssm


def _x(rng, B=2, T=37, d=64):
    return jnp.asarray(rng.standard_normal((B, T, d)) * 0.5, jnp.float32)


@pytest.fixture(scope="module")
def cfgs():
    mamba_cfg = get_config("jamba-1.5-large-398b", reduced=True, d_model=64,
                           n_heads=2, n_kv_heads=1)
    xl = get_config("xlstm-125m", reduced=True, d_model=64, n_heads=2, n_kv_heads=2)
    return mamba_cfg, xl


@pytest.mark.parametrize("kind", ["mamba", "mlstm", "slstm"])
def test_forward_equals_stepwise_decode(kind, cfgs, rng):
    mamba_cfg, xl = cfgs
    cfg = mamba_cfg if kind == "mamba" else xl
    init = getattr(ssm, f"init_{kind}")
    fwd = getattr(ssm, f"{kind}_forward")
    step = getattr(ssm, f"{kind}_decode_step")
    state0 = getattr(ssm, f"{kind}_init_state")
    p = init(jax.random.PRNGKey(0), cfg)
    x = _x(rng)
    y_seq, final_state = fwd(p, x, cfg, return_state=True)
    st = state0(x.shape[0], cfg)
    outs = []
    for t in range(x.shape[1]):
        y_t, st = step(p, x[:, t], st, cfg)
        outs.append(y_t)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    # final states agree too
    for a, b in zip(jax.tree_util.tree_leaves(final_state),
                    jax.tree_util.tree_leaves(st)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_chunk_size_does_not_change_result(cfgs, rng):
    mamba_cfg, _ = cfgs
    p = ssm.init_mamba(jax.random.PRNGKey(1), mamba_cfg)
    x = _x(rng, T=50)
    orig = ssm.SCAN_CHUNK
    try:
        ssm.SCAN_CHUNK = 7
        y1 = ssm.mamba_forward(p, x, mamba_cfg)
        ssm.SCAN_CHUNK = 64
        y2 = ssm.mamba_forward(p, x, mamba_cfg)
    finally:
        ssm.SCAN_CHUNK = orig
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


def test_mamba_causality(cfgs, rng):
    """Output at t must not depend on inputs after t."""
    mamba_cfg, _ = cfgs
    p = ssm.init_mamba(jax.random.PRNGKey(2), mamba_cfg)
    x = _x(rng, T=20)
    y1 = ssm.mamba_forward(p, x, mamba_cfg)
    x_mod = x.at[:, 15:].set(7.7)
    y2 = ssm.mamba_forward(p, x_mod, mamba_cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :15]), np.asarray(y2[:, :15]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 15:]), np.asarray(y2[:, 15:]))


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_stability_long_range(kind, cfgs, rng):
    """Exponential gating with stabiliser must not overflow on long inputs."""
    _, xl = cfgs
    p = getattr(ssm, f"init_{kind}")(jax.random.PRNGKey(3), xl)
    x = _x(rng, T=256) * 5.0          # large inputs stress the exp gates
    y = getattr(ssm, f"{kind}_forward")(p, x, xl)
    assert np.all(np.isfinite(np.asarray(y)))


def test_mamba_gradients_finite(cfgs, rng):
    mamba_cfg, _ = cfgs
    p = ssm.init_mamba(jax.random.PRNGKey(4), mamba_cfg)
    x = _x(rng, T=33)

    def loss(p):
        return jnp.sum(ssm.mamba_forward(p, x, mamba_cfg) ** 2)

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert np.all(np.isfinite(np.asarray(v))), k
