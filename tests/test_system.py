"""End-to-end system test: the paper's full pipeline on a real (tiny) model.

Trace a ReLU model's activations -> extract co-activation -> search placement
-> serve with the offload engine -> verify (a) outputs equal the dense model
and (b) RIPPLE's I/O time beats the llama.cpp-style baseline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (EngineConfig, OffloadEngine, identity_placement,
                        search_placement, stats_from_masks)
from repro.core.sparse_ffn import FFNWeights, dense_ffn, make_bundles
from repro.core.predictor import PredictorConfig, recall_precision, train_predictor
from repro.models import build_model
from repro.serving.engine import OffloadedFFNRuntime


def test_full_paper_pipeline(rng):
    # 1. a tiny ReLU dense model (the paper's OPT-style setting)
    cfg = get_config("opt-350m", reduced=True, d_model=64, d_ff=256,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # 2. trace FFN activations on a calibration stream
    tokens = jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    pre = out["ffn_pre_act"]                     # [L, B, T, d_ff]
    assert pre.shape[0] == 2 and pre.shape[-1] == 256
    masks = [np.asarray(pre[l] > 0).reshape(-1, 256) for l in range(2)]
    sparsity = float(np.mean(masks[0]))
    assert 0.05 < sparsity < 0.95

    # 3. offline: co-activation -> placement per layer
    placements = [search_placement(stats_from_masks(m).distance_matrix(), mode="exact")
                  for m in masks]

    # 4. predictor on layer-0 hidden states (here: embeddings as proxy input)
    h = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (256, 64)))
    pred_masks = (h @ rng.standard_normal((64, 256)) > 1.0)
    pcfg = PredictorConfig(d_model=64, n_neurons=256, lr=3e-3)
    pparams, _ = train_predictor(pcfg, h, pred_masks.astype(np.float32), epochs=6)
    rec, prec = recall_precision(pparams, h, pred_masks)
    assert rec > 0.5

    # 5. online: serve the trace through the offload engine, check ordering
    bundles = []
    for l in range(2):
        sub = params["stack"]["sub_0"]
        w = FFNWeights(w_up=sub["ffn"]["w_up"][l].T, w_down=sub["ffn"]["w_down"][l])
        bundles.append(np.asarray(make_bundles(w)))
    ripple = OffloadedFFNRuntime(cfg, bundles, placements)
    base = OffloadedFFNRuntime(
        cfg, bundles, [identity_placement(256) for _ in range(2)],
        engine_cfg=EngineConfig(collapse=False, linking_aligned_cache=False,
                                reads_per_bundle=2))
    x = rng.standard_normal((4, 64)).astype(np.float32)
    for runtime in (ripple, base):
        for l in range(2):
            sub = params["stack"]["sub_0"]
            w = FFNWeights(w_up=sub["ffn"]["w_up"][l].T, w_down=sub["ffn"]["w_down"][l])
            pre_x = x @ np.asarray(w.w_up).T
            y, _ = runtime.ffn_apply(l, x, oracle_mask=pre_x > 0)
            ref = np.asarray(dense_ffn(jnp.asarray(x), w, activation="relu"))
            np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
    # serve more tokens to compare I/O
    for t in range(40):
        xt = rng.standard_normal((1, 64)).astype(np.float32)
        for runtime in (ripple, base):
            for l in range(2):
                sub = params["stack"]["sub_0"]
                w_up = np.asarray(sub["ffn"]["w_up"][l]).T
                mask = (xt @ w_up.T) > 0
                runtime.ffn_apply(l, xt, oracle_mask=mask)
    io_r = ripple.io_summary()["io_seconds_per_token"]
    io_b = base.io_summary()["io_seconds_per_token"]
    assert io_r < io_b, (io_r, io_b)


def test_predictor_in_the_loop_serving(rng):
    """Close the full loop with a LEARNED predictor: trace a real model, train
    per-layer predictors on (hidden, mask) pairs, and serve through
    OffloadedFFNRuntime with predicted (not oracle) activations. The served
    output must stay close to dense whenever predicted support covers the true
    support; I/O stats must be sane."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import identity_placement, search_placement, stats_from_masks
    from repro.core.predictor import (PredictorConfig, predict_mask,
                                      train_predictor)
    from repro.core.sparse_ffn import FFNWeights, dense_ffn, make_bundles
    from repro.models import build_model
    from repro.serving.engine import OffloadedFFNRuntime

    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    sub = params["stack"]["sub_0"]

    # calibration: hidden states at the FFN input + true ReLU masks, layer 0
    h_calib = rng.standard_normal((600, 48)).astype(np.float32)
    w = FFNWeights(w_up=sub["ffn"]["w_up"][0].T, w_down=sub["ffn"]["w_down"][0])
    masks = (h_calib @ np.asarray(w.w_up).T) > 0
    pcfg = PredictorConfig(d_model=48, n_neurons=192, lr=3e-3, pos_weight=4.0)
    pparams, _ = train_predictor(pcfg, h_calib, masks.astype(np.float32), epochs=20)

    placement = search_placement(
        stats_from_masks(masks[:400]).distance_matrix(), mode="exact")
    bundles = [np.asarray(make_bundles(w)) for _ in range(1)]
    runtime = OffloadedFFNRuntime(cfg, bundles, [placement],
                                  predictors=[pparams])

    # serve fresh tokens THROUGH THE PREDICTOR (no oracle_mask argument)
    h_serve = rng.standard_normal((20, 48)).astype(np.float32)
    rel_errs = []
    for h in h_serve:
        y, ts = runtime.ffn_apply(0, h[None])          # predictor path
        ref = np.asarray(dense_ffn(jnp.asarray(h[None]), w, activation="relu"))
        pred = np.asarray(predict_mask(pparams, jnp.asarray(h[None])))[0]
        truth = (h[None] @ np.asarray(w.w_up).T)[0] > 0
        covered = bool(np.all(~truth | pred))
        denom = max(np.abs(ref).max(), 1e-3)
        rel = np.abs(y - ref).max() / denom
        rel_errs.append((rel, covered))
        if covered:                                     # exactness when covered
            assert rel < 1e-4, rel
        assert ts.n_activated == int(pred.sum())
    # recall-leaning predictor: a good fraction of tokens fully covered, and
    # the approximation stays small when a few neurons are missed — the
    # Deja Vu / paper operating regime
    assert sum(c for _, c in rel_errs) >= 5, rel_errs
    uncovered = [r for r, c in rel_errs if not c]
    if uncovered:
        assert float(np.mean(uncovered)) < 0.1, uncovered
    s = runtime.io_summary()
    assert s["io_seconds_per_token"] > 0 and s["ops_per_token"] > 0
