"""NeuronPack artifact + FileNeuronStore (ISSUE 5).

The contract under test: the on-disk pack is a faithful serialization of the
offline placement — `FileNeuronStore` serves the exact `NeuronStore`
read/fetch/plan contract with bit-identical payloads AND bit-identical
modeled IOStats on randomized placements (float32 and int8 packs), while
additionally issuing one REAL positional file read per collapsed extent
(measured_* accounting). End to end, a pack built by the offline packer
serves tokens through the serving stack identical to the in-memory path,
under both the ReLU oracle and trained predictor masks. Satellites: the
sharded streaming trace writer + merge-based stats entry point, and the
`IOStats.add` run-lengths aggregation contract.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coactivation import stats_from_mask_shards, stats_from_masks
from repro.core.engine import EngineConfig, OffloadEngine
from repro.core.placement import identity_placement, search_placement
from repro.core.storage import IOStats, ManagedReader, NeuronStore
from repro.core.trace import (ShardedTraceWriter, SyntheticTraceConfig,
                              iter_trace_shards, synthetic_masks)
from repro.models import build_model
from repro.serving.engine import (OffloadedFFNRuntime, Request, ServingEngine,
                                  dense_ffn_layer_count,
                                  validate_pack_for_model)
from repro.store import (FileNeuronStore, NeuronPack, build_pack,
                         dequantize_int8, quantize_int8, write_pack)
from repro.store.packer import extract_dense_ffn_bundles


def _random_placement(rng, n):
    d = rng.random((n, n))
    d = (d + d.T) / 2
    np.fill_diagonal(d, np.inf)
    return search_placement(d, mode="exact")


# ---------------------------------------------------------------------------
# format round-trip + store identity
# ---------------------------------------------------------------------------

def test_pack_roundtrip_header_placement_and_logical_bundles(tmp_path, rng):
    n, w = 64, 12
    data = rng.standard_normal((n, w)).astype(np.float32)
    pl = _random_placement(rng, n)
    path = tmp_path / "a.npack"
    manifest = write_pack(path, [data, data * 2], [pl, identity_placement(n)],
                          meta={"arch": "test"})
    assert manifest["n_layers"] == 2 and manifest["file_bytes"] > 0
    pack = NeuronPack.open(path)
    assert (pack.n_neurons, pack.bundle_width) == (n, w)
    assert not pack.quantized and pack.meta["arch"] == "test"
    np.testing.assert_array_equal(pack.placement(0).placement, pl.placement)
    np.testing.assert_array_equal(pack.placement(0).inverse, pl.inverse)
    # physical-order on disk, logical order recovered exactly
    np.testing.assert_array_equal(np.asarray(pack.bundles_memmap(0)),
                                  data[pl.placement])
    np.testing.assert_array_equal(pack.logical_bundles(0), data)
    np.testing.assert_array_equal(pack.logical_bundles(1), data * 2)


def test_pack_rejects_bad_magic_and_geometry(tmp_path, rng):
    bad = tmp_path / "bad.npack"
    bad.write_bytes(b"NOTAPACKxxxxxxxx")
    with pytest.raises(ValueError, match="magic"):
        NeuronPack.open(bad)
    data = rng.standard_normal((8, 4)).astype(np.float32)
    with pytest.raises(ValueError, match="homogeneous"):
        write_pack(tmp_path / "b.npack",
                   [data, data[:4]],
                   [identity_placement(8), identity_placement(4)])


@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_file_store_bit_identical_to_in_memory(tmp_path, quantize):
    """fetch / fetch_into / read payloads and every MODELED IOStats field
    bit-equal to the in-memory NeuronStore, on randomized placements."""
    rng = np.random.default_rng(7)
    n, w = 96, 16
    data = rng.standard_normal((n, w)).astype(np.float32)
    pl = _random_placement(rng, n)
    path = tmp_path / f"{quantize}.npack"
    write_pack(path, [data], [pl], quantize=quantize)
    fst = FileNeuronStore(path, 0)

    if quantize == "int8":
        q, scales = quantize_int8(data[pl.placement])
        ref_logical = dequantize_int8(q, scales)[pl.inverse]
        assert fst.bundle_bytes == w            # billed at stored int8 bytes
    else:
        ref_logical = data
        assert fst.bundle_bytes == w * 4
    mem = NeuronStore(ref_logical, pl, bundle_bytes=fst.bundle_bytes)

    for seed in range(5):
        r = np.random.default_rng(seed)
        ids = r.choice(n, size=r.integers(1, 40), replace=False)
        thr = int(r.integers(0, 6))
        assert mem.plan_extents(ids, thr) == fst.plan_extents(ids, thr)
        pm, sm = mem.read(ids, collapse_threshold=thr)
        pf, sf = fst.read(ids, collapse_threshold=thr)
        np.testing.assert_array_equal(pm, pf)
        assert pf.dtype == np.float32
        assert (sm.n_ops, sm.bytes_read, sm.bytes_useful, sm.seconds) == \
               (sf.n_ops, sf.bytes_read, sf.bytes_useful, sf.seconds)
        np.testing.assert_array_equal(sm.run_lengths, sf.run_lengths)
        # dual accounting: real reads happened on the file store only
        assert sf.measured_ops == len(fst.plan_extents(ids, thr))
        assert sf.measured_bytes > 0 and sf.measured_seconds > 0
        assert (sm.measured_ops, sm.measured_bytes, sm.measured_seconds) == \
               (0, 0, 0.0)
        np.testing.assert_array_equal(mem.fetch(ids), fst.fetch(ids))
        buf_m = np.full((48, w), -1, np.float32)
        buf_f = np.full((48, w), -1, np.float32)
        np.testing.assert_array_equal(mem.fetch_into(ids, buf_m),
                                      fst.fetch_into(ids, buf_f))
    fst.close()


def test_file_store_real_reads_happen_without_payload(tmp_path, rng):
    """The engine's probe path (`fetch_payload=False`) must still hit the
    file: the extent reads ARE the I/O, only row gathering is skipped."""
    n, w = 64, 8
    data = rng.standard_normal((n, w)).astype(np.float32)
    path = tmp_path / "p.npack"
    write_pack(path, [data], [identity_placement(n)])
    fst = FileNeuronStore(path, 0)
    payload, stats = fst.read(np.array([1, 2, 3, 30, 31]),
                              fetch_payload=False)
    assert payload is None
    assert stats.measured_ops == 2 and stats.measured_bytes == 5 * w * 4
    # mmap fallback path serves the same bytes
    fb = FileNeuronStore(path, 0, use_pread=False)
    p1, _ = fst.read(np.array([5, 6, 40]))
    p2, s2 = fb.read(np.array([5, 6, 40]))
    np.testing.assert_array_equal(p1, p2)
    assert s2.measured_ops == 2


def test_file_store_through_engine_and_managed_reader(tmp_path, rng):
    """OffloadEngine.from_store over a FileNeuronStore: token stats identical
    to the in-memory engine, measured accounting aggregated by the reader."""
    n, w = 128, 8
    data = rng.standard_normal((n, w)).astype(np.float32)
    pl = _random_placement(np.random.default_rng(3), n)
    path = tmp_path / "e.npack"
    write_pack(path, [data], [pl])
    masks = synthetic_masks(SyntheticTraceConfig(n_neurons=n, n_clusters=8,
                                                 seed=4), 20)
    e_mem = OffloadEngine(data, placement=pl, config=EngineConfig())
    e_file = OffloadEngine.from_store(FileNeuronStore(path, 0),
                                      config=EngineConfig())
    e_mem.run_trace(masks)
    e_file.run_trace(masks)
    s_mem, s_file = e_mem.summary(), e_file.summary()
    for key in ("io_seconds_per_token", "ops_per_token", "cache_hit_rate",
                "mean_run_length", "effective_bandwidth"):
        assert s_mem[key] == pytest.approx(s_file[key]), key
    assert sum(t.io.measured_ops for t in e_file.history) > 0
    assert sum(t.io.measured_ops for t in e_mem.history) == 0
    # ManagedReader.total aggregates measured fields; run_lengths obey the
    # aggregation contract (never a stale array on an aggregate)
    assert e_file.reader.total.measured_seconds > 0
    assert e_file.reader.total.run_lengths is None


# ---------------------------------------------------------------------------
# satellites: IOStats.add contract, sharded trace writer
# ---------------------------------------------------------------------------

def test_iostats_add_never_carries_stale_run_lengths():
    """Regression (satellite): `add` used to keep `self`'s run_lengths,
    handing aggregates a stale view of only the first read."""
    a = IOStats(n_ops=1, bytes_read=10, seconds=0.5,
                run_lengths=np.array([1, 2]))
    b = IOStats(n_ops=2, bytes_read=20, bytes_useful=5, seconds=0.25,
                n_requests=1, measured_ops=3, measured_bytes=7,
                measured_seconds=0.125, run_lengths=np.array([9]))
    a.add(b)
    assert a.run_lengths is None                 # the contract
    assert (a.n_ops, a.bytes_read, a.bytes_useful) == (3, 30, 5)
    assert (a.measured_ops, a.measured_bytes) == (3, 7)
    assert a.seconds == 0.75 and a.measured_seconds == 0.125
    # aggregating INTO a fresh total clears too (other has runs, self None)
    total = IOStats()
    total.add(b)
    assert total.run_lengths is None
    assert total.measured_bandwidth == pytest.approx(7 / 0.125)


def test_sharded_trace_writer_roundtrip_and_merged_stats(tmp_path, rng):
    n = 48
    tc = SyntheticTraceConfig(n_neurons=n, n_clusters=6, seed=9)
    all_masks = synthetic_masks(tc, 30)
    writer = ShardedTraceWriter(tmp_path / "trace", n_layers=2, n_neurons=n)
    for lo in range(0, 30, 10):                   # 3 shards per layer
        writer.append(0, all_masks[lo:lo + 10])
        writer.append(1, ~all_masks[lo:lo + 10])
    manifest = writer.finish()
    assert manifest["tokens_per_layer"] == [30, 30]
    assert len(manifest["shards"][0]) == 3
    got = np.concatenate(list(iter_trace_shards(tmp_path / "trace", 0)))
    np.testing.assert_array_equal(got, all_masks)
    # shard-merged stats == one-shot stats (counts, pairs, tokens)
    merged = stats_from_mask_shards(iter_trace_shards(tmp_path / "trace", 0))
    whole = stats_from_masks(all_masks)
    assert merged.n_tokens == whole.n_tokens
    np.testing.assert_array_equal(merged.counts, whole.counts)
    np.testing.assert_array_equal(merged.pair_counts, whole.pair_counts)
    with pytest.raises(ValueError, match="n_neurons"):
        stats_from_mask_shards(iter([]))
    assert stats_from_mask_shards(iter([]), n_neurons=4).n_tokens == 0
    with pytest.raises(ValueError, match="width"):
        writer.append(0, np.zeros((2, n + 1), bool))


# ---------------------------------------------------------------------------
# end to end: packer -> pack -> serving identity
# ---------------------------------------------------------------------------

def _tiny_model(seed=0):
    cfg = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                     n_layers=2, vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return cfg, model, params


def _mem_runtime_like_pack(cfg, params, pack, **kw):
    """In-memory arm over the SAME bundles + the pack's placements."""
    return OffloadedFFNRuntime(
        cfg, extract_dense_ffn_bundles(cfg, params),
        [pack.placement(l) for l in range(pack.n_layers)], **kw)


def test_build_pack_then_serve_token_and_io_identity(tmp_path, rng):
    """ISSUE 5 acceptance: a pack built by the offline packer serves tokens
    through the serving stack identical to the in-memory NeuronStore path
    (greedy, ReLU oracle), with per-request io_seconds matching too."""
    cfg, model, params = _tiny_model()
    path = tmp_path / "m.npack"
    report = build_pack(model, params, path, calib_tokens=128, calib_batch=4,
                        calib_seqlen=16, shard_dir=tmp_path / "shards")
    assert report.n_layers == dense_ffn_layer_count(cfg) == 2
    assert os.path.exists(path) and report.tokens_traced >= 128
    assert (tmp_path / "shards" / "manifest.json").exists()
    pack = NeuronPack.open(path)
    validate_pack_for_model(pack, cfg)

    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 6 + 3 * i).astype(np.int32),
                    max_new_tokens=3 + i) for i in range(3)]
    res_mem = ServingEngine(model, params, max_len=64, mode="offload",
                            offload=_mem_runtime_like_pack(cfg, params, pack)
                            ).serve(reqs)
    res_pack = ServingEngine(model, params, max_len=64, mode="offload",
                             pack_path=str(path)).serve(reqs)
    for a, b in zip(res_mem, res_pack):
        assert a.tokens == b.tokens
        assert a.io_seconds == pytest.approx(b.io_seconds, abs=1e-12)


def test_pack_serving_identity_with_trained_predictor_masks(tmp_path, rng):
    """Acceptance, predictor arm: same trained predictors attached to both
    runtimes -> identical tokens from the pack and the in-memory path."""
    from repro.core.predictor import PredictorConfig, train_predictor

    cfg, model, params = _tiny_model()
    path = tmp_path / "m.npack"
    build_pack(model, params, path, calib_tokens=64, calib_batch=4,
               calib_seqlen=16)
    pack = NeuronPack.open(path)
    # train tiny per-layer predictors on a short captured trace
    import jax.numpy as jnp
    tokens = jnp.asarray(rng.integers(0, 128, (4, 16)), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    hiddens = np.asarray(out["ffn_inputs"]).reshape(2, -1, cfg.d_model)
    masks = np.asarray(out["ffn_pre_act"] > 0).reshape(2, -1, cfg.d_ff)
    predictors = [train_predictor(
        PredictorConfig(d_model=cfg.d_model, n_neurons=cfg.d_ff, d_hidden=16),
        hiddens[l], masks[l], epochs=1)[0] for l in range(2)]

    rt_mem = _mem_runtime_like_pack(cfg, params, pack, predictors=predictors)
    rt_pack = OffloadedFFNRuntime.from_pack(cfg, pack, predictors=predictors)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(2)]
    res_mem = ServingEngine(model, params, max_len=32, mode="offload",
                            offload=rt_mem, oracle=False).serve(reqs)
    res_pack = ServingEngine(model, params, max_len=32, mode="offload",
                             offload=rt_pack, oracle=False).serve(reqs)
    for a, b in zip(res_mem, res_pack):
        assert a.tokens == b.tokens


def test_from_pack_validates_model_geometry(tmp_path, rng):
    cfg, model, params = _tiny_model()
    path = tmp_path / "m.npack"
    build_pack(model, params, path, calib_tokens=32, calib_batch=2,
               calib_seqlen=8, use_placement=False)
    wrong = get_config("opt-350m", reduced=True, d_model=48, d_ff=256,
                       n_layers=2, vocab_size=128)
    with pytest.raises(ValueError, match="n_neurons"):
        OffloadedFFNRuntime.from_pack(wrong, path)
    wrong2 = get_config("opt-350m", reduced=True, d_model=48, d_ff=192,
                        n_layers=4, vocab_size=128)
    with pytest.raises(ValueError, match="n_layers"):
        validate_pack_for_model(NeuronPack.open(path), wrong2)
    # geometry collision caught by meta: a 3-mat silu pack of d_model=32 has
    # the same bundle width (96) as this 2-mat relu model of d_model=48
    silu_path = tmp_path / "silu.npack"
    write_pack(silu_path, [np.zeros((192, 96), np.float32)],
               [identity_placement(192)],
               meta=dict(d_model=32, n_mats=3, activation="silu"))
    with pytest.raises(ValueError, match="meta.activation"):
        validate_pack_for_model(NeuronPack.open(silu_path), cfg)
    # pack_path= + offload= is ambiguous; resident mode can't take a pack
    with pytest.raises(ValueError, match="not both"):
        ServingEngine(model, params, mode="offload", pack_path=str(path),
                      offload=OffloadedFFNRuntime.from_pack(cfg, path))
    with pytest.raises(ValueError, match="offload"):
        ServingEngine(model, params, mode="resident", pack_path=str(path))


def test_int8_pack_serves_tokens_end_to_end(tmp_path, rng):
    """Quantized packs serve through the whole stack (tokens need not match
    the float32 path — int8 IS lossy — but the pipeline must be exact w.r.t.
    the dequantized bundles)."""
    cfg, model, params = _tiny_model()
    path = tmp_path / "q.npack"
    build_pack(model, params, path, calib_tokens=32, calib_batch=2,
               calib_seqlen=8, quantize="int8")
    pack = NeuronPack.open(path)
    assert pack.quantized
    rt_pack = OffloadedFFNRuntime.from_pack(cfg, pack)
    rt_mem = OffloadedFFNRuntime(
        cfg, [pack.logical_bundles(l) for l in range(pack.n_layers)],
        [pack.placement(l) for l in range(pack.n_layers)],
        bundle_bytes=pack.row_bytes)
    reqs = [Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new_tokens=4)]
    res_pack = ServingEngine(model, params, max_len=32, mode="offload",
                             offload=rt_pack).serve(reqs)
    res_mem = ServingEngine(model, params, max_len=32, mode="offload",
                            offload=rt_mem).serve(reqs)
    assert res_pack[0].tokens == res_mem[0].tokens
    assert len(res_pack[0].tokens) == 4
