"""S3-FIFO + linking-aligned admission (§5.2)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache import LinkingAlignedCache, S3FIFOCache


def test_capacity_respected():
    c = S3FIFOCache(capacity=10)
    for i in range(100):
        c.access(i)
        c.insert(i)
    assert len(c) <= 10


def test_hit_after_insert():
    c = S3FIFOCache(capacity=4)
    c.insert("a")
    assert c.access("a")


def test_ghost_promotion_to_main():
    c = S3FIFOCache(capacity=10)   # small=1, main=9, ghost=9
    c.insert("a")
    for i in range(3):             # push 'a' out of the small FIFO -> ghost
        c.insert(i)
    assert "a" not in c and "a" in c.ghost
    c.insert("a")                  # ghost hit -> straight to main
    assert "a" in c.main


def test_frequent_small_items_promoted():
    c = S3FIFOCache(capacity=10)
    c.insert("hot")
    c.access("hot")
    c.access("hot")
    for i in range(20):
        c.insert(i)
    # 'hot' was re-accessed on probation: must have been moved to main, not dropped
    assert "hot" in c.main


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_lookup_partitions_ids(seed):
    rng = np.random.default_rng(seed)
    cache = LinkingAlignedCache(capacity=16)
    ids = rng.choice(100, size=20, replace=False)
    hits, misses = cache.lookup(ids)
    assert len(hits) + len(misses) == len(ids)
    assert set(hits.tolist()) | set(misses.tolist()) == set(ids.tolist())


def test_classification_sporadic_vs_segment():
    cache = LinkingAlignedCache(capacity=100, segment_min_len=4)
    ids = np.array([10, 11, 12, 13, 50, 80, 81])
    phys = ids.copy()                      # identity physical layout
    sporadic, segment = cache.classify(ids, phys)
    assert segment == {10, 11, 12, 13}
    assert sporadic == {50, 80, 81}


def test_linking_aligned_admits_fewer_segment_members():
    rng = np.random.default_rng(0)
    # one long segment + scattered sporadics, accessed over several rounds
    seg_ids = np.arange(100, 164)
    spor_ids = rng.choice(300, 40, replace=False) + 200
    aligned = LinkingAlignedCache(capacity=64, segment_admit_p=0.1, linking_aligned=True)
    naive = LinkingAlignedCache(capacity=64, linking_aligned=False)
    ids = np.concatenate([seg_ids, spor_ids])
    for cache in (aligned, naive):
        for _ in range(5):
            _, misses = cache.lookup(ids)
            cache.admit(misses, misses.copy())
    # §5.2: "we only control the cache admitting policy" — the aligned cache
    # must reject segment members at admission; the naive one never rejects.
    assert aligned.stats.rejected > 0
    assert naive.stats.rejected == 0
    assert aligned.stats.admitted < naive.stats.admitted


def test_zero_capacity_never_hits():
    cache = LinkingAlignedCache(capacity=0)
    ids = np.arange(10)
    hits, misses = cache.lookup(ids)
    cache.admit(misses, misses)
    hits2, _ = cache.lookup(ids)
    assert len(hits) == 0 and len(hits2) == 0


def test_s3fifo_beats_lru_and_fifo_on_scan_resistant_workload():
    """S3-FIFO's one-hit-wonder filtering: a hot set + a scan of cold keys.
    LRU/FIFO churn; S3-FIFO's probationary small queue keeps the hot set."""
    from repro.core.cache import FIFOCache, LRUCache
    rng = np.random.default_rng(0)
    hot = list(range(32))
    caches = {"s3fifo": S3FIFOCache(64), "lru": LRUCache(64), "fifo": FIFOCache(64)}
    for step in range(3000):
        if rng.random() < 0.5:
            key = int(rng.choice(hot))            # recurring hot keys
        else:
            key = 1000 + step                      # one-hit-wonder scan
        for c in caches.values():
            if not c.access(key):
                c.insert(key)
    rates = {name: c.stats.hit_rate for name, c in caches.items()}
    assert rates["s3fifo"] > rates["lru"] >= rates["fifo"] - 0.02, rates
