"""IOScheduler / overlap-model invariants (core/pipeline.py)."""
import numpy as np

from repro.core.pipeline import (IOScheduler, Stage, StageMeasurement,
                                 overlapped_latency, serial_latency)


def _random_stages(rng, n):
    return [Stage(layer=i, compute_seconds=float(rng.uniform(0, 5e-3)),
                  io_seconds=float(rng.uniform(0, 5e-3))) for i in range(n)]


def test_overlapped_bounded_by_serial_and_critical_path():
    rng = np.random.default_rng(0)
    for trial in range(200):
        stages = _random_stages(rng, int(rng.integers(1, 12)))
        serial = serial_latency(stages)
        over = overlapped_latency(stages)
        total_io = sum(s.io_seconds for s in stages)
        total_c = sum(s.compute_seconds for s in stages)
        assert over <= serial + 1e-12
        assert over >= max(total_io, total_c) - 1e-12


def test_first_read_is_never_hidden():
    # one stage: nothing to overlap with -> overlapped == serial
    stages = [Stage(0, compute_seconds=2e-3, io_seconds=3e-3)]
    assert overlapped_latency(stages) == serial_latency(stages)


def test_steady_state_max_compute_io():
    """Equal stages: latency -> io_0 + sum(max(c, io)) (the paper's overlap
    argument: per layer you pay the slower of compute and prefetch)."""
    c, io, L = 2e-3, 3e-3, 8
    stages = [Stage(i, c, io) for i in range(L)]
    expected = io + (L - 1) * max(c, io) + c  # first read exposed, last compute
    assert abs(overlapped_latency(stages) - expected) < 1e-12


def test_scheduler_overlap_off_equals_serial():
    rng = np.random.default_rng(1)
    on, off = IOScheduler(overlap=True), IOScheduler(overlap=False)
    for _ in range(5):
        stages = _random_stages(rng, 6)
        for sch in (on, off):
            sch.begin_token()
            for s in stages:
                sch.record_stage(s.layer, s.compute_seconds, s.io_seconds)
            sch.end_token()
    s_on, s_off = on.summary(), off.summary()
    assert s_off["overlapped_seconds_per_token"] == s_off["serial_seconds_per_token"]
    assert s_off["overlap_efficiency"] == 0.0
    assert s_on["overlapped_seconds_per_token"] <= s_on["serial_seconds_per_token"]
    assert s_on["serial_seconds_per_token"] == s_off["serial_seconds_per_token"]


def test_io_bound_and_compute_bound_limits():
    # pure compute: nothing to hide, overlapped == serial == sum(compute)
    comp = [Stage(i, 1e-3, 0.0) for i in range(5)]
    assert overlapped_latency(comp) == serial_latency(comp)
    # pure io: serialised on the single channel, overlapped == sum(io)
    io = [Stage(i, 0.0, 1e-3) for i in range(5)]
    assert abs(overlapped_latency(io) - 5e-3) < 1e-12


def test_end_token_apportions_compute_by_flops():
    """Sync-free mode: stages carry modeled FLOPs; one end-of-token
    measurement is split across stages by FLOPs share."""
    sch = IOScheduler(overlap=False)
    sch.begin_token()
    sch.record_stage(0, io_seconds=1e-3, flops=1e9)
    sch.record_stage(1, io_seconds=2e-3, flops=3e9)
    timing = sch.end_token(compute_seconds=4e-3)
    # serial = io (3ms) + compute (4ms split 1:3)
    assert abs(timing.serial_seconds - 7e-3) < 1e-12
    # per-stage split is visible through the overlap model too
    sch2 = IOScheduler(overlap=True)
    sch2.begin_token()
    sch2.record_stage(0, io_seconds=1e-3, flops=1e9)
    sch2.record_stage(1, io_seconds=2e-3, flops=3e9)
    t2 = sch2.end_token(compute_seconds=4e-3)
    assert t2.overlapped_seconds <= timing.serial_seconds
    # zero-flops stages split the measurement evenly instead of dropping it
    sch3 = IOScheduler(overlap=False)
    sch3.begin_token()
    sch3.record_stage(0, io_seconds=0.0)
    sch3.record_stage(1, io_seconds=0.0)
    t3 = sch3.end_token(compute_seconds=2e-3)
    assert abs(t3.serial_seconds - 2e-3) < 1e-12


def test_measured_mode_reconciles_wall_clock():
    """Measured mode: end_token(wall_seconds=...) aggregates worker busy /
    blocked / top-up host timings next to the analytic schedule."""
    sch = IOScheduler(overlap=True)
    sch.begin_token()
    sch.record_stage(0, io_seconds=1e-3, flops=1e9,
                     measured=StageMeasurement(io_host_seconds=2e-3,
                                               blocked_seconds=0.5e-3))
    sch.record_stage(1, io_seconds=1e-3, flops=1e9,
                     measured=StageMeasurement(io_host_seconds=3e-3,
                                               blocked_seconds=0.0,
                                               topup_seconds=0.25e-3))
    t = sch.end_token(compute_seconds=4e-3, wall_seconds=6e-3)
    assert t.measured_wall_seconds == 6e-3
    assert abs(t.measured_io_busy_seconds - 5e-3) < 1e-15
    assert abs(t.measured_exposed_seconds - 0.75e-3) < 1e-15
    # hidden = busy - exposed (the I/O host time that did not extend the token)
    assert abs(t.measured_hidden_seconds - 4.25e-3) < 1e-15
    assert abs(t.measured_serial_seconds - (6e-3 + 4.25e-3)) < 1e-15
    s = sch.summary()
    assert s["measured_wall_seconds_per_token"] == 6e-3
    assert abs(s["measured_overlap_efficiency"]
               - 4.25e-3 / (6e-3 + 4.25e-3)) < 1e-12


def test_measured_hidden_never_negative():
    """A slow worker (main thread blocked longer than the worker was busy)
    clamps hidden time at zero instead of going negative."""
    sch = IOScheduler(overlap=True)
    sch.begin_token()
    sch.record_stage(0, io_seconds=1e-3,
                     measured=StageMeasurement(io_host_seconds=1e-3,
                                               blocked_seconds=5e-3))
    t = sch.end_token(compute_seconds=1e-3, wall_seconds=7e-3)
    assert t.measured_hidden_seconds == 0.0
    assert t.measured_serial_seconds == t.measured_wall_seconds


def test_unmeasured_tokens_keep_summary_model_only():
    sch = IOScheduler(overlap=True)
    sch.begin_token()
    sch.record_stage(0, compute_seconds=1e-3, io_seconds=1e-3)
    sch.end_token()
    assert "measured_wall_seconds_per_token" not in sch.summary()
