"""Data pipeline + training substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticCorpus, make_data_iter
from repro.models import build_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_update, cosine_schedule, init_adamw
from repro.training.train import (TrainState, init_train_state, make_train_step,
                                  train_loop)


def test_synthetic_batches_shapes_and_range():
    it = make_data_iter(DataConfig(vocab_size=100, seq_len=32, batch_size=4))
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    assert int(b["tokens"].min()) >= 0 and int(b["tokens"].max()) < 100


def test_synthetic_corpus_is_learnable_structure():
    c = SyntheticCorpus(64, seed=0)
    rng = np.random.default_rng(0)
    seq = c.sample(rng, 2000)
    # bigram following the chain is far more frequent than chance
    follows = sum(int(seq[i + 1] in c.successors[seq[i]]) for i in range(1999))
    assert follows / 1999 > 0.5


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for testing! " * 20)
    it = make_data_iter(DataConfig(vocab_size=256, seq_len=16, batch_size=2,
                                   kind="bytes", path=str(p)))
    b = next(it)
    assert b["tokens"].shape == (2, 16)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.01)   # lr_min_ratio * peak


def test_adamw_decreases_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, total_steps=1000, weight_decay=0.0)
    st = init_adamw(p, cfg)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st, _ = adamw_update(g, st, p, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_grad_accumulation_matches_full_batch(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=128, n_layers=2)
    model = build_model(cfg)
    opt = AdamWConfig(grad_clip_norm=1e9)   # disable clipping (nonlinear in split)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (4, 32)), jnp.int32)}
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_train_loop_reduces_loss():
    cfg = get_config("xlstm-125m", reduced=True, vocab_size=128)
    model = build_model(cfg)
    data = make_data_iter(DataConfig(vocab_size=128, seq_len=32, batch_size=8))
    opt = AdamWConfig(lr_peak=2e-3, warmup_steps=5, total_steps=40)
    _, hist = train_loop(model, data, steps=40, opt_cfg=opt, log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("qwen2-7b", reduced=True, vocab_size=64, n_layers=2)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(3), AdamWConfig())
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, state, {"step": 7})
    restored, meta = load_checkpoint(path, state)
    assert meta["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    cfg = get_config("qwen2-7b", reduced=True, vocab_size=64, n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params)
    cfg2 = get_config("qwen2-7b", reduced=True, vocab_size=128, n_layers=2)
    params2 = build_model(cfg2).init_params(jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(path, params2)
