"""§Perf variants: triangular flash, sparse serve FFN, chunked CE — exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.layers import (ffn_forward, flash_gqa_attend,
                                 flash_gqa_attend_triangular, init_ffn,
                                 init_ffn_predictor, sparse_ffn_decode)

from conftest import tiny_batch


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_triangular_flash_equals_masked_flash(window, chunk):
    rng = np.random.default_rng(chunk + window)
    B, T, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    a = flash_gqa_attend(q, k, v, pos, pos, causal=True, window=window,
                         q_chunk=chunk, k_chunk=chunk)
    b = flash_gqa_attend_triangular(q, k, v, pos, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_sparse_ffn_decode_full_fraction_is_dense():
    rng = np.random.default_rng(0)
    cfg = get_config("internlm2-20b", reduced=True, d_model=64, d_ff=512,
                     serve_sparse=True, sparse_seg=64, sparse_frac=1.0)
    p = init_ffn(jax.random.PRNGKey(0), cfg)
    pred = init_ffn_predictor(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((3, 1, 64)), jnp.float32)
    dense, _ = ffn_forward(p, x, cfg)
    sparse = sparse_ffn_decode(p, pred, x, cfg)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse),
                               rtol=1e-4, atol=1e-4)


def test_sparse_serve_decode_end_to_end(rng):
    """Full decode step with serve_sparse at frac=1.0 == dense decode step."""
    cfg_d = get_config("qwen2-7b", reduced=True, d_model=128, d_ff=512, vocab_size=128)
    cfg_s = dataclasses.replace(cfg_d, serve_sparse=True, sparse_seg=64,
                                sparse_frac=1.0)
    md, ms = build_model(cfg_d), build_model(cfg_s)
    # sparse params = dense params + predictors; copy the shared subtree
    ps = ms.init_params(jax.random.PRNGKey(5))
    pd = jax.tree_util.tree_map(lambda x: x, ps)
    for j in list(pd["stack"]):
        pd["stack"][j] = {k: v for k, v in pd["stack"][j].items() if k != "ffn_pred"}
    batch = tiny_batch(cfg_d, rng, B=2, S=8)
    cd = md.init_cache(2, 16)
    cs = ms.init_cache(2, 16)
    ld, cd = md.prefill(pd, batch, cd)
    ls, cs = ms.prefill(ps, batch, cs)           # prefill is dense in both
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls), rtol=1e-5, atol=1e-5)
    tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
    od, _ = md.decode_step(pd, tok, jnp.int32(8), cd)
    os_, _ = ms.decode_step(ps, tok, jnp.int32(8), cs)
    np.testing.assert_allclose(np.asarray(od), np.asarray(os_), rtol=1e-3, atol=1e-3)


def test_chunked_ce_matches_naive(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=128, n_layers=2)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(0))
    # sequence longer than one CE chunk boundary (pad path exercised)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 37)), jnp.int32)}
    loss, _ = m.loss_fn(p, batch)
    lg = np.asarray(m.forward(p, batch)["logits"], np.float64)[:, :-1]
    tg = np.asarray(batch["tokens"])[:, 1:]
    logz = np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1)) + lg.max(-1)
    ce = (logz - np.take_along_axis(lg, tg[..., None], -1)[..., 0]).mean()
    assert abs(float(loss) - ce) < 1e-4


def test_chunked_ce_respects_loss_mask(rng):
    cfg = get_config("granite-3-2b", reduced=True, vocab_size=64, n_layers=2)
    m = build_model(cfg)
    p = m.init_params(jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, 64, (2, 20)), jnp.int32)
    mask = jnp.zeros((2, 20)).at[:, :10].set(1.0)
    l_masked, _ = m.loss_fn(p, {"tokens": toks, "loss_mask": mask})
    l_full, _ = m.loss_fn(p, {"tokens": toks})
    assert not np.isclose(float(l_masked), float(l_full))
    # causality: masking to the first 10 positions == scoring the 10-token prefix
    l_prefix, _ = m.loss_fn(p, {"tokens": toks[:, :10]})
    assert abs(float(l_masked) - float(l_prefix)) < 1e-4


def test_int8_kv_decode_close_to_dense(rng):
    import dataclasses
    cfg_d = get_config("qwen2-7b", reduced=True, d_model=128, vocab_size=128)
    cfg_q = dataclasses.replace(cfg_d, kv_quant=True)
    md, mq = build_model(cfg_d), build_model(cfg_q)
    p = md.init_params(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg_d, rng, B=2, S=12)
    cd, cq = md.init_cache(2, 24), mq.init_cache(2, 24)
    ld, cd = md.prefill(p, batch, cd)
    lq, cq = mq.prefill(p, batch, cq)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lq), atol=1e-5)
    tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
    od, _ = md.decode_step(p, tok, jnp.int32(12), cd)
    oq, _ = mq.decode_step(p, tok, jnp.int32(12), cq)
    scale = max(float(jnp.max(jnp.abs(od))), 1.0)
    assert float(jnp.max(jnp.abs(od - oq))) < 0.05 * scale
    # the cache really is int8
    leaves = jax.tree_util.tree_leaves(cq)
    assert any(l.dtype == jnp.int8 for l in leaves)
