"""Unified tracing + metrics subsystem (repro.obs).

The contract under test (ISSUE 10 acceptance): spans nest and order
correctly across threads into per-thread rings; ring wraparound keeps the
newest events and counts drops; the export is Perfetto/Chrome-loadable JSON
(every row has `ph`/`tid`, every body row has `ts`); the disabled
NULL_TRACER records exactly zero events so instrumented call sites are free
when tracing is off; a traced server run emits exactly one `decode` span
per emitted token; registered gauges read live object state; and
`request_timeline(handle)` reconstructs a request's phase breakdown.
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.obs import (NULL_TRACER, Counter, Gauge, Histogram,
                       MetricsRegistry, Tracer, disable_tracing,
                       enable_tracing, get_metrics, get_tracer,
                       request_timeline, set_metrics, set_tracer)
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.server import InferenceServer


@pytest.fixture
def tracer():
    """A fresh recording tracer installed globally; always restored."""
    tr = enable_tracing(capacity_per_thread=4096)
    yield tr
    disable_tracing()


@pytest.fixture
def registry():
    prev = get_metrics()
    reg = MetricsRegistry()
    set_metrics(reg)
    yield reg
    set_metrics(prev)


def _setup(seed=0, vocab=128):
    cfg = get_config("opt-350m", reduced=True, d_model=64, d_ff=256,
                     n_layers=2, vocab_size=vocab, activation="relu")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return cfg, model, params


# -- tracer core -------------------------------------------------------------

def test_span_nesting_and_ordering(tracer):
    """A child span closes before its parent, so the parent's X event has an
    earlier ts and a dur that covers the child's interval."""
    with tracer.span("outer", depth=0):
        time.sleep(0.001)
        with tracer.span("inner") as sp:
            sp.set(depth=1)
            time.sleep(0.001)
        time.sleep(0.001)
    evs = {e["name"]: e for e in tracer.events() if e["ph"] == "X"}
    outer, inner = evs["outer"], evs["inner"]
    assert outer["ts"] < inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert inner["args"]["depth"] == 1
    body = [e for e in tracer.events() if e["ph"] != "M"]
    assert body == sorted(body, key=lambda e: e["ts"])


def test_spans_from_threads_get_distinct_tids(tracer):
    def work(i):
        with tracer.span("job", worker=i):
            time.sleep(0.002)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    jobs = [e for e in tracer.events() if e["name"] == "job"]
    assert len(jobs) == 3
    assert len({e["tid"] for e in jobs}) == 3
    meta_tids = {e["tid"] for e in tracer.events() if e["ph"] == "M"}
    assert {e["tid"] for e in jobs} <= meta_tids


def test_ring_buffer_wraparound_keeps_newest():
    tr = Tracer(capacity_per_thread=8)
    for i in range(20):
        tr.instant("ev", i=i)
    assert tr.n_events == 20          # total recorded
    assert tr.dropped == 12
    kept = [e["args"]["i"] for e in tr.events() if e["ph"] == "i"]
    assert kept == list(range(12, 20))  # newest 8, oldest-first order


def test_complete_and_virtual_tracks(tracer):
    t0 = tracer.now()
    time.sleep(0.001)
    t1 = tracer.now()
    tracer.complete("work", t0, t1, track="req 7", uid=7)
    ev = next(e for e in tracer.events() if e["name"] == "work")
    assert ev["tid"] >= 1_000_000     # virtual track lane
    meta = next(e for e in tracer.events()
                if e["ph"] == "M" and e["tid"] == ev["tid"])
    assert meta["args"]["name"] == "req 7"
    assert ev["dur"] == pytest.approx(t1 - t0)


def test_perfetto_export_schema(tracer, tmp_path):
    with tracer.span("a"):
        tracer.instant("mark", k=1)
    tracer.counter("ctr", x=1.0, y=2.0)
    path = tmp_path / "trace.json"
    tracer.export(str(path))
    doc = json.loads(path.read_text())   # loads as plain JSON
    assert "traceEvents" in doc and doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        assert "ph" in ev and "tid" in ev and "pid" in ev
        if ev["ph"] != "M":
            assert "ts" in ev
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phs
    inst = next(e for e in doc["traceEvents"] if e["ph"] == "i")
    assert inst["s"] == "t"


def test_disabled_tracer_records_exactly_zero():
    assert get_tracer() is NULL_TRACER   # module default
    with get_tracer().span("x", a=1) as sp:
        sp.set(b=2)                      # no-op, never raises
    get_tracer().instant("y")
    get_tracer().counter("z", v=1.0)
    get_tracer().complete("w", 0.0, 1.0)
    assert get_tracer().n_events == 0
    assert get_tracer().dropped == 0
    assert get_tracer().export() == []
    assert not get_tracer().enabled


def test_set_tracer_returns_previous(tracer):
    prev = set_tracer(NULL_TRACER)
    assert prev is tracer
    set_tracer(tracer)
    assert get_tracer() is tracer


# -- metrics -----------------------------------------------------------------

def test_counter_gauge_histogram_snapshot(registry):
    registry.counter("reqs").inc()
    registry.counter("reqs").inc(4)      # create-or-get: same counter
    registry.gauge("depth").set(3.0)
    h = registry.histogram("lat")
    for v in (0.5, 1.5, 6.0, 0.0):
        h.observe(v)
    snap = registry.snapshot()
    assert snap["counters"]["reqs"] == 5
    assert snap["gauges"]["depth"] == 3.0
    hs = snap["histograms"]["lat"]
    assert hs["count"] == 4 and hs["max"] == 6.0 and hs["min"] == 0.0
    assert hs["buckets"]["zero"] == 1    # v <= 0 sentinel bucket
    assert sum(hs["buckets"].values()) == 4


def test_histogram_log_buckets():
    h = Histogram("b")
    h.observe(1.0)      # frexp exp 1
    h.observe(1.9)      # same bucket
    h.observe(2.0)      # next bucket
    assert len([k for k in h.snapshot()["buckets"] if k != "zero"]) == 2


def test_registered_gauge_reads_live_state(registry):
    state = {"v": 1.0}
    registry.register_gauge("live", lambda: state["v"])
    assert registry.snapshot()["gauges"]["live"] == 1.0
    state["v"] = 9.0
    assert registry.snapshot()["gauges"]["live"] == 9.0
    registry.register_gauge("boom", lambda: 1 / 0)
    assert registry.snapshot()["gauges"]["boom"] is None   # failure -> None


def test_metrics_delta(registry):
    registry.counter("n").inc(2)
    registry.gauge("g").set(1.0)
    prev = registry.snapshot()
    registry.counter("n").inc(3)
    registry.gauge("g").set(7.0)
    d = registry.delta(prev)
    assert d["counters"]["n"] == 3       # counters subtract
    assert d["gauges"]["g"] == 7.0       # gauges report current


# -- server integration ------------------------------------------------------

def test_server_one_decode_span_per_token(tracer, registry, rng):
    cfg, model, params = _setup()
    server = InferenceServer(model, params, max_slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=rng.integers(0, 128, 6 + 2 * i).astype(np.int32),
                    max_new_tokens=4 + i) for i in range(3)]
    try:
        for r in reqs:
            server.submit(r)
        results = server.drain()
    finally:
        server.close()
    evs = tracer.events()
    decode = [e for e in evs if e["name"] == "decode" and e["ph"] == "X"]
    assert len(decode) == server.stats.tokens_emitted
    assert server.stats.tokens_emitted == sum(len(r.tokens) for r in results)
    # every request has its own lane with a prefill and a retire
    for r in reqs:
        lane = [e for e in decode if e["args"]["uid"] == r.uid]
        assert len(lane) == next(
            len(x.tokens) for x in results if x.uid == r.uid)
    assert sum(1 for e in evs if e["name"] == "retire") == 3
    # registered server gauges read the final stats
    snap = registry.snapshot()
    assert snap["gauges"]["server.tokens_emitted"] == server.stats.tokens_emitted
    assert snap["histograms"]["server.step_seconds"]["count"] == \
        server.stats.decode_steps


def test_offload_trace_shows_prefetch_overlap(tracer, registry, rng):
    """Prefetch-worker read spans run on their own lane and at least one
    intersects a serving-thread decode_step span in wall time."""
    cfg, model, params = _setup()
    rt = build_offload_runtime(model, params,
                               rng=np.random.default_rng(7),
                               train_lookahead=True)
    server = InferenceServer(model, params, max_slots=2, max_len=64,
                             mode="offload", offload=rt, prefetch=True)
    try:
        for i in range(2):
            server.submit(Request(
                uid=i, prompt=rng.integers(0, 128, 8).astype(np.int32),
                max_new_tokens=5))
        server.drain()
    finally:
        server.close()
    evs = tracer.events()
    pf = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in evs
          if e["name"] == "prefetch" and e["ph"] == "X"]
    ds = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in evs
          if e["name"] == "decode_step"]
    assert pf and ds
    assert len({p[2] for p in pf} & {d[2] for d in ds}) == 0  # separate lanes
    assert any(p[0] < d[1] and d[0] < p[1] for p in pf for d in ds)
    # IOScheduler counter tracks rode along
    assert any(e["ph"] == "C" and e["name"] == "io_model_ms" for e in evs)
    # scheduler gauges registered by the server match its summary
    snap = registry.snapshot()
    summ = server.scheduler.summary()
    assert snap["gauges"]["scheduler.tokens"] == summ["tokens"]
    assert snap["gauges"]["scheduler.overlap_efficiency"] == \
        pytest.approx(summ["overlap_efficiency"])


def test_request_timeline(tracer, registry, rng):
    cfg, model, params = _setup()
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    req = Request(uid=0, prompt=rng.integers(0, 128, 8).astype(np.int32),
                  max_new_tokens=5)
    try:
        handle = server.submit(req)
        server.drain()
        tl = server.request_timeline(handle)
    finally:
        server.close()
    assert tl["uid"] == 0 and tl["n_tokens"] == len(handle.tokens)
    assert set(tl["phases"]) == {"queued", "prefill", "decode"}
    for ph in tl["phases"].values():
        assert ph["end"] >= ph["start"] >= 0.0
    assert tl["ttft"] is not None and tl["total"] >= tl["ttft"]
    assert len(tl["tokens"]) == tl["n_tokens"]
    assert tl["itl"]["count"] == tl["n_tokens"] - 1
    # the tracer slice only contains this request's spans
    assert tl["spans"] and all(
        e["args"]["uid"] == 0 for e in tl["spans"])


def test_disabled_server_run_emits_nothing(registry, rng):
    """With the null tracer installed (the default), a full server run
    records zero events — the disabled path costs only no-op calls."""
    assert get_tracer() is NULL_TRACER
    cfg, model, params = _setup()
    server = InferenceServer(model, params, max_slots=1, max_len=64)
    try:
        server.submit(Request(uid=0,
                              prompt=rng.integers(0, 128, 6).astype(np.int32),
                              max_new_tokens=3))
        server.drain()
    finally:
        server.close()
    assert get_tracer().n_events == 0
