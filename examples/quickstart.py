"""Quickstart: the RIPPLE pipeline end to end in ~a minute on CPU.

1. Build a tiny ReLU LM, trace its FFN activations on a token stream.
2. Offline: extract co-activation patterns, search the neuron placement.
3. Online: serve the trace through the flash-offload engine and compare
   I/O latency / bandwidth / run lengths against the llama.cpp-style and
   LLMFlash-style baselines.
4. Artifact: write the placement to disk as a NeuronPack and serve the same
   trace from the FILE with real positional extent reads — modeled I/O
   stats bit-identical to step 3's in-memory RIPPLE arm.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (EngineConfig, OffloadEngine, identity_placement,
                        search_placement, stats_from_masks)
from repro.core.sparse_ffn import FFNWeights, make_bundles
from repro.models import build_model
from repro.store import FileNeuronStore, write_pack


def main() -> None:
    rng = np.random.default_rng(0)
    print("=== 1. tiny ReLU model + activation trace ===")
    cfg = get_config("opt-350m", reduced=True, d_model=128, d_ff=1024,
                     n_layers=2, vocab_size=256)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, 256, (16, 64)), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    masks = np.asarray(out["ffn_pre_act"][0] > 0).reshape(-1, cfg.d_ff)
    print(f"traced {masks.shape[0]} tokens, {cfg.d_ff} neurons, "
          f"sparsity={1 - masks.mean():.1%} (activated {masks.mean():.1%})")

    print("\n=== 2. offline: co-activation -> Hamiltonian-path placement ===")
    stats = stats_from_masks(masks[:512])
    placement = search_placement(stats.distance_matrix(), mode="exact")
    print(f"search: mode={placement.mode} edges={placement.edges_used} "
          f"time={placement.search_seconds:.2f}s")

    print("\n=== 3. online: serve through the flash-offload engine ===")
    sub = params["stack"]["sub_0"]
    w = FFNWeights(w_up=sub["ffn"]["w_up"][0].T, w_down=sub["ffn"]["w_down"][0])
    bundles = np.asarray(make_bundles(w))
    serve_masks = masks[512:900]
    systems = {
        "llama.cpp (split matrices)": (identity_placement(cfg.d_ff),
                                       EngineConfig(collapse=False,
                                                    linking_aligned_cache=False,
                                                    reads_per_bundle=2)),
        "LLMFlash (bundled)": (identity_placement(cfg.d_ff),
                               EngineConfig(collapse=False, linking_aligned_cache=False)),
        "RIPPLE (placement+collapse+cache)": (placement, EngineConfig()),
    }
    results = {}
    for name, (pl, ecfg) in systems.items():
        eng = OffloadEngine(bundles, placement=pl, config=ecfg)
        eng.run_trace(serve_masks)
        results[name] = eng.summary()
    base = results["llama.cpp (split matrices)"]["io_seconds_per_token"]
    for name, s in results.items():
        print(f"  {name:36s} io={s['io_seconds_per_token']*1e6:7.0f}us/tok "
              f"(x{base/s['io_seconds_per_token']:.2f}) run_len={s['mean_run_length']:.2f} "
              f"bw={s['effective_bandwidth']/1e6:.0f}MB/s")

    print("\n=== 4. artifact: NeuronPack on disk -> file-backed serving ===")
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "quickstart.npack")
        manifest = write_pack(path, [bundles], [placement],
                              meta=dict(arch="quickstart"))
        print(f"wrote {manifest['file_bytes']/1e6:.1f}MB pack "
              f"({manifest['n_neurons']} bundles in physical linked order)")
        eng = OffloadEngine.from_store(FileNeuronStore(path, 0),
                                       config=EngineConfig())
        eng.run_trace(serve_masks)
        s = eng.summary()
        mem = results["RIPPLE (placement+collapse+cache)"]
        extents = sum(t.io.measured_ops for t in eng.history)
        meas_ms = sum(t.io.measured_seconds for t in eng.history) * 1e3
        print(f"  file-backed RIPPLE: modeled io={s['io_seconds_per_token']*1e6:7.0f}us/tok "
              f"(in-memory arm: {mem['io_seconds_per_token']*1e6:.0f}us/tok — identical), "
              f"{extents} REAL extent reads in {meas_ms:.1f}ms wall")


if __name__ == "__main__":
    main()
