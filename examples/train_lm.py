"""End-to-end training driver: train a language model on the synthetic corpus.

Default is a CPU-friendly ~10M-param model for 200 steps (a few minutes);
--preset 100m selects a ~100M-param config (the assignment's end-to-end
driver scale) — same code path, longer wall time.

Run: PYTHONPATH=src python examples/train_lm.py [--arch granite-3-2b]
     [--steps 200] [--preset tiny|100m] [--checkpoint ckpt/model.npz]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_data_iter
from repro.models import build_model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train import train_loop
from repro.utils import logger, tree_param_count


PRESETS = {
    # (d_model, n_layers, n_heads, n_kv, d_ff, vocab)
    "tiny": dict(d_model=256, n_layers=4, n_heads=4, n_kv_heads=2,
                 d_ff=1024, vocab_size=2048),
    "100m": dict(d_model=768, n_layers=12, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=32768),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    overrides = dict(PRESETS[args.preset])
    overrides["remat"] = False
    cfg = get_config(args.arch, **overrides)
    model = build_model(cfg)
    import jax
    n_params = tree_param_count(model.init_params(jax.random.PRNGKey(0)))
    logger.info("arch=%s preset=%s params=%.1fM", args.arch, args.preset, n_params / 1e6)

    data = make_data_iter(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                     batch_size=args.batch))
    opt = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)

    def log(step, metrics):
        logger.info("step %4d  loss=%.4f  grad_norm=%.3f  lr=%.2e",
                    step, metrics["loss"], metrics["grad_norm"], metrics["lr"])

    state, history = train_loop(model, data, steps=args.steps, opt_cfg=opt,
                                microbatches=args.microbatches,
                                log_every=max(args.steps // 20, 1), callback=log)
    first, last = history[0]["loss"], history[-1]["loss"]
    logger.info("loss %.4f -> %.4f (delta %.3f) over %d steps",
                first, last, first - last, args.steps)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state.params,
                        {"arch": args.arch, "steps": args.steps, "loss": last})
        logger.info("checkpoint written to %s", args.checkpoint)


if __name__ == "__main__":
    main()
