"""Serve a batch of requests END-TO-END through the RIPPLE offload runtime —
the paper's online scenario: FFN neuron bundles in (simulated UFS) flash,
activation prediction (exact ReLU oracle here), placement-ordered reads,
access collapse, the linking-aligned DRAM cache, and double-buffered
I/O-compute overlap. MHA weights stay resident (paper §4.1).

Every generated token's FFNs are computed from the bundle payloads the engine
actually read, batched across all requests in the decode batch (one merged
extent read per layer per step). The driver compares RIPPLE against the
LLMFlash-style identity-layout baseline and reports per-token compute,
serial I/O, and pipelined (overlapped) latency.

Run: PYTHONPATH=src python examples/serve_offload.py [--tokens 32] [--batch 4]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import EngineConfig, IOScheduler
from repro.models import build_model
from repro.serving.engine import (Request, ServingEngine,
                                  build_offload_runtime)
from repro.serving.server import InferenceServer
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefetch", action="store_true",
                    help="serve the RIPPLE arm through the async layer-ahead "
                         "prefetch pipeline (trained cross-layer lookahead)")
    ap.add_argument("--pack", default=None, metavar="PATH",
                    help="serve the RIPPLE arm from an on-disk NeuronPack "
                         "(REAL positional file reads) instead of the "
                         "synthetic in-memory flash; must have been built "
                         "for this demo's model geometry (d_model=128, "
                         "d_ff=2048, 2 layers) — validated at load")
    args = ap.parse_args()
    if args.pack and args.prefetch:
        raise SystemExit("--pack serves with oracle-depth prefetch only; "
                         "drop --prefetch (packs carry no lookahead "
                         "predictors)")

    # a small ReLU model (the paper's OPT setting, reduced for CPU)
    cfg = get_config("opt-350m", reduced=True, d_model=128, d_ff=2048,
                     n_layers=2, vocab_size=512)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 512, 16).astype(np.int32),
                    max_new_tokens=args.tokens) for i in range(args.batch)]

    logger.info("=== resident baseline (all weights in memory) ===")
    resident = ServingEngine(model, params, max_len=args.tokens + 40)
    res_resident = resident.serve(reqs)

    logger.info("=== offload serving: RIPPLE vs identity-layout baseline ===")
    # throwaway warmup at the measured batch shape so neither arm pays the
    # one-time XLA compilation of the fixed-shape (attention/norm) ops
    warm = build_offload_runtime(model, params, rng=np.random.default_rng(2),
                                 use_placement=False)
    warm_reqs = [Request(uid=r.uid, prompt=r.prompt, max_new_tokens=2)
                 for r in reqs]
    ServingEngine(model, params, max_len=args.tokens + 40, mode="offload",
                  offload=warm).serve(warm_reqs)
    runs = {}
    for name, use_placement in (("RIPPLE", True), ("LLMFlash", False)):
        prefetch = args.prefetch and use_placement
        if use_placement and args.pack:
            # the deployable-artifact path: placements read from the pack,
            # every collapsed extent a real positional file read
            from repro.serving.engine import OffloadedFFNRuntime
            try:
                runtime = OffloadedFFNRuntime.from_pack(cfg, args.pack)
            except ValueError as e:        # geometry validated at load
                raise SystemExit(str(e))
        else:
            runtime = build_offload_runtime(
                model, params, rng=np.random.default_rng(1),
                use_placement=use_placement,
                train_lookahead=prefetch,
                engine_cfg=EngineConfig(collapse=use_placement,
                                        linking_aligned_cache=use_placement))
        engine = ServingEngine(model, params, max_len=args.tokens + 40,
                               mode="offload", offload=runtime,
                               scheduler=IOScheduler(overlap=True),
                               prefetch=prefetch)
        results = engine.serve(reqs)
        runs[name] = (runtime, engine, results)

    n_tok = args.batch * args.tokens
    ripple_results = runs["RIPPLE"][2]
    mismatch = sum(a.tokens != b.tokens
                   for a, b in zip(res_resident, ripple_results))
    logger.info("generated %d tokens/run; offload vs resident mismatched "
                "requests: %d (oracle mask => exact)", n_tok, mismatch)
    for name, (runtime, engine, results) in runs.items():
        s = runtime.io_summary()
        p = engine.scheduler.summary()
        logger.info("%-8s io=%7.2fms/token overlapped=%7.2fms/token "
                    "run_len=%.2f bw=%6.1fMB/s hit=%.2f",
                    name, s["io_seconds_per_token"] * 1e3,
                    p["overlapped_seconds_per_token"] * 1e3,
                    s["mean_run_length"], s["effective_bandwidth"] / 1e6,
                    s["cache_hit_rate"])
    io_r = runs["RIPPLE"][0].io_summary()["io_seconds_per_token"]
    io_b = runs["LLMFlash"][0].io_summary()["io_seconds_per_token"]
    logger.info("I/O speedup RIPPLE vs LLMFlash: %.2fx", io_b / io_r)
    s_ripple = runs["RIPPLE"][0].io_summary()
    if "measured_file_seconds_per_token" in s_ripple:
        logger.info("pack file I/O MEASURED: %.3fms/token over %d real "
                    "extent reads (modeled UFS stays the latency source)",
                    s_ripple["measured_file_seconds_per_token"] * 1e3,
                    s_ripple["measured_extents_total"])
    for r in ripple_results[:2]:
        logger.info("request %d -> %s... (io %.1fms total)", r.uid,
                    r.tokens[:8], r.io_seconds * 1e3)

    # -- continuous batching: mixed lengths, mid-flight admission, streaming --
    logger.info("=== continuous batching (InferenceServer, offload mode) ===")
    runtime = build_offload_runtime(model, params,
                                    rng=np.random.default_rng(1))
    server = InferenceServer(model, params, max_slots=2,
                             max_len=args.tokens + 40, mode="offload",
                             offload=runtime)
    streamed = []
    mixed = [Request(uid=100 + i,
                     prompt=rng.integers(0, 512, 8 + 4 * i).astype(np.int32),
                     max_new_tokens=4 + 2 * i) for i in range(3)]
    try:
        server.submit(mixed[0], on_token=lambda u, t: streamed.append((u, t)))
        server.submit(mixed[1])          # different prompt length, same batch
        for _ in range(3):
            server.step()
        server.submit(mixed[2])          # admitted mid-flight into a freed slot
        results = server.drain()
    finally:
        server.close()
    logger.info("served %d mixed-length requests on 2 slots: %d decode steps, "
                "occupancy %.0f%%, io conserved to %.1fms",
                len(results), server.stats.decode_steps,
                server.stats.occupancy * 100,
                sum(r.io_seconds for r in results) * 1e3)
    logger.info("streamed tokens for request 100: %s (finish=%s)",
                [t for u, t in streamed if u == 100], results[0].finish_reason)


if __name__ == "__main__":
    main()
