"""Serve a model with batched requests THROUGH the RIPPLE offload path —
the paper's end-to-end scenario: FFN weights in (simulated UFS) flash,
activation prediction, placement-ordered reads, access collapse, and the
linking-aligned DRAM cache; MHA weights resident (paper §4.1).

Per generated token the driver reports compute time and simulated I/O time,
for RIPPLE vs the LLMFlash-style baseline.

Run: PYTHONPATH=src python examples/serve_offload.py [--tokens 32] [--batch 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (EngineConfig, identity_placement, search_placement,
                        stats_from_masks)
from repro.core.predictor import PredictorConfig, recall_precision, train_predictor
from repro.core.sparse_ffn import FFNWeights, make_bundles
from repro.models import build_model
from repro.serving.engine import OffloadedFFNRuntime, Request, ServingEngine
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--calib-tokens", type=int, default=768)
    args = ap.parse_args()

    # a small ReLU model (the paper's OPT setting, reduced for CPU)
    cfg = get_config("opt-350m", reduced=True, d_model=128, d_ff=2048,
                     n_layers=2, vocab_size=512)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    logger.info("=== calibration: trace activations + train predictors ===")
    tokens = jnp.asarray(rng.integers(0, 512, (args.calib_tokens // 64, 64)), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    L = cfg.n_layers
    masks = [np.asarray(out["ffn_pre_act"][l] > 0).reshape(-1, cfg.d_ff) for l in range(L)]
    logger.info("activated fraction per layer: %s",
                [f"{m.mean():.1%}" for m in masks])

    placements = []
    for l in range(L):
        pl = search_placement(stats_from_masks(masks[l]).distance_matrix(), mode="auto")
        placements.append(pl)
        logger.info("layer %d placement: %d edges in %.2fs", l, pl.edges_used,
                    pl.search_seconds)

    bundles = []
    for l in range(L):
        sub = params["stack"]["sub_0"]
        w = FFNWeights(w_up=sub["ffn"]["w_up"][l].T, w_down=sub["ffn"]["w_down"][l])
        bundles.append(np.asarray(make_bundles(w)))

    logger.info("=== serve %d requests x %d new tokens ===", args.batch, args.tokens)
    ripple = OffloadedFFNRuntime(cfg, bundles, placements)
    base = OffloadedFFNRuntime(cfg, bundles, [identity_placement(cfg.d_ff)] * L,
                               engine_cfg=EngineConfig(collapse=False,
                                                       linking_aligned_cache=False))
    engine = ServingEngine(model, params, max_len=args.tokens + 40)
    reqs = [Request(uid=i, prompt=rng.integers(0, 512, 16).astype(np.int32),
                    max_new_tokens=args.tokens) for i in range(args.batch)]
    t0 = time.perf_counter()
    results = engine.serve(reqs)
    compute_s = time.perf_counter() - t0

    # account the offload I/O for every generated token's FFN activations
    h_stream = rng.standard_normal((args.batch * args.tokens, cfg.d_model)).astype(np.float32)
    for runtime in (ripple, base):
        for h in h_stream:
            for l in range(L):
                sub = params["stack"]["sub_0"]
                w_up = np.asarray(sub["ffn"]["w_up"][l]).T
                mask = (h[None] @ w_up.T) > 0
                runtime.ffn_apply(l, h[None], oracle_mask=mask)
    s_r, s_b = ripple.io_summary(), base.io_summary()
    n_tok = args.batch * args.tokens
    logger.info("generated %d tokens; compute %.1fms/token", n_tok,
                compute_s / n_tok * 1e3)
    logger.info("RIPPLE   io=%7.2fms/token run_len=%.2f bw=%6.1fMB/s hit=%.2f",
                s_r["io_seconds_per_token"] * 1e3, s_r["mean_run_length"],
                s_r["effective_bandwidth"] / 1e6, s_r["cache_hit_rate"])
    logger.info("LLMFlash io=%7.2fms/token run_len=%.2f bw=%6.1fMB/s hit=%.2f",
                s_b["io_seconds_per_token"] * 1e3, s_b["mean_run_length"],
                s_b["effective_bandwidth"] / 1e6, s_b["cache_hit_rate"])
    logger.info("I/O speedup: %.2fx",
                s_b["io_seconds_per_token"] / s_r["io_seconds_per_token"])
    for r in results[:2]:
        logger.info("request %d -> %s...", r.uid, r.tokens[:8])


if __name__ == "__main__":
    main()
