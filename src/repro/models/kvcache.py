"""KV caches (full and sliding-window ring) and cached-attention helpers."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import gqa_attend, rope


class KVCache(NamedTuple):
    """Full cache: slot s holds position s. k/v: [B, S_max, KV, hd]."""
    k: jnp.ndarray
    v: jnp.ndarray


class QuantKVCache(NamedTuple):
    """int8 full cache (§Perf A3): halves the dominant KV-streaming bytes of
    batched decode. Symmetric per-(slot, head) quantisation; scales bf16."""
    k: jnp.ndarray        # int8 [B, S_max, KV, hd]
    v: jnp.ndarray
    k_scale: jnp.ndarray  # [B, S_max, KV]
    v_scale: jnp.ndarray


class SWACache(NamedTuple):
    """Sliding-window ring: slot = pos % W. pos: [B, W] (-1 = empty)."""
    k: jnp.ndarray   # [B, W, KV, hd]
    v: jnp.ndarray
    pos: jnp.ndarray


class PagedKVCache(NamedTuple):
    """Paged arena (vLLM-style): physical page p, offset o holds one KV row.

    There is no batch axis — requests own disjoint sets of pages through
    per-request page tables (`serving/paging.py`), so one arena serves every
    slot. The LAST physical page (index num_pages) is the reserved null page:
    page-table entries of inactive slots / unallocated logical pages point at
    it, so garbage decode writes land somewhere harmless instead of clobbering
    a live page."""
    k: jnp.ndarray   # [num_pages + 1, page_size, KV, hd]
    v: jnp.ndarray

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


class PagedQuantKVCache(NamedTuple):
    """int8 paged arena with per-page-row scales (the `QuantKVCache` layout
    re-cut along page boundaries): quantisation is per (page, offset, head),
    identical math to `quant_kv_write_rows`, so paged int8 decode is
    bit-identical to the contiguous int8 path."""
    k: jnp.ndarray        # int8 [num_pages + 1, page_size, KV, hd]
    v: jnp.ndarray
    k_scale: jnp.ndarray  # [num_pages + 1, page_size, KV]
    v_scale: jnp.ndarray

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, max_len: int, cfg: ModelConfig, dtype=None) -> KVCache:
    dtype = dtype or cfg.dtype()
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def init_swa_cache(batch: int, cfg: ModelConfig, dtype=None, window: int = 0) -> SWACache:
    dtype = dtype or cfg.dtype()
    W = window or cfg.sliding_window
    shape = (batch, W, cfg.n_kv_heads, cfg.head_dim)
    return SWACache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.full((batch, W), -1, jnp.int32),
    )


def init_quant_kv_cache(batch: int, max_len: int, cfg: ModelConfig,
                        scale_dtype=jnp.bfloat16) -> QuantKVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return QuantKVCache(
        k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
        k_scale=jnp.zeros(shape[:3], scale_dtype),
        v_scale=jnp.zeros(shape[:3], scale_dtype),
    )


def init_paged_kv_cache(num_pages: int, page_size: int, cfg: ModelConfig,
                        dtype=None) -> PagedKVCache:
    """Arena with `num_pages` allocatable pages + the trailing null page."""
    dtype = dtype or cfg.dtype()
    shape = (num_pages + 1, page_size, cfg.n_kv_heads, cfg.head_dim)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def init_paged_quant_kv_cache(num_pages: int, page_size: int, cfg: ModelConfig,
                              scale_dtype=jnp.bfloat16) -> PagedQuantKVCache:
    shape = (num_pages + 1, page_size, cfg.n_kv_heads, cfg.head_dim)
    return PagedQuantKVCache(
        k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
        k_scale=jnp.zeros(shape[:3], scale_dtype),
        v_scale=jnp.zeros(shape[:3], scale_dtype),
    )


# -- writes -------------------------------------------------------------------

def kv_write(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray, start) -> KVCache:
    """Write [B, T, KV, hd] at slots [start, start+T)."""
    idx = (0, start, 0, 0)
    return KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), idx),
        v=jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), idx),
    )


def _row_slots(k_new: jnp.ndarray, positions: jnp.ndarray):
    """Per-row scatter indices for [B, T, ...] writes starting at positions[b]."""
    B, T = k_new.shape[0], k_new.shape[1]
    rows = jnp.arange(B)[:, None]
    slots = positions.astype(jnp.int32)[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    return rows, slots


def kv_write_rows(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                  positions: jnp.ndarray) -> KVCache:
    """Per-row write: [B, T, KV, hd] at slots [positions[b], positions[b]+T).

    The continuous-batching decode path: every slot of the batch sits at its
    own sequence position, so the write start is a [B] vector instead of the
    shared scalar `kv_write` takes."""
    rows, slots = _row_slots(k_new, positions)
    return KVCache(
        k=cache.k.at[rows, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[rows, slots].set(v_new.astype(cache.v.dtype)),
    )


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, KV, hd] -> (int8 values, per-[B,T,KV] scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def quant_kv_write(cache: QuantKVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                   start) -> QuantKVCache:
    kq, ks = _quantize(k_new)
    vq, vs = _quantize(v_new)
    idx4 = (0, start, 0, 0)
    idx3 = (0, start, 0)
    return QuantKVCache(
        k=jax.lax.dynamic_update_slice(cache.k, kq, idx4),
        v=jax.lax.dynamic_update_slice(cache.v, vq, idx4),
        k_scale=jax.lax.dynamic_update_slice(
            cache.k_scale, ks.astype(cache.k_scale.dtype), idx3),
        v_scale=jax.lax.dynamic_update_slice(
            cache.v_scale, vs.astype(cache.v_scale.dtype), idx3),
    )


def quant_kv_write_rows(cache: QuantKVCache, k_new: jnp.ndarray,
                        v_new: jnp.ndarray, positions: jnp.ndarray) -> QuantKVCache:
    """Per-row variant of `quant_kv_write` (see `kv_write_rows`)."""
    kq, ks = _quantize(k_new)
    vq, vs = _quantize(v_new)
    rows, slots = _row_slots(k_new, positions)
    return QuantKVCache(
        k=cache.k.at[rows, slots].set(kq),
        v=cache.v.at[rows, slots].set(vq),
        k_scale=cache.k_scale.at[rows, slots].set(ks.astype(cache.k_scale.dtype)),
        v_scale=cache.v_scale.at[rows, slots].set(vs.astype(cache.v_scale.dtype)),
    )


def swa_write(cache: SWACache, k_new: jnp.ndarray, v_new: jnp.ndarray,
              positions: jnp.ndarray) -> SWACache:
    """Scatter [B, T, KV, hd] at ring slots positions % W. positions: [B, T]."""
    W = cache.k.shape[1]
    T = k_new.shape[1]
    slots = positions % W                                    # [B, T]
    bidx = jnp.arange(cache.k.shape[0])[:, None]
    # keep only the last W entries if T > W (earlier ones would be overwritten)
    if T > W:
        k_new, v_new = k_new[:, -W:], v_new[:, -W:]
        positions, slots = positions[:, -W:], slots[:, -W:]
    return SWACache(
        k=cache.k.at[bidx, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[bidx, slots].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[bidx, slots].set(positions),
    )


# -- cached attention ----------------------------------------------------------

def attend_full_cache(q: jnp.ndarray, cache, q_pos: jnp.ndarray) -> jnp.ndarray:
    """q: [B, T, H, hd] (rope applied); q_pos: [B, T]. Causal over filled slots.

    Accepts KVCache or QuantKVCache (dequant fuses into the attention matmul)."""
    B, S = cache.k.shape[0], cache.k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if isinstance(cache, QuantKVCache):
        k = cache.k.astype(q.dtype) * cache.k_scale[..., None].astype(q.dtype)
        v = cache.v.astype(q.dtype) * cache.v_scale[..., None].astype(q.dtype)
        return gqa_attend(q, k, v, q_pos, k_pos, causal=True)
    return gqa_attend(q, cache.k, cache.v, q_pos, k_pos, causal=True)


def attend_swa_cache(q: jnp.ndarray, cache: SWACache, q_pos: jnp.ndarray,
                     window: int) -> jnp.ndarray:
    """Sliding-window attention against the ring buffer."""
    valid = cache.pos >= 0
    return gqa_attend(q, cache.k, cache.v, q_pos, cache.pos,
                      k_valid=valid, causal=True, window=window)


# -- paged writes / attention --------------------------------------------------

def _paged_targets(positions: jnp.ndarray, page_tables: jnp.ndarray,
                   page_size: int):
    """(physical page, offset) write target per batch row for a one-token
    decode write at `positions[b]`. Inactive rows' page tables point every
    logical page at the null page, so their (garbage) writes collide there
    harmlessly instead of hitting a live page."""
    pos = positions.astype(jnp.int32)
    rows = jnp.arange(page_tables.shape[0], dtype=jnp.int32)
    phys = page_tables[rows, pos // page_size]
    return phys, pos % page_size


def paged_kv_write_rows(cache: PagedKVCache, k_new: jnp.ndarray,
                        v_new: jnp.ndarray, positions: jnp.ndarray,
                        page_tables: jnp.ndarray) -> PagedKVCache:
    """Page-scatter decode write: [B, 1, KV, hd] at positions[b], routed
    through the per-request page tables [B, max_pages]. The paged twin of
    `kv_write_rows` (T == 1: continuous-batching decode writes one row per
    slot per step; prompt pages are block-copied by `PagePool.write_prompt`)."""
    assert k_new.shape[1] == 1, "paged decode writes one token per step"
    phys, off = _paged_targets(positions, page_tables, cache.page_size)
    return PagedKVCache(
        k=cache.k.at[phys, off].set(k_new[:, 0].astype(cache.k.dtype)),
        v=cache.v.at[phys, off].set(v_new[:, 0].astype(cache.v.dtype)),
    )


def paged_quant_kv_write_rows(cache: PagedQuantKVCache, k_new: jnp.ndarray,
                              v_new: jnp.ndarray, positions: jnp.ndarray,
                              page_tables: jnp.ndarray) -> PagedQuantKVCache:
    """Paged twin of `quant_kv_write_rows`: same per-row symmetric int8
    quantisation, scattered to (page, offset) instead of (row, slot)."""
    assert k_new.shape[1] == 1, "paged decode writes one token per step"
    kq, ks = _quantize(k_new)
    vq, vs = _quantize(v_new)
    phys, off = _paged_targets(positions, page_tables, cache.page_size)
    return PagedQuantKVCache(
        k=cache.k.at[phys, off].set(kq[:, 0]),
        v=cache.v.at[phys, off].set(vq[:, 0]),
        k_scale=cache.k_scale.at[phys, off].set(
            ks[:, 0].astype(cache.k_scale.dtype)),
        v_scale=cache.v_scale.at[phys, off].set(
            vs[:, 0].astype(cache.v_scale.dtype)),
    )


def paged_gather_kv(cache, page_tables: jnp.ndarray):
    """Gather each row's pages into a contiguous [B, S, KV, hd] view, where
    S = max_pages * page_size and slot s holds position s — the same layout
    `attend_full_cache` sees, so identical attention math applies. Gathered
    rows past a request's current position hold whatever the page last held
    (null-page trash for unallocated logical pages); causal masking hides
    them exactly as it hides stale contiguous-cache slots."""
    B = page_tables.shape[0]
    P = cache.page_size
    gather = lambda a: a[page_tables].reshape((B, page_tables.shape[1] * P)
                                              + a.shape[2:])
    if isinstance(cache, PagedQuantKVCache):
        return (gather(cache.k), gather(cache.v),
                gather(cache.k_scale), gather(cache.v_scale))
    return gather(cache.k), gather(cache.v)


def attend_paged_cache(q: jnp.ndarray, cache, q_pos: jnp.ndarray,
                       page_tables: jnp.ndarray) -> jnp.ndarray:
    """Paged twin of `attend_full_cache`: gather pages, then the identical
    causal GQA math (same masking, same einsum contraction order), so a paged
    layout reproduces the contiguous cache bitwise. Accepts PagedKVCache or
    PagedQuantKVCache (dequant applied post-gather, pre-attention, exactly as
    the contiguous quant path does)."""
    B = q.shape[0]
    S = page_tables.shape[1] * cache.page_size
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if isinstance(cache, PagedQuantKVCache):
        k, v, ks, vs = paged_gather_kv(cache, page_tables)
        k = k.astype(q.dtype) * ks[..., None].astype(q.dtype)
        v = v.astype(q.dtype) * vs[..., None].astype(q.dtype)
        return gqa_attend(q, k, v, q_pos, k_pos, causal=True)
    k, v = paged_gather_kv(cache, page_tables)
    return gqa_attend(q, k, v, q_pos, k_pos, causal=True)
