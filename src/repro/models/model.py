"""Model facade: build any assigned architecture from its ModelConfig.

Entry points (all pure functions of (params, batch)):
  init_params(key)                       — real parameter init
  forward(params, batch, capture=False)  — logits for training/eval
  loss_fn(params, batch)                 — (loss, aux) next-token CE
  init_cache(batch, max_len, swa=...)    — decode cache pytree
  prefill(params, batch, cache)          — (logits_last, cache)
  decode_step(params, tokens, position, cache) — (logits, cache)

Batch dict keys: "tokens" [B,S] int32 (targets = tokens shifted, with
batch.get("loss_mask")); "patch_feats" [B,P,d_frontend] (vlm);
"frames" [B,F,d_frontend] (audio).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import (apply_norm, embed_tokens, init_embedding,
                                 init_norm, unembed)

Params = Dict[str, Any]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_stack, k_extra = jax.random.split(key, 3)
        params: Params = {"embed": init_embedding(k_emb, cfg)}
        if cfg.is_encdec:
            k_enc, k_dec = jax.random.split(k_stack)
            params["encoder"] = encdec.init_encoder(k_enc, cfg)
            params["decoder"] = encdec.init_decoder(k_dec, cfg)
        else:
            params["stack"] = transformer.init_stack(k_stack, cfg)
            params["final_norm"] = init_norm(cfg)
        if cfg.family == "vlm":
            k1, k2 = jax.random.split(k_extra)
            params["projector"] = {
                "w1": jax.random.normal(k1, (cfg.d_frontend, cfg.d_model),
                                        cfg.pdtype()) * cfg.d_frontend ** -0.5,
                "w2": jax.random.normal(k2, (cfg.d_model, cfg.d_model),
                                        cfg.pdtype()) * cfg.d_model ** -0.5,
            }
        return params

    # -- shared pieces ---------------------------------------------------------
    def _embed_inputs(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Token (+ prefix) embeddings and positions for decoder-only families."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        x = embed_tokens(params["embed"], tokens, cfg)
        if cfg.family == "vlm":
            pf = batch["patch_feats"].astype(cfg.dtype())
            proj = jax.nn.gelu(pf @ params["projector"]["w1"].astype(cfg.dtype()))
            proj = proj @ params["projector"]["w2"].astype(cfg.dtype())
            x = jnp.concatenate([proj, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, positions

    # -- training forward --------------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                capture_activations: bool = False, window: int = 0):
        cfg = self.cfg
        if cfg.is_encdec:
            memory = encdec.encoder_forward(params["encoder"], batch["frames"], cfg)
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = embed_tokens(params["embed"], tokens, cfg)
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            h = encdec.decoder_forward(params["decoder"], x, positions, memory, cfg,
                                       window=window)
            logits = unembed(params["embed"], h, cfg)
            return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}
        x, positions = self._embed_inputs(params, batch)
        out = transformer.stack_forward(params["stack"], x, positions, cfg,
                                        window=window,
                                        capture_activations=capture_activations)
        h = apply_norm(params["final_norm"], out.x, cfg)
        if cfg.family == "vlm":           # only text positions produce logits
            n_prefix = batch["patch_feats"].shape[1]
            h = h[:, n_prefix:]
        logits = unembed(params["embed"], h, cfg)
        res = {"logits": logits, "aux_loss": out.aux_loss, "hidden": h}
        if capture_activations:
            res["ffn_pre_act"] = out.ffn_pre_act
            res["ffn_inputs"] = out.ffn_inputs
        return res

    def _hidden_and_aux(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Final hidden states (pre-unembed) — the chunked-CE path avoids ever
        materialising full-sequence logits (§Perf X3)."""
        cfg = self.cfg
        if cfg.is_encdec:
            memory = encdec.encoder_forward(params["encoder"], batch["frames"], cfg)
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = embed_tokens(params["embed"], tokens, cfg)
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            h = encdec.decoder_forward(params["decoder"], x, positions, memory, cfg)
            return h, jnp.zeros((), jnp.float32)
        x, positions = self._embed_inputs(params, batch)
        out = transformer.stack_forward(params["stack"], x, positions, cfg)
        h = apply_norm(params["final_norm"], out.x, cfg)
        if cfg.family == "vlm":
            h = h[:, batch["patch_feats"].shape[1]:]
        return h, out.aux_loss

    CE_CHUNK = 512

    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Next-token CE with a CHUNKED lm_head (§Perf X3): the vocab
        projection + softmax statistics run per sequence-chunk inside a
        rematerialised scan, so peak logits memory is [B, chunk, V] instead of
        [B, T, V] — decisive for the 256 k-vocab archs (full-sequence f32
        logits for seamless train_4k would be ~250 GiB/device).
        The target logit uses an iota-compare select-reduce, never a vocab
        gather (which would all-gather tensor-parallel lm_head shards)."""
        cfg = self.cfg
        h, aux = self._hidden_and_aux(params, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        h = h[:, :-1]
        mask = batch.get("loss_mask")
        mask = (mask[:, 1:] if mask is not None
                else jnp.ones_like(targets, jnp.float32)).astype(jnp.float32)
        B, T, d = h.shape
        chunk = min(self.CE_CHUNK, T)
        pad = (-T) % chunk
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = (T + pad) // chunk
        hc = jnp.moveaxis(h.reshape(B, n, chunk, d), 1, 0)
        tc = jnp.moveaxis(targets.reshape(B, n, chunk), 1, 0)
        mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

        @jax.checkpoint
        def chunk_fn(carry, inp):
            h_c, t_c, m_c = inp
            logits = unembed(params["embed"], h_c, cfg)          # [B, chunk, V]
            maxl = jax.lax.stop_gradient(
                jnp.max(logits, axis=-1, keepdims=True)).astype(jnp.float32)
            shifted = logits.astype(jnp.float32) - maxl
            logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
            iota = jnp.arange(logits.shape[-1], dtype=t_c.dtype)
            tgt = jnp.sum(jnp.where(t_c[..., None] == iota, shifted, 0.0), axis=-1)
            ce_sum, m_sum = carry
            ce_sum = ce_sum + jnp.sum((logz - tgt) * m_c)
            return (ce_sum, m_sum + jnp.sum(m_c)), None

        (ce_sum, m_sum), _ = jax.lax.scan(
            chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, tc, mc))
        loss = ce_sum / jnp.maximum(m_sum, 1.0)
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
        return loss, {"ce": loss, "aux_loss": aux}

    # -- serving -------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, swa: bool = False,
                   n_frames: int = 0, dtype=None) -> Any:
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.init_decoder_cache(cfg, batch, max_len,
                                             n_frames or cfg.n_prefix_tokens,
                                             swa=swa, dtype=dtype)
        return transformer.init_stack_cache(cfg, batch, max_len, swa=swa, dtype=dtype)

    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=None) -> Any:
        """Paged KV arena (decoder-only, attention-only stacks; see
        `transformer.init_paged_stack_cache` for the layout and the
        ValueError surface)."""
        if self.cfg.is_encdec:
            raise ValueError("paged KV cache covers decoder-only stacks")
        return transformer.init_paged_stack_cache(self.cfg, num_pages,
                                                  page_size, dtype=dtype)

    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray], cache: Any,
                window: int = 0) -> Tuple[jnp.ndarray, Any]:
        cfg = self.cfg
        if cfg.is_encdec:
            memory = encdec.encoder_forward(params["encoder"], batch["frames"], cfg)
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = embed_tokens(params["embed"], tokens, cfg)
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            h, cache = encdec.decoder_prefill(params["decoder"], x, positions, memory,
                                              cache, cfg, window=window)
            logits = unembed(params["embed"], h[:, -1:], cfg)
            return logits, cache
        x, positions = self._embed_inputs(params, batch)
        h, cache = transformer.stack_prefill(params["stack"], x, positions, cache,
                                             cfg, window=window)
        h = apply_norm(params["final_norm"], h[:, -1:], cfg)
        logits = unembed(params["embed"], h, cfg)
        return logits, cache

    def decode_step(self, params: Params, tokens: jnp.ndarray, position: jnp.ndarray,
                    cache: Any, window: int = 0,
                    page_tables: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Any]:
        """tokens: [B, 1]; position: scalar int32 shared by the batch, or a
        [B] int32 vector of per-slot positions (continuous-batching decode,
        decoder-only stacks only — the encdec path takes the shared scalar).
        `page_tables` [B, max_pages] routes a paged cache pytree (from
        `init_paged_cache`) through per-request page tables."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        if cfg.is_encdec:
            if page_tables is not None:
                raise ValueError("paged KV cache covers decoder-only stacks")
            h, cache = encdec.decoder_decode_step(params["decoder"], x, position,
                                                  cache, cfg, window=window)
        else:
            h, cache = transformer.stack_decode_step(params["stack"], x, position,
                                                     cache, cfg, window=window,
                                                     page_tables=page_tables)
            h = apply_norm(params["final_norm"], h, cfg)
        logits = unembed(params["embed"], h, cfg)
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
