"""Encoder-decoder stack (seamless-m4t family).

Encoder: bidirectional self-attention + FFN over stub frontend frame embeddings
(the conv/mel frontend is a stub per the assignment — `input_specs` supplies
[B, F, d_frontend] features; we implement the projector + transformer).
Decoder: causal self-attention (cached), cross-attention to encoder memory
(K/V precomputed at prefill), FFN. Both stacks are scanned over layers.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.kvcache import (KVCache, SWACache, attend_full_cache,
                                  attend_swa_cache, init_kv_cache,
                                  init_swa_cache, kv_write, swa_write)
from repro.models.layers import (_project_qkv, apply_norm, attention_forward,
                                 cross_attention_forward, ffn_forward,
                                 init_attention, init_ffn, init_norm,
                                 project_memory_kv, rope)

Params = Dict[str, Any]


def init_encoder(key: jax.Array, cfg: ModelConfig) -> Params:
    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": init_norm(cfg), "attn": init_attention(k1, cfg),
            "norm2": init_norm(cfg), "ffn": init_ffn(k2, cfg),
        }
    keys = jax.random.split(key, cfg.n_enc_layers)
    layers = jax.vmap(one)(keys)
    kp = jax.random.fold_in(key, 99)
    return {
        "frontend_proj": jax.random.normal(
            kp, (cfg.d_frontend, cfg.d_model), cfg.pdtype()) * cfg.d_frontend ** -0.5,
        "layers": layers,
        "final_norm": init_norm(cfg),
    }


def encoder_forward(p: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: [B, F, d_frontend] stub features -> [B, F, d_model] memory."""
    x = (frames.astype(cfg.dtype()) @ p["frontend_proj"].astype(cfg.dtype()))
    B, F = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def layer_fn(h, lp):
        a = attention_forward(lp["attn"], apply_norm(lp["norm1"], h, cfg),
                              positions, cfg, causal=False)
        h = h + a
        y, _ = ffn_forward(lp["ffn"], apply_norm(lp["norm2"], h, cfg), cfg)
        return h + y, None

    fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    x, _ = jax.lax.scan(fn, x, p["layers"])
    return apply_norm(p["final_norm"], x, cfg)


def init_decoder(key: jax.Array, cfg: ModelConfig) -> Params:
    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": init_norm(cfg), "self_attn": init_attention(k1, cfg),
            "norm_x": init_norm(cfg), "cross_attn": init_attention(k2, cfg, cross=True),
            "norm2": init_norm(cfg), "ffn": init_ffn(k3, cfg),
        }
    keys = jax.random.split(key, cfg.n_layers)
    return {"layers": jax.vmap(one)(keys), "final_norm": init_norm(cfg)}


def decoder_forward(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                    memory: jnp.ndarray, cfg: ModelConfig, window: int = 0) -> jnp.ndarray:
    """Teacher-forced decode over full target sequence (training)."""

    def layer_fn(h, lp):
        a = attention_forward(lp["self_attn"], apply_norm(lp["norm1"], h, cfg),
                              positions, cfg, causal=True, window=window)
        h = h + a
        mk, mv = project_memory_kv(lp["cross_attn"], memory, cfg)
        c = cross_attention_forward(lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg),
                                    mk, mv, cfg)
        h = h + c
        y, _ = ffn_forward(lp["ffn"], apply_norm(lp["norm2"], h, cfg), cfg)
        return h + y, None

    fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    x, _ = jax.lax.scan(fn, x, p["layers"])
    return apply_norm(p["final_norm"], x, cfg)


class DecoderCache(NamedTuple):
    self_kv: Any          # KVCache or SWACache, leaves stacked [L, ...]
    mem_k: jnp.ndarray    # [L, B, F, KV, hd]
    mem_v: jnp.ndarray


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int, n_frames: int,
                       swa: bool = False, dtype=None) -> DecoderCache:
    dtype = dtype or cfg.dtype()
    L = cfg.n_layers

    def stacked(one):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)

    self_kv = stacked(init_swa_cache(batch, cfg, dtype) if swa
                      else init_kv_cache(batch, max_len, cfg, dtype))
    mem = jnp.zeros((L, batch, n_frames, cfg.n_kv_heads, cfg.head_dim), dtype)
    return DecoderCache(self_kv=self_kv, mem_k=mem, mem_v=mem)


def decoder_prefill(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                    memory: jnp.ndarray, cache: DecoderCache, cfg: ModelConfig,
                    window: int = 0) -> Tuple[jnp.ndarray, DecoderCache]:
    """Fill self-attn cache with the prompt and precompute cross K/V."""

    def layer_fn(h, inp):
        lp, kv, _, _ = inp
        normed = apply_norm(lp["norm1"], h, cfg)
        q, k, v = _project_qkv(lp["self_attn"], normed, normed, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        from repro.models.layers import (FLASH_SEQ_THRESHOLD, flash_gqa_attend,
                                         gqa_attend)
        if normed.shape[1] > FLASH_SEQ_THRESHOLD:
            a = flash_gqa_attend(q, k, v, positions, positions, causal=True,
                                 window=window, q_chunk=cfg.flash_q_chunk,
                                 k_chunk=cfg.flash_k_chunk)
        else:
            a = gqa_attend(q, k, v, positions, positions, causal=True, window=window)
        if isinstance(kv, SWACache):
            kv = swa_write(kv, k, v, positions)
        else:
            kv = kv_write(kv, k, v, 0)
        h = h + a @ lp["self_attn"]["wo"]
        mk, mv = project_memory_kv(lp["cross_attn"], memory, cfg)
        c = cross_attention_forward(lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg),
                                    mk, mv, cfg)
        h = h + c
        y, _ = ffn_forward(lp["ffn"], apply_norm(lp["norm2"], h, cfg), cfg)
        return h + y, (kv, mk, mv)

    x, (kv, mk, mv) = jax.lax.scan(layer_fn, x, (p["layers"], cache.self_kv,
                                                 cache.mem_k, cache.mem_v))
    x = apply_norm(p["final_norm"], x, cfg)
    return x, DecoderCache(self_kv=kv, mem_k=mk, mem_v=mv)


def decoder_decode_step(p: Params, x: jnp.ndarray, position: jnp.ndarray,
                        cache: DecoderCache, cfg: ModelConfig,
                        window: int = 0) -> Tuple[jnp.ndarray, DecoderCache]:
    B = x.shape[0]
    pos_arr = jnp.broadcast_to(position.astype(jnp.int32), (B, 1))

    def layer_fn(h, inp):
        lp, kv, mk, mv = inp
        normed = apply_norm(lp["norm1"], h, cfg)
        q, k, v = _project_qkv(lp["self_attn"], normed, normed, cfg)
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
        if isinstance(kv, SWACache):
            kv = swa_write(kv, k, v, pos_arr)
            a = attend_swa_cache(q, kv, pos_arr, window or cfg.sliding_window)
        else:
            kv = kv_write(kv, k, v, position)
            a = attend_full_cache(q, kv, pos_arr)
        h = h + a @ lp["self_attn"]["wo"]
        c = cross_attention_forward(lp["cross_attn"], apply_norm(lp["norm_x"], h, cfg),
                                    mk, mv, cfg)
        h = h + c
        y, _ = ffn_forward(lp["ffn"], apply_norm(lp["norm2"], h, cfg), cfg)
        return h + y, kv

    x, kv = jax.lax.scan(layer_fn, x, (p["layers"], cache.self_kv,
                                       cache.mem_k, cache.mem_v))
    x = apply_norm(p["final_norm"], x, cfg)
    return x, DecoderCache(self_kv=kv, mem_k=cache.mem_k, mem_v=cache.mem_v)
