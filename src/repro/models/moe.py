"""Mixture-of-Experts FFN: top-k router + capacity-based sort-free dispatch.

GShard-style expert-parallel formulation adapted for pjit: tokens are placed
into per-expert capacity slots via one-hot cumsum ranking, experts run as a
batched einsum over the expert axis (sharded on the mesh "model" axis), and
outputs are combined with router weights. FLOPs scale with top_k x capacity
factor — NOT with n_experts — so the roofline's MODEL_FLOPS ratio stays honest.

Load-balance auxiliary loss follows Switch/GShard: E * sum_e(mean_router_prob_e
* frac_tokens_e).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig

Params = Dict[str, jnp.ndarray]


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, E), cfg.pdtype()) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (E, d, f), cfg.pdtype()) * d ** -0.5,
        "w_up": jax.random.normal(ks[2], (E, d, f), cfg.pdtype()) * d ** -0.5,
        "w_down": jax.random.normal(ks[3], (E, f, d), cfg.pdtype()) * f ** -0.5,
    }


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(4, -(-c // 4) * 4)   # round up to a multiple of 4


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    assert cfg.moe is not None
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = _capacity(T, m)
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(cfg.dtype())).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, K)                                  # [T, K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e mean_prob_e * frac_routed_e
    sel_one_hot = jax.nn.one_hot(sel, E, dtype=jnp.float32).sum(axis=1)    # [T, E]
    frac_routed = sel_one_hot.mean(axis=0) / K
    aux = E * jnp.sum(probs.mean(axis=0) * frac_routed)

    # capacity ranking: position of each (token, k) within its expert's queue
    flat_sel = sel.reshape(-1)                                             # [T*K]
    onehot = jax.nn.one_hot(flat_sel, E, dtype=jnp.int32)                  # [T*K, E]
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)                       # [T*K, E]
    slot = jnp.take_along_axis(pos_in_expert, flat_sel[:, None], axis=1)[:, 0]   # [T*K]
    keep = slot < C                                                         # overflow drops

    token_idx = jnp.repeat(jnp.arange(T), K)                               # [T*K]
    # scatter (expert, slot) <- token index
    slot_token = jnp.full((E, C), T, dtype=jnp.int32)                      # T = sentinel (pad row)
    slot_token = slot_token.at[flat_sel, jnp.where(keep, slot, C - 1)].set(
        jnp.where(keep, token_idx, T).astype(jnp.int32), mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)    # sentinel row
    xe = xt_pad[slot_token]                                                # [E, C, d]

    # expert FFN (batched over experts; expert axis sharded on "model")
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cfg.dtype()))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(cfg.dtype()))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cfg.dtype()))    # [E, C, d]

    # combine: scatter-add expert outputs back to tokens with router weights
    gate_flat = gate_w.reshape(-1)                                         # [T*K]
    w_slot = jnp.zeros((E, C), dtype=jnp.float32)
    w_slot = w_slot.at[flat_sel, jnp.where(keep, slot, C - 1)].set(
        jnp.where(keep, gate_flat, 0.0), mode="drop")
    y = jnp.zeros((T + 1, d), ye.dtype)
    y = y.at[slot_token.reshape(-1)].add(
        (ye * w_slot[..., None].astype(ye.dtype)).reshape(E * C, d), mode="drop")
    return y[:T].reshape(B, S, d), aux.astype(jnp.float32)


def moe_forward_dense_einsum(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference oracle: compute ALL experts densely, weight by router gates.

    O(E) FLOPs — used only in tests to validate the dispatch path (the two
    agree exactly when no token overflows capacity).
    """
    assert cfg.moe is not None
    m = cfg.moe
    B, S, d = x.shape
    logits = (x.reshape(-1, d) @ p["router"].astype(cfg.dtype())).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, m.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    dense_gates = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], sel].set(gate_w)               # [T, E]
    xt = x.reshape(-1, d)
    h = jnp.einsum("td,edf->etf", xt, p["w_gate"].astype(cfg.dtype()))
    h = jax.nn.silu(h) * jnp.einsum("td,edf->etf", xt, p["w_up"].astype(cfg.dtype()))
    ye = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(cfg.dtype()))     # [E, T, d]
    y = jnp.einsum("etd,te->td", ye, dense_gates.astype(ye.dtype))
    sel_one_hot = jax.nn.one_hot(sel, m.n_experts, dtype=jnp.float32).sum(axis=1)
    frac = sel_one_hot.mean(axis=0) / m.top_k
    aux = m.n_experts * jnp.sum(probs.mean(axis=0) * frac)
    return y.reshape(B, S, d), aux.astype(jnp.float32)
