"""Transformer building blocks: norms, RoPE, GQA attention, FFN, embeddings.

Pure-function style: params are plain dicts of jnp arrays; every forward takes
the ModelConfig. Attention covers full-causal, sliding-window, bidirectional
(encoder), cross-attention, and single-step decode against a KV cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


# -- norms -------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.pdtype())}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.pdtype())
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"].astype(jnp.float32)
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- rotary position embedding ------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, T, N, hd]; positions: [B, T] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs          # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd, H, KV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p: Params = {
        "wq": jax.random.normal(ks[0], (d, H * hd), cfg.pdtype()) * std,
        "wk": jax.random.normal(ks[1], (d, KV * hd), cfg.pdtype()) * std,
        "wv": jax.random.normal(ks[2], (d, KV * hd), cfg.pdtype()) * std,
        "wo": jax.random.normal(ks[3], (H * hd, d), cfg.pdtype()) * (H * hd) ** -0.5,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype())
        p["bk"] = jnp.zeros((KV * hd,), cfg.pdtype())
        p["bv"] = jnp.zeros((KV * hd,), cfg.pdtype())
    return p


def _project_qkv(p: Params, xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig):
    B, T = xq.shape[0], xq.shape[1]
    S = xkv.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, T, H, hd), k.reshape(B, S, KV, hd), v.reshape(B, S, KV, hd))


def gqa_attend(
    q: jnp.ndarray,                 # [B, T, H, hd]
    k: jnp.ndarray,                 # [B, S, KV, hd]
    v: jnp.ndarray,                 # [B, S, KV, hd]
    q_pos: jnp.ndarray,             # [B, T]
    k_pos: jnp.ndarray,             # [B, S]
    k_valid: Optional[jnp.ndarray] = None,   # [B, S] bool
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    mask = jnp.ones((B, T, S), dtype=bool)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, T, H * hd)


def flash_gqa_attend(
    q: jnp.ndarray,                 # [B, T, H, hd]
    k: jnp.ndarray,                 # [B, S, KV, hd]
    v: jnp.ndarray,
    q_pos: jnp.ndarray,             # [B, T]
    k_pos: jnp.ndarray,             # [B, S]
    k_valid: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax chunked attention: O(T) memory (flash-attention in jnp).

    Numerically matches gqa_attend; used whenever T x S would be too large to
    materialise. Double scan: outer over query chunks, inner over KV chunks.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, T)
    k_chunk = min(k_chunk, S)
    padT, padS = (-T) % q_chunk, (-S) % k_chunk
    if k_valid is None:
        k_valid = jnp.ones((B, S), bool)
    if padT:
        q = jnp.pad(q, ((0, 0), (0, padT), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, padT)))
    if padS:
        k = jnp.pad(k, ((0, 0), (0, padS), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padS), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, padS)))
        k_valid = jnp.pad(k_valid, ((0, 0), (0, padS)))
    nq, nk = (T + padT) // q_chunk, (S + padS) // k_chunk
    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, k_chunk, KV, hd)
    vc = v.reshape(B, nk, k_chunk, KV, hd)
    qp = q_pos.reshape(B, nq, q_chunk)
    kp = k_pos.reshape(B, nk, k_chunk)
    kval = k_valid.reshape(B, nk, k_chunk)
    scale = hd ** -0.5

    def q_step(_, qi):
        q_i, qp_i = qi                                  # [B,qc,KV,G,hd], [B,qc]

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j, v_j, kp_j, kv_j = ki
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j).astype(jnp.float32) * scale
            mask = kv_j[:, None, :]
            if causal:
                mask = mask & (kp_j[:, None, :] <= qp_i[:, :, None])
            if window > 0:
                mask = mask & (qp_i[:, :, None] - kp_j[:, None, :] < window)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pmat = jnp.where(mask[:, None, None, :, :], jnp.exp(s - m_new[..., None]), 0.0)
            l = l * alpha + pmat.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", pmat.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
             jnp.moveaxis(kp, 1, 0), jnp.moveaxis(kval, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)                # [B,KV,G,qc,hd]

    _, outs = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(qp, 1, 0)))
    # outs: [nq, B, KV, G, qc, hd] -> [B, T, H*hd]
    out = jnp.moveaxis(outs, 0, 1)                               # [B,nq,KV,G,qc,hd]
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * q_chunk, H * hd)
    return out[:, :T]


def flash_gqa_attend_triangular(
    q: jnp.ndarray,                 # [B, T, H, hd]
    k: jnp.ndarray,                 # [B, T, KV, hd] (self-attention: S == T)
    v: jnp.ndarray,
    positions: jnp.ndarray,         # [B, T] == arange
    window: int = 0,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Causal flash that SKIPS fully-masked KV blocks (§Perf optimization).

    The baseline flash scans all nq x nk blocks and masks — 2x the causal
    FLOPs. Here the q-chunk loop is unrolled (python) and each q chunk only
    visits k chunks <= its own index (and >= the window horizon), so the
    compiled graph contains exactly the lower-triangle (band) blocks.
    Requires T == S and aligned position chunks (self-attention prefill/train).
    """
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    n = (T + pad) // chunk
    qc = q.reshape(B, n, chunk, KV, G, hd)
    kc = k.reshape(B, n, chunk, KV, hd)
    vc = v.reshape(B, n, chunk, KV, hd)
    pc = positions.reshape(B, n, chunk)
    scale = hd ** -0.5
    outs = []
    for qi in range(n):
        lo = 0 if window <= 0 else max(0, qi - (window - 1) // chunk - 1)
        q_i, qp_i = qc[:, qi], pc[:, qi]

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j, v_j, kp_j = ki
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j).astype(jnp.float32) * scale
            mask = (kp_j[:, None, :] <= qp_i[:, :, None]) & (kp_j[:, None, :] >= 0)
            if window > 0:
                mask = mask & (qp_i[:, :, None] - kp_j[:, None, :] < window)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pmat = jnp.where(mask[:, None, None, :, :], jnp.exp(s - m_new[..., None]), 0.0)
            l = l * alpha + pmat.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", pmat.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk, hd), jnp.float32)
        ks = jnp.moveaxis(kc[:, lo : qi + 1], 1, 0)
        vs = jnp.moveaxis(vc[:, lo : qi + 1], 1, 0)
        ps = jnp.moveaxis(pc[:, lo : qi + 1], 1, 0)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, ps))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, chunk, H * hd))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :T]


FLASH_SEQ_THRESHOLD = 2048


def attention_forward(
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill / encoder)."""
    q, k, v = _project_qkv(p, x, x, cfg)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if x.shape[1] > FLASH_SEQ_THRESHOLD:
        if causal and cfg.flash_triangular:
            out = flash_gqa_attend_triangular(q, k, v, positions, window=window,
                                              chunk=cfg.flash_q_chunk)
        else:
            out = flash_gqa_attend(q, k, v, positions, positions,
                                   causal=causal, window=window,
                                   q_chunk=cfg.flash_q_chunk,
                                   k_chunk=cfg.flash_k_chunk)
    else:
        out = gqa_attend(q, k, v, positions, positions, causal=causal, window=window)
    return out @ p["wo"]


def cross_attention_forward(
    p: Params,
    x: jnp.ndarray,
    memory_k: jnp.ndarray,          # [B, S, KV, hd] — precomputed from encoder output
    memory_v: jnp.ndarray,
    cfg: ModelConfig,
) -> jnp.ndarray:
    B, T = x.shape[0], x.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    S = memory_k.shape[1]
    zeros_q = jnp.zeros((B, T), jnp.int32)
    zeros_k = jnp.zeros((B, S), jnp.int32)
    if T > FLASH_SEQ_THRESHOLD or S > FLASH_SEQ_THRESHOLD:
        out = flash_gqa_attend(q, memory_k, memory_v, zeros_q, zeros_k,
                               causal=False, q_chunk=cfg.flash_q_chunk,
                               k_chunk=cfg.flash_k_chunk)
    else:
        out = gqa_attend(q, memory_k, memory_v, zeros_q, zeros_k, causal=False)
    return out @ p["wo"]


def project_memory_kv(p: Params, memory: jnp.ndarray, cfg: ModelConfig):
    B, S = memory.shape[0], memory.shape[1]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = (memory @ p["wk"]).reshape(B, S, KV, hd)
    v = (memory @ p["wv"]).reshape(B, S, KV, hd)
    return k, v


# -- FFN -----------------------------------------------------------------------

def init_ffn(key: jax.Array, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p: Params = {
        "w_up": jax.random.normal(ks[0], (d, f), cfg.pdtype()) * d ** -0.5,
        "w_down": jax.random.normal(ks[1], (f, d), cfg.pdtype()) * f ** -0.5,
    }
    if cfg.activation in ("silu",):   # gated (SwiGLU-family) FFN
        p["w_gate"] = jax.random.normal(ks[2], (d, f), cfg.pdtype()) * d ** -0.5
    return p


def apply_activation(x: jnp.ndarray, name: str) -> jnp.ndarray:
    if name == "relu":
        return jax.nn.relu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def ffn_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                capture: bool = False) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    pre = x @ p["w_up"]
    act = apply_activation(pre, cfg.activation)
    if "w_gate" in p:
        act = act * (x @ p["w_gate"])
    y = act @ p["w_down"]
    return y, (pre if capture else None)


# -- sparse (offloaded) decode FFN — the paper's technique at the HBM tier -----

def init_ffn_predictor(key: jax.Array, cfg: ModelConfig) -> Params:
    """Per-layer activation predictor (paper Fig. 3 / Deja Vu) that scores
    neuron SEGMENTS — contiguous groups in the co-activation-permuted layout —
    so the decode step gathers a few large contiguous weight slabs instead of
    scattered rows (kernels/sparse_ffn is the Pallas version of this gather)."""
    n_seg = cfg.d_ff // cfg.sparse_seg
    k1, k2 = jax.random.split(key)
    h = 128
    return {
        "w1": jax.random.normal(k1, (cfg.d_model, h), cfg.pdtype()) * cfg.d_model ** -0.5,
        "w2": jax.random.normal(k2, (h, n_seg), cfg.pdtype()) * h ** -0.5,
    }


def sparse_ffn_decode(p: Params, pred: Params, x: jnp.ndarray,
                      cfg: ModelConfig) -> jnp.ndarray:
    """x: [B, 1, d]. Segment-top-k FFN: only k = sparse_frac * n_seg segments
    of W_up/W_gate/W_down are touched (union across the local batch), so HBM
    weight traffic drops by ~sparse_frac — the RIPPLE flash argument, one tier
    up. Exact for ReLU models whenever the predictor over-covers the true
    support; top-k sparsification (Deja Vu-style) otherwise."""
    B, T, d = x.shape
    f = cfg.d_ff
    seg = cfg.sparse_seg
    n_seg = f // seg
    k_seg = max(1, int(n_seg * cfg.sparse_frac))
    scores = jax.nn.relu(x.reshape(B * T, d) @ pred["w1"].astype(x.dtype))
    scores = scores @ pred["w2"].astype(x.dtype)                  # [B*T, n_seg]
    union = scores.astype(jnp.float32).sum(axis=0)                # union over batch
    _, seg_ids = jax.lax.top_k(union, k_seg)                      # [k_seg]
    w_up = p["w_up"].reshape(d, n_seg, seg)
    wu = jnp.take(w_up, seg_ids, axis=1).reshape(d, k_seg * seg)
    pre = x @ wu
    act = apply_activation(pre, cfg.activation)
    if "w_gate" in p:
        wg = jnp.take(p["w_gate"].reshape(d, n_seg, seg), seg_ids, axis=1)
        act = act * (x @ wg.reshape(d, k_seg * seg))
    w_down = p["w_down"].reshape(n_seg, seg, d)
    wd = jnp.take(w_down, seg_ids, axis=0).reshape(k_seg * seg, d)
    return act @ wd


# -- embeddings ----------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig) -> Params:
    p: Params = {
        "embedding": jax.random.normal(key, (cfg.vocab_size, cfg.d_model), cfg.pdtype()) * 0.02,
    }
    if not cfg.tie_embeddings:
        key2 = jax.random.fold_in(key, 1)
        p["lm_head"] = jax.random.normal(
            key2, (cfg.d_model, cfg.vocab_size), cfg.pdtype()) * cfg.d_model ** -0.5
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return p["embedding"][tokens].astype(cfg.dtype())


def unembed(p: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return h @ p["embedding"].T.astype(cfg.dtype())
    return h @ p["lm_head"].astype(cfg.dtype())
