"""State-space / recurrent mixers: Mamba (selective scan), xLSTM (mLSTM, sLSTM).

All three expose:
  init_*(key, cfg)                          -> params
  *_forward(p, x, cfg)                      -> y            (full sequence)
  *_decode_step(p, x_t, state, cfg)         -> (y_t, state) (one token)
  *_init_state(batch, cfg, dtype)           -> state pytree

Sequence forwards run a time scan in chunks of `SCAN_CHUNK` with jax.checkpoint
on each chunk (sqrt-T activation memory for backward). States are exact — the
decode step continues any prefix processed by the sequence forward.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig

Params = Dict[str, jnp.ndarray]
SCAN_CHUNK = 128


def _chunked_time_scan(step_fn, carry, xs_time_major, chunk: int = SCAN_CHUNK):
    """scan(step_fn) over leading time axis, checkpointed per chunk.

    Padded steps are carry-IDENTITY (masked): zero-padded inputs are not
    guaranteed to be no-ops for every recurrence (sLSTM's hidden recurrence
    evolves on zero input), so the final state must ignore them.
    """
    T = jax.tree_util.tree_leaves(xs_time_major)[0].shape[0]
    pad = (-T) % chunk
    valid = jnp.arange(T + pad) < T
    if pad:
        xs_time_major = jax.tree_util.tree_map(
            lambda a: jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]), xs_time_major)
    nchunks = (T + pad) // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((nchunks, chunk) + a.shape[1:]), xs_time_major)
    valid_c = valid.reshape(nchunks, chunk)

    def masked_step(c, inp):
        v, xs = inp
        new_c, y = step_fn(c, xs)
        new_c = jax.tree_util.tree_map(lambda a, b: jnp.where(v, a, b), new_c, c)
        return new_c, y

    @jax.checkpoint
    def chunk_fn(c, inp):
        return jax.lax.scan(masked_step, c, inp)

    carry, ys = jax.lax.scan(chunk_fn, carry, (valid_c, xs_c))
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((nchunks * chunk,) + a.shape[2:])[:T], ys)
    return carry, ys


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================

class MambaState(NamedTuple):
    conv: jnp.ndarray   # [B, d_conv-1, d_inner] — trailing inputs for the causal conv
    ssm: jnp.ndarray    # [B, d_inner, d_state]


def _mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba or MambaConfig()
    di = m.expand * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return di, m.d_state, m.d_conv, dt_rank


def init_mamba(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di, N, dc, R = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    pd = cfg.pdtype()
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), pd) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (dc, di), pd) * dc ** -0.5,
        "conv_b": jnp.zeros((di,), pd),
        "x_proj": jax.random.normal(ks[2], (di, R + 2 * N), pd) * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (R, di), pd) * R ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, pd))),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=pd), (di, 1))),
        "D": jnp.ones((di,), pd),
        "out_proj": jax.random.normal(ks[5], (di, d), pd) * di ** -0.5,
    }


def mamba_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> MambaState:
    di, N, dc, _ = _mamba_dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, dc - 1, di), dtype),
        ssm=jnp.zeros((batch, di, N), jnp.float32),
    )


def _mamba_ssm_inputs(p: Params, x_conv: jnp.ndarray, cfg: ModelConfig):
    """x_conv: [..., di] post-conv activations -> (dt, B_t, C_t)."""
    di, N, _, R = _mamba_dims(cfg)
    proj = x_conv @ p["x_proj"].astype(x_conv.dtype)
    dt_r, B_t, C_t = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(x_conv.dtype)
                         + p["dt_bias"].astype(x_conv.dtype))
    return dt, B_t, C_t


def _mamba_step(A, D):
    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp      # [B,di], [B,di], [B,N], [B,N]
        dtf = dt_t.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A)                         # [B, di, N]
        dBx = dtf[..., None] * B_t.astype(jnp.float32)[:, None, :] * x_t.astype(jnp.float32)[..., None]
        h = dA * h + dBx
        y = (h * C_t.astype(jnp.float32)[:, None, :]).sum(-1) + D * x_t.astype(jnp.float32)
        return h, y.astype(x_t.dtype)
    return step


def mamba_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_state: bool = False):
    """x: [B, T, d] -> [B, T, d] (and the final MambaState if requested)."""
    B, T, d = x.shape
    di, N, dc, _ = _mamba_dims(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                           # [B, T, di]
    # causal depthwise conv over time
    x_pad = jnp.pad(x_in, ((0, 0), (dc - 1, 0), (0, 0)))
    conv_w = p["conv_w"].astype(x.dtype)
    x_conv = sum(x_pad[:, k : k + T, :] * conv_w[k] for k in range(dc))
    x_conv = jax.nn.silu(x_conv + p["conv_b"].astype(x.dtype))
    dt, B_t, C_t = _mamba_ssm_inputs(p, x_conv, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    D = p["D"].astype(jnp.float32)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    tm = lambda a: jnp.moveaxis(a, 1, 0)                          # time-major
    h_final, ys = _chunked_time_scan(_mamba_step(A, D), h0, (tm(x_conv), tm(dt), tm(B_t), tm(C_t)))
    y = jnp.moveaxis(ys, 0, 1)                                    # [B, T, di]
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, MambaState(conv=x_pad[:, T:, :], ssm=h_final)
    return out


def mamba_decode_step(p: Params, x_t: jnp.ndarray, state: MambaState,
                      cfg: ModelConfig) -> Tuple[jnp.ndarray, MambaState]:
    """x_t: [B, d] one token -> (y_t [B, d], new state)."""
    di, N, dc, _ = _mamba_dims(cfg)
    xz = x_t @ p["in_proj"].astype(x_t.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                           # [B, di]
    window = jnp.concatenate([state.conv, x_in[:, None, :]], axis=1)   # [B, dc, di]
    conv_w = p["conv_w"].astype(x_t.dtype)
    x_conv = (window * conv_w[None]).sum(axis=1) + p["conv_b"].astype(x_t.dtype)
    x_conv = jax.nn.silu(x_conv)
    dt, B_t, C_t = _mamba_ssm_inputs(p, x_conv, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    D = p["D"].astype(jnp.float32)
    h, y = _mamba_step(A, D)(state.ssm, (x_conv, dt, B_t, C_t))
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x_t.dtype), MambaState(conv=window[:, 1:], ssm=h)


# ===========================================================================
# mLSTM (xLSTM matrix-memory block)
# ===========================================================================

class MLSTMState(NamedTuple):
    C: jnp.ndarray   # [B, H, hd, hd]
    n: jnp.ndarray   # [B, H, hd]
    m: jnp.ndarray   # [B, H]


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    pd = cfg.pdtype()
    std = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, H * hd), pd) * std,
        "wk": jax.random.normal(ks[1], (d, H * hd), pd) * std,
        "wv": jax.random.normal(ks[2], (d, H * hd), pd) * std,
        "w_i": jax.random.normal(ks[3], (d, H), pd) * std,
        "b_i": jnp.zeros((H,), pd),
        "w_f": jax.random.normal(ks[4], (d, H), pd) * std,
        "b_f": jnp.full((H,), 3.0, pd),          # forget-gate bias: start remembering
        "w_o": jax.random.normal(ks[5], (d, H * hd), pd) * std,
        "out_proj": jax.random.normal(jax.random.fold_in(key, 7), (H * hd, d), pd) * (H * hd) ** -0.5,
    }


def mlstm_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> MLSTMState:
    H, hd = cfg.n_heads, cfg.head_dim
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


def _mlstm_gates(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    H, hd = cfg.n_heads, cfg.head_dim
    shp = x.shape[:-1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(*shp, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(*shp, H, hd) * hd ** -0.5
    v = (x @ p["wv"].astype(x.dtype)).reshape(*shp, H, hd)
    i_log = (x @ p["w_i"].astype(x.dtype) + p["b_i"].astype(x.dtype)).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        (x @ p["w_f"].astype(x.dtype) + p["b_f"].astype(x.dtype)).astype(jnp.float32))
    o = jax.nn.sigmoid(x @ p["w_o"].astype(x.dtype))
    return q, k, v, i_log, f_log, o


def _mlstm_step(carry: MLSTMState, inp):
    q, k, v, i_log, f_log = inp      # [B,H,hd] x3, [B,H] x2
    C, n, m = carry
    m_new = jnp.maximum(f_log + m, i_log)
    i_p = jnp.exp(i_log - m_new)[..., None]                        # [B,H,1]
    f_p = jnp.exp(f_log + m - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = f_p[..., None] * C + i_p[..., None] * vf[..., :, None] * kf[..., None, :]
    n = f_p * n + i_p * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, qf)), 1.0)[..., None]
    y = (num / den).astype(q.dtype)                                # [B,H,hd]
    return MLSTMState(C, n, m_new), y


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_state: bool = False):
    B, T, d = x.shape
    q, k, v, i_log, f_log, o = _mlstm_gates(p, x, cfg)
    carry = mlstm_init_state(B, cfg)
    tm = lambda a: jnp.moveaxis(a, 1, 0)
    final, ys = _chunked_time_scan(_mlstm_step, carry, (tm(q), tm(k), tm(v), tm(i_log), tm(f_log)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, -1) * o
    out = y @ p["out_proj"].astype(x.dtype)
    return (out, final) if return_state else out


def mlstm_decode_step(p: Params, x_t: jnp.ndarray, state: MLSTMState,
                      cfg: ModelConfig) -> Tuple[jnp.ndarray, MLSTMState]:
    B, d = x_t.shape
    q, k, v, i_log, f_log, o = _mlstm_gates(p, x_t, cfg)
    state, y = _mlstm_step(state, (q, k, v, i_log, f_log))
    y = y.reshape(B, -1) * o
    return y @ p["out_proj"].astype(x_t.dtype), state


# ===========================================================================
# sLSTM (xLSTM scalar-memory block with true hidden recurrence)
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, H, hd]
    n: jnp.ndarray   # [B, H, hd]
    h: jnp.ndarray   # [B, H, hd]
    m: jnp.ndarray   # [B, H, hd]


def init_slstm(key: jax.Array, cfg: ModelConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    pd = cfg.pdtype()
    p: Params = {}
    for i, gate in enumerate(("z", "i", "f", "o")):
        kw, kr = jax.random.split(jax.random.fold_in(key, i))
        p[f"w_{gate}"] = jax.random.normal(kw, (d, H * hd), pd) * d ** -0.5
        p[f"r_{gate}"] = jax.random.normal(kr, (H, hd, hd), pd) * hd ** -0.5
        p[f"b_{gate}"] = (jnp.full((H * hd,), 3.0, pd) if gate == "f"
                          else jnp.zeros((H * hd,), pd))
    p["out_proj"] = jax.random.normal(jax.random.fold_in(key, 9), (H * hd, d), pd) * (H * hd) ** -0.5
    return p


def slstm_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> SLSTMState:
    H, hd = cfg.n_heads, cfg.head_dim
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, h=z, m=jnp.full((batch, H, hd), -1e30, jnp.float32))


def _slstm_step_fn(p: Params, cfg: ModelConfig):
    H, hd = cfg.n_heads, cfg.head_dim

    def rec(gate: str, h_prev: jnp.ndarray) -> jnp.ndarray:
        return jnp.einsum("bhd,hde->bhe", h_prev, p[f"r_{gate}"].astype(h_prev.dtype))

    def step(state: SLSTMState, wx):   # wx: dict of [B, H, hd] pre-projected inputs
        hp = state.h
        z = jnp.tanh(wx["z"] + rec("z", hp))
        i_log = (wx["i"] + rec("i", hp)).astype(jnp.float32)
        f_log = jax.nn.log_sigmoid((wx["f"] + rec("f", hp)).astype(jnp.float32))
        o = jax.nn.sigmoid(wx["o"] + rec("o", hp))
        m_new = jnp.maximum(f_log + state.m, i_log)
        i_p = jnp.exp(i_log - m_new)
        f_p = jnp.exp(f_log + state.m - m_new)
        c = f_p * state.c + i_p * z.astype(jnp.float32)
        n = f_p * state.n + i_p
        h = (o.astype(jnp.float32) * c / jnp.maximum(n, 1e-6)).astype(z.dtype)
        new = SLSTMState(c=c, n=n, h=h.astype(jnp.float32), m=m_new)
        return new, h

    return step


def _slstm_wx(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    H, hd = cfg.n_heads, cfg.head_dim
    shp = x.shape[:-1]
    return {
        g: (x @ p[f"w_{g}"].astype(x.dtype) + p[f"b_{g}"].astype(x.dtype)).reshape(*shp, H, hd)
        for g in ("z", "i", "f", "o")
    }


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  return_state: bool = False):
    B, T, d = x.shape
    wx = _slstm_wx(p, x, cfg)
    carry = slstm_init_state(B, cfg)
    tm = lambda a: jnp.moveaxis(a, 1, 0)
    final, ys = _chunked_time_scan(_slstm_step_fn(p, cfg), carry,
                                   {k: tm(v) for k, v in wx.items()})
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, -1)
    out = (y @ p["out_proj"].astype(y.dtype)).astype(x.dtype)
    return (out, final) if return_state else out


def slstm_decode_step(p: Params, x_t: jnp.ndarray, state: SLSTMState,
                      cfg: ModelConfig) -> Tuple[jnp.ndarray, SLSTMState]:
    B, d = x_t.shape
    wx = _slstm_wx(p, x_t, cfg)
    state, y = _slstm_step_fn(p, cfg)(state, wx)
    y = y.reshape(B, -1)
    return (y @ p["out_proj"].astype(y.dtype)).astype(x_t.dtype), state
