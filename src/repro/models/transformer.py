"""Decoder stack assembly: heterogeneous layers under a single lax.scan.

The per-layer mixer/FFN pattern (cfg.layer_kinds / cfg.ffn_kinds) is detected
to be periodic with period P; the stack is scanned over n_layers/P groups, each
group applying P sublayers unrolled. This keeps HLO size O(P), which is what
makes 88-layer configs compile quickly on one host and is standard MaxText
practice. Parameters and caches are stacked [G, ...] along the scan axis.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.kernels import ops
from repro.models.kvcache import (KVCache, PagedKVCache, PagedQuantKVCache,
                                  QuantKVCache, SWACache, attend_full_cache,
                                  attend_swa_cache,
                                  init_kv_cache, init_paged_kv_cache,
                                  init_paged_quant_kv_cache,
                                  init_quant_kv_cache, init_swa_cache,
                                  kv_write, kv_write_rows,
                                  paged_kv_write_rows,
                                  paged_quant_kv_write_rows, quant_kv_write,
                                  quant_kv_write_rows, swa_write)
from repro.models.layers import (apply_norm, attention_forward, ffn_forward,
                                 init_attention, init_ffn, init_ffn_predictor,
                                 init_norm, rope, sparse_ffn_decode)

Params = Dict[str, Any]


def stack_period(cfg: ModelConfig) -> int:
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    L = cfg.n_layers
    for P in range(1, L + 1):
        if L % P:
            continue
        if all(kinds[i] == kinds[i % P] for i in range(L)) and \
           all(ffns[i] == ffns[i % P] for i in range(L)):
            return P
    return L


# -- init ---------------------------------------------------------------------

def _init_sublayer(key: jax.Array, cfg: ModelConfig, kind: str, ffn: str) -> Params:
    kmix, kffn = jax.random.split(key)
    p: Params = {"norm1": init_norm(cfg)}
    if kind == "attn":
        p["mixer"] = init_attention(kmix, cfg)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba(kmix, cfg)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(kmix, cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(kmix, cfg)
    else:
        raise ValueError(kind)
    if ffn == "dense":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_ffn(kffn, cfg)
        if cfg.serve_sparse:
            p["ffn_pred"] = init_ffn_predictor(jax.random.fold_in(kffn, 7), cfg)
    elif ffn == "moe":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = moe_lib.init_moe(kffn, cfg)
    return p


def init_stack(key: jax.Array, cfg: ModelConfig) -> Params:
    P = stack_period(cfg)
    G = cfg.n_layers // P
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    stack: Params = {}
    for j in range(P):
        keys = jax.random.split(jax.random.fold_in(key, j), G)
        stack[f"sub_{j}"] = jax.vmap(
            lambda k: _init_sublayer(k, cfg, kinds[j], ffns[j]))(keys)
    return stack


# -- full-sequence forward ------------------------------------------------------

class StackOutput(NamedTuple):
    x: jnp.ndarray
    aux_loss: jnp.ndarray                     # scalar (MoE load balance)
    ffn_pre_act: Optional[jnp.ndarray]        # [L_dense, B, T, d_ff] if captured
    ffn_inputs: Optional[jnp.ndarray] = None  # [L_dense, B, T, d_model] if captured


def stack_forward(
    stack: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    window: int = 0,
    capture_activations: bool = False,
) -> StackOutput:
    P = stack_period(cfg)
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()

    def group_fn(carry, group_params):
        h = carry
        aux_total = jnp.zeros((), jnp.float32)
        captures: List[jnp.ndarray] = []
        captures_h: List[jnp.ndarray] = []
        for j in range(P):
            sp = group_params[f"sub_{j}"]
            kind, ffn = kinds[j], ffns[j]
            normed = apply_norm(sp["norm1"], h, cfg)
            if kind == "attn":
                mix = attention_forward(sp["mixer"], normed, positions, cfg, window=window)
            elif kind == "mamba":
                mix = ssm.mamba_forward(sp["mixer"], normed, cfg)
            elif kind == "mlstm":
                mix = ssm.mlstm_forward(sp["mixer"], normed, cfg)
            else:
                mix = ssm.slstm_forward(sp["mixer"], normed, cfg)
            h = h + mix
            if ffn != "none":
                normed2 = apply_norm(sp["norm2"], h, cfg)
                if ffn == "dense":
                    y, pre = ffn_forward(sp["ffn"], normed2, cfg, capture=capture_activations)
                    if capture_activations:
                        captures.append(pre)
                        captures_h.append(normed2)
                else:
                    y, aux = moe_lib.moe_forward(sp["ffn"], normed2, cfg)
                    aux_total = aux_total + aux
                h = h + y
        cap = jnp.stack(captures) if captures else jnp.zeros((0,), h.dtype)
        cap_h = jnp.stack(captures_h) if captures_h else jnp.zeros((0,), h.dtype)
        return h, (aux_total, cap, cap_h)

    fn = jax.checkpoint(group_fn) if cfg.remat else group_fn
    x, (aux, caps, caps_h) = jax.lax.scan(fn, x, stack)
    aux_loss = aux.sum()
    pre_act = ffn_inputs = None
    if capture_activations and caps.size:
        # caps: [G, n_dense_per_period, B, T, d_ff] -> [L_dense, B, T, d_ff]
        pre_act = caps.reshape((-1,) + caps.shape[2:])
        # pre-FFN hidden states, same layer order — the lookahead predictor's
        # training input (layer k's hidden predicts layer k+1's mask)
        ffn_inputs = caps_h.reshape((-1,) + caps_h.shape[2:])
    return StackOutput(x=x, aux_loss=aux_loss, ffn_pre_act=pre_act,
                       ffn_inputs=ffn_inputs)


# -- caches ----------------------------------------------------------------------

def init_stack_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    swa: bool = False,
    dtype=None,
) -> Params:
    """Cache pytree: per sublayer position, leaves stacked [G, ...]."""
    P = stack_period(cfg)
    G = cfg.n_layers // P
    kinds = cfg.layer_kinds()
    dtype = dtype or cfg.dtype()

    def stacked(make_one):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (G,) + a.shape).copy(), one)

    cache: Params = {}
    for j in range(P):
        kind = kinds[j]
        if kind == "attn":
            if swa:
                cache[f"sub_{j}"] = stacked(lambda: init_swa_cache(batch, cfg, dtype))
            elif cfg.kv_quant:
                cache[f"sub_{j}"] = stacked(lambda: init_quant_kv_cache(batch, max_len, cfg))
            else:
                cache[f"sub_{j}"] = stacked(lambda: init_kv_cache(batch, max_len, cfg, dtype))
        elif kind == "mamba":
            cache[f"sub_{j}"] = stacked(lambda: ssm.mamba_init_state(batch, cfg, dtype))
        elif kind == "mlstm":
            cache[f"sub_{j}"] = stacked(lambda: ssm.mlstm_init_state(batch, cfg, dtype))
        else:
            cache[f"sub_{j}"] = stacked(lambda: ssm.slstm_init_state(batch, cfg, dtype))
    return cache


def init_paged_stack_cache(
    cfg: ModelConfig,
    num_pages: int,
    page_size: int,
    dtype=None,
) -> Params:
    """Paged cache pytree: per attention sublayer, a page arena stacked
    [G, num_pages + 1, page_size, KV, hd] (the trailing null page absorbs
    inactive-slot writes). One set of `num_pages` logical pages serves every
    layer — a page table entry indexes all G x P arenas at once, vLLM-style —
    so allocator accounting stays per-request, not per-layer.

    Raises ValueError for stacks the paged layout cannot represent (SSM
    sublayers keep per-slot recurrent state, not positional KV) — no silent
    fallback to a contiguous cache."""
    if num_pages < 1 or page_size < 1:
        raise ValueError(f"paged cache needs num_pages >= 1 and page_size >= 1, "
                         f"got num_pages={num_pages} page_size={page_size}")
    kinds = cfg.layer_kinds()
    if any(k != "attn" for k in kinds):
        raise ValueError(
            f"paged KV cache covers attention-only stacks; config "
            f"{cfg.arch_id!r} has layer kinds {sorted(set(kinds))} (SSM "
            f"sublayers carry per-slot recurrent state, which pages cannot "
            f"represent)")
    P = stack_period(cfg)
    G = cfg.n_layers // P
    dtype = dtype or cfg.dtype()

    def stacked(make_one):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (G,) + a.shape).copy(), one)

    cache: Params = {}
    for j in range(P):
        if cfg.kv_quant:
            cache[f"sub_{j}"] = stacked(
                lambda: init_paged_quant_kv_cache(num_pages, page_size, cfg))
        else:
            cache[f"sub_{j}"] = stacked(
                lambda: init_paged_kv_cache(num_pages, page_size, cfg, dtype))
    return cache


# -- prefill ----------------------------------------------------------------------

def _attn_seq_with_cache(sp, normed, positions, cfg, cache, window):
    """Sequence attention that also fills the cache (prefill path).

    Long sequences route through flash attention exactly like
    attention_forward — the dense [T, S] score matrix at 32k would be
    hundreds of GiB (§Perf X7)."""
    from repro.models.layers import (FLASH_SEQ_THRESHOLD, _project_qkv,
                                     flash_gqa_attend,
                                     flash_gqa_attend_triangular, gqa_attend)
    q, k, v = _project_qkv(sp, normed, normed, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if normed.shape[1] > FLASH_SEQ_THRESHOLD:
        if cfg.flash_triangular:
            out = flash_gqa_attend_triangular(q, k, v, positions, window=window,
                                              chunk=cfg.flash_q_chunk)
        else:
            out = flash_gqa_attend(q, k, v, positions, positions, causal=True,
                                   window=window, q_chunk=cfg.flash_q_chunk,
                                   k_chunk=cfg.flash_k_chunk)
    else:
        out = gqa_attend(q, k, v, positions, positions, causal=True, window=window)
    if isinstance(cache, SWACache):
        cache = swa_write(cache, k, v, positions)
    elif isinstance(cache, QuantKVCache):
        cache = quant_kv_write(cache, k, v, 0)
    else:
        cache = kv_write(cache, k, v, 0)
    return out @ sp["wo"], cache


def stack_prefill(
    stack: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Params,
    cfg: ModelConfig,
    window: int = 0,
) -> Tuple[jnp.ndarray, Params]:
    P = stack_period(cfg)
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()

    def group_fn(carry, inp):
        h = carry
        group_params, group_cache = inp
        new_cache: Params = {}
        for j in range(P):
            sp = group_params[f"sub_{j}"]
            cj = group_cache[f"sub_{j}"]
            kind, ffn = kinds[j], ffns[j]
            normed = apply_norm(sp["norm1"], h, cfg)
            if kind == "attn":
                mix, cj = _attn_seq_with_cache(sp["mixer"], normed, positions, cfg, cj, window)
            elif kind == "mamba":
                mix, cj = ssm.mamba_forward(sp["mixer"], normed, cfg, return_state=True)
            elif kind == "mlstm":
                mix, cj = ssm.mlstm_forward(sp["mixer"], normed, cfg, return_state=True)
            else:
                mix, cj = ssm.slstm_forward(sp["mixer"], normed, cfg, return_state=True)
            h = h + mix
            if ffn != "none":
                normed2 = apply_norm(sp["norm2"], h, cfg)
                if ffn == "dense":
                    y, _ = ffn_forward(sp["ffn"], normed2, cfg)
                else:
                    y, _ = moe_lib.moe_forward(sp["ffn"], normed2, cfg)
                h = h + y
            new_cache[f"sub_{j}"] = cj
        return h, new_cache

    x, new_cache = jax.lax.scan(group_fn, x, (stack, cache))
    return x, new_cache


# -- single-token decode -----------------------------------------------------------

def _decode_positions(position: jnp.ndarray, B: int) -> jnp.ndarray:
    """[B, 1] decode positions from either a shared scalar or a per-slot [B]
    vector (the continuous-batching server: every KV-cache slot sits at its
    own sequence position)."""
    pos = jnp.asarray(position).astype(jnp.int32)
    if pos.ndim == 1:
        return pos[:, None]
    return jnp.broadcast_to(pos, (B, 1))


def _mixer_decode(sp: Params, cj: Any, h: jnp.ndarray, pos_arr: jnp.ndarray,
                  position: jnp.ndarray, cfg: ModelConfig, kind: str,
                  window: int,
                  page_tables: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Any]:
    """One sublayer's mixer for a single decode token: (mix [B,1,d], new cache).

    Shared by the jit'd scan path (stack_decode_step) and the host-driven
    layerwise path (stack_decode_step_layerwise) so both run identical math.
    `position` is a shared scalar or a per-slot [B] vector; the full-cache
    writes pick the matching (slice vs per-row scatter) variant. Paged caches
    scatter the write through `page_tables` [B, max_pages] and attend via
    `kernels/ops.paged_decode_attention` — the XLA gather twin on CPU, the
    Pallas paged-attention kernel elsewhere (per-slot positions required —
    the paged layout exists for the continuous-batching server).
    """
    per_row = jnp.asarray(position).ndim == 1
    normed = apply_norm(sp["norm1"], h, cfg)
    if kind == "attn":
        from repro.models.layers import _project_qkv
        q, k, v = _project_qkv(sp["mixer"], normed, normed, cfg)
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
        if isinstance(cj, (PagedKVCache, PagedQuantKVCache)):
            if page_tables is None:
                raise ValueError("paged KV cache decode needs page_tables")
            if not per_row:
                raise ValueError("paged KV cache decode needs per-slot [B] "
                                 "positions (continuous batching)")
            cur_pos = jnp.asarray(position).astype(jnp.int32)
            if isinstance(cj, PagedQuantKVCache):
                cj = paged_quant_kv_write_rows(cj, k, v, position, page_tables)
                out = ops.paged_decode_attention(
                    q[:, 0], cj.k, cj.v, page_tables, cur_pos,
                    k_scale=cj.k_scale, v_scale=cj.v_scale)
            else:
                cj = paged_kv_write_rows(cj, k, v, position, page_tables)
                out = ops.paged_decode_attention(q[:, 0], cj.k, cj.v,
                                                 page_tables, cur_pos)
            # the kernel dispatcher (XLA gather twin on CPU, Pallas paged
            # kernel elsewhere) returns [B, H, hd] fp32; fold back to the
            # [B, 1, H*hd] residual layout at the model dtype
            B, H, hd = out.shape
            mix = out.reshape(B, 1, H * hd).astype(q.dtype)
        elif isinstance(cj, SWACache):
            cj = swa_write(cj, k, v, pos_arr)
            mix = attend_swa_cache(q, cj, pos_arr, window or cfg.sliding_window)
        elif isinstance(cj, QuantKVCache):
            cj = (quant_kv_write_rows(cj, k, v, position) if per_row
                  else quant_kv_write(cj, k, v, position))
            mix = attend_full_cache(q, cj, pos_arr)
        else:
            cj = (kv_write_rows(cj, k, v, position) if per_row
                  else kv_write(cj, k, v, position))
            mix = attend_full_cache(q, cj, pos_arr)
        return mix @ sp["mixer"]["wo"], cj
    if kind == "mamba":
        y, cj = ssm.mamba_decode_step(sp["mixer"], normed[:, 0], cj, cfg)
    elif kind == "mlstm":
        y, cj = ssm.mlstm_decode_step(sp["mixer"], normed[:, 0], cj, cfg)
    else:
        y, cj = ssm.slstm_decode_step(sp["mixer"], normed[:, 0], cj, cfg)
    return y[:, None], cj


def stack_decode_step(
    stack: Params,
    x: jnp.ndarray,            # [B, 1, d]
    position: jnp.ndarray,     # scalar int32 (shared) or [B] per-slot positions
    cache: Params,
    cfg: ModelConfig,
    window: int = 0,
    page_tables: Optional[jnp.ndarray] = None,  # [B, max_pages] (paged caches)
) -> Tuple[jnp.ndarray, Params]:
    P = stack_period(cfg)
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    B = x.shape[0]
    pos_arr = _decode_positions(position, B)

    def group_fn(carry, inp):
        h = carry
        group_params, group_cache = inp
        new_cache: Params = {}
        for j in range(P):
            sp = group_params[f"sub_{j}"]
            cj = group_cache[f"sub_{j}"]
            kind, ffn = kinds[j], ffns[j]
            mix, cj = _mixer_decode(sp, cj, h, pos_arr, position, cfg, kind,
                                    window, page_tables=page_tables)
            h = h + mix
            if ffn != "none":
                normed2 = apply_norm(sp["norm2"], h, cfg)
                if ffn == "dense":
                    if cfg.serve_sparse:
                        y2 = sparse_ffn_decode(sp["ffn"], sp["ffn_pred"], normed2, cfg)
                    else:
                        y2, _ = ffn_forward(sp["ffn"], normed2, cfg)
                else:
                    y2, _ = moe_lib.moe_forward(sp["ffn"], normed2, cfg)
                h = h + y2
            new_cache[f"sub_{j}"] = cj
        return h, new_cache

    x, new_cache = jax.lax.scan(group_fn, x, (stack, cache))
    return x, new_cache


# -- host-driven layerwise decode (offload serving hook) ---------------------------

def unstack_groups(tree: Params, cfg: ModelConfig) -> List[Params]:
    """Split a stacked {sub_j: [G, ...]} pytree into G per-group pytrees.

    Done once per served batch by the offload path so the per-token layer loop
    indexes views instead of re-slicing the stacked arrays every step."""
    G = cfg.n_layers // stack_period(cfg)
    return [jax.tree_util.tree_map(lambda a: a[g], tree) for g in range(G)]


def stack_groups(groups: List[Params]) -> Params:
    """Inverse of unstack_groups (restack along the scan axis)."""
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *groups)


def stack_decode_step_layerwise(
    param_groups: List[Params],
    x: jnp.ndarray,            # [B, 1, d]
    position: jnp.ndarray,     # scalar int32 (shared) or [B] per-slot positions
    cache_groups: List[Params],
    cfg: ModelConfig,
    window: int = 0,
    ffn_override=None,         # (dense_layer_idx, normed2 [B,1,d]) -> y [B,1,d]
    page_tables: Optional[jnp.ndarray] = None,  # [B, max_pages] (paged caches)
) -> Tuple[jnp.ndarray, List[Params]]:
    """Python-loop decode step over unstacked layer groups.

    Identical math to `stack_decode_step`, but the loop runs on host so a
    caller can intercept every dense-FFN sublayer via `ffn_override` — the
    offload serving path computes those from flash bundle payloads (predict ->
    batched engine step -> sparse FFN) instead of the resident weights.
    `dense_layer_idx` counts dense FFN sublayers in (group, sublayer) order —
    the same order `stack_forward(capture_activations=True)` stacks
    `ffn_pre_act`, so calibration traces and serving agree on layer ids.
    `page_tables` routes attention sublayers through a paged arena exactly as
    in `stack_decode_step` — the one page table serves every layer group.
    """
    P = stack_period(cfg)
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    B = x.shape[0]
    pos_arr = _decode_positions(position, B)
    h = x
    dense_idx = 0
    new_groups: List[Params] = []
    for group_params, group_cache in zip(param_groups, cache_groups):
        new_cache: Params = {}
        for j in range(P):
            sp = group_params[f"sub_{j}"]
            cj = group_cache[f"sub_{j}"]
            kind, ffn = kinds[j], ffns[j]
            mix, cj = _mixer_decode(sp, cj, h, pos_arr, position, cfg, kind,
                                    window, page_tables=page_tables)
            h = h + mix
            if ffn != "none":
                normed2 = apply_norm(sp["norm2"], h, cfg)
                if ffn == "dense":
                    if ffn_override is not None:
                        y2 = ffn_override(dense_idx, normed2)
                    elif cfg.serve_sparse:
                        y2 = sparse_ffn_decode(sp["ffn"], sp["ffn_pred"], normed2, cfg)
                    else:
                        y2, _ = ffn_forward(sp["ffn"], normed2, cfg)
                    dense_idx += 1
                else:
                    y2, _ = moe_lib.moe_forward(sp["ffn"], normed2, cfg)
                h = h + y2
            new_cache[f"sub_{j}"] = cj
        new_groups.append(new_cache)
    return h, new_groups
