"""repro — RIPPLE/"Neuralink" neuron co-activation linking, as a multi-pod JAX
framework. See README.md / DESIGN.md. Public API highlights:

    from repro.configs import get_config, ASSIGNED_CONFIGS, INPUT_SHAPES
    from repro.models import build_model
    from repro.core import OffloadEngine, search_placement, CoActivationStats
"""
__version__ = "1.0.0"
