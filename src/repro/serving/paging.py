"""Paged KV cache: page pool, page tables, and copy-on-write prefix sharing.

The `InferenceServer` used to preallocate one full-`max_len` KV region per
decode slot, so concurrency was bounded by WORST-CASE sequence length: a slot
serving a 20-token request still pinned `max_len` positions of KV in DRAM —
exactly the waste vLLM-style PagedAttention eliminates, and the scarce-DRAM
premise of the paper makes it the dominant waste on device. This module owns
all KV memory instead:

  * `PagePool` holds the arena — per attention sublayer, physical pages of
    `page_size` KV rows stacked `[G, num_pages + 1, page_size, KV, hd]`
    (float or the int8 `QuantKVCache` layout with per-page-row scales; the
    trailing null page absorbs inactive-slot garbage writes). ONE set of
    logical pages serves every layer: a page-table entry indexes all layers'
    arenas at once, so allocator accounting is per request, not per layer.
  * a free-list allocator with refcounted pages: `num_pages` pages, LIFO
    free list (deterministic), refcount per page; a page returns to the free
    list exactly when its last reference drops.
  * per-request `PageTable`s grow ONE page at a time during decode
    (`prepare_append`), and every retirement path releases through one choke
    point (`release`) — length/stop/timeout/error/rejected/preempted/abort
    all reclaim deterministically.
  * prefix sharing, hash-matched at admission (`plan_admit`/`admit`):
      - the PREFIX REGISTRY maps page-aligned prompt-prefix byte strings to
        the full pages holding their KV. Registered pages are immutable by
        construction (appends never land in a full page), so registry hits
        share without ever copying; entries hold their own refcounts and are
        evicted oldest-first under page pressure (`prefix_evictions`),
        skipping entries whose pages are all pinned by live tables (evicting
        those frees nothing).
      - LIVE-PROMPT FORKING: a new prompt extending (or equal to) a live
        request's full prompt maps the live request's pages — including a
        partially-filled final page — until divergence. A write into a page
        with refcount > 1 triggers copy-on-write (`cow_copies`): the writer
        allocates a fresh page, copies, and drops its shared reference, so
        the other sharers (and the registry) keep the original bytes.
    Identity is byte-exact, not probabilistic: match keys are the raw prompt
    bytes, so hash collisions cannot alias different prompts.
  * admission accounting: `plan_admit` prices a candidate's worst-case page
    need (prompt pages + decode growth + pending CoW, minus shared-forever
    full pages) AND the registry-only shared pages it would pin — pinned
    shares stop being evictable, so they count as consumed availability;
    `can_admit` gates on free + registry-evictable pages net of that pin,
    minus the outstanding commitments of active tables. In the default strict mode
    an admitted request can therefore ALWAYS grow to completion — the pool
    never runs dry mid-decode and preemption stays at exactly zero. With
    `overcommit=True` only the immediate prompt need is gated, admitting more
    concurrency at the cost of possible page-pressure preemption upstream
    (the server's `_grow_page_tables` hook retires the lowest-priority
    request when `prepare_append` finds the pool dry).

Everything here is host-side numpy/python bookkeeping; the only jnp work is
page block copies (prompt writes, CoW) against the arenas, which the decode
step then indexes through `[B, max_pages]` page-table arrays
(`models/kvcache.py` paged writes + `kernels/ops.paged_decode_attention`).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.kvcache import PagedKVCache, PagedQuantKVCache
from repro.obs import get_tracer


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass
class PagePoolStats:
    """Lifetime counters (mirrored into `ServerStats` by the server)."""
    pages_allocated: int = 0       # every successful page allocation
    pages_freed: int = 0           # refcount reached zero, page back on the list
    pages_shared: int = 0          # pages mapped shared at admission (prefix hits)
    prefix_hits: int = 0           # admissions that matched a shared prefix
    cow_copies: int = 0            # copy-on-write page copies (divergence)
    prefix_evictions: int = 0      # registry entries dropped under pressure
    peak_page_occupancy: int = 0   # max pages simultaneously referenced


@dataclasses.dataclass
class AdmitPlan:
    """Priced admission for one candidate prompt (nothing allocated yet)."""
    shared_len: int         # matched prefix length in tokens (0 = no match)
    n_shared: int           # pages mapped shared (incl. a partial final page)
    shared_full: int        # full shared pages — never written again, ever
    new_now: int            # pages allocated during admission itself
    budget: int             # worst-case lifetime allocations for this request
    extra_parent: int       # +1 when forking a live partial page (parent may CoW)
    # shared pages currently held ONLY by the registry: admitting pins them
    # (incref), which removes them from the evictable set — they must be
    # priced as consumed availability or the gate over-admits
    n_shared_evictable: int = 0
    parent: Optional["PageTable"] = None   # live fork source, if any
    shared_pages: Tuple[int, ...] = ()

    @property
    def worst_case(self) -> int:
        return self.budget + self.extra_parent


class PageTable:
    """One request's logical-to-physical page mapping."""
    __slots__ = ("uid", "pages", "length", "prompt_len", "budget",
                 "allocated", "prompt_key", "released")

    def __init__(self, uid: int, prompt_len: int, budget: int,
                 prompt_key: bytes):
        self.uid = uid
        self.pages: List[int] = []
        self.length = 0            # KV rows written (prompt + generated)
        self.prompt_len = prompt_len
        self.budget = budget       # worst-case allocations (commit accounting)
        self.allocated = 0         # allocations so far (<= budget, strict mode)
        self.prompt_key = prompt_key
        self.released = False

    @property
    def n_pages(self) -> int:
        return len(self.pages)


class PagePool:
    """Owner of all paged KV memory: arenas + allocator + prefix sharing.

    `layout="stacked"` keeps the arena pytree `{sub_j: [G, ...]}` for the
    jitted resident decode scan; `layout="groups"` keeps a list of G
    per-group pytrees for the host-driven layerwise (offload) decode. Arena
    mutation (prompt writes, CoW copies) handles either.

    Construction raises `ValueError` — never silently degrades — for layouts
    pages cannot represent: non-attention sublayers (SSM state is per-slot,
    not positional) and sliding-window caches are rejected by
    `init_paged_stack_cache` / the server; the int8 `QuantKVCache` layout is
    fully supported (per-page-row scales ride in the arena pytree).
    """

    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_len: int, layout: str = "stacked",
                 overcommit: bool = False, dtype=None):
        if layout not in ("stacked", "groups"):
            raise ValueError(f"unknown pool layout {layout!r}")
        if max_len < 1:
            raise ValueError("max_len must be >= 1")
        # init_paged_stack_cache validates num_pages/page_size/layer kinds and
        # picks the float vs int8 arena from cfg.kv_quant
        cache = transformer.init_paged_stack_cache(cfg, num_pages, page_size,
                                                   dtype=dtype)
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.null_page = num_pages           # arena row reserved for garbage
        self.max_len = max_len
        self.max_pages_per_seq = cdiv(max_len, page_size)
        self.layout = layout
        self.overcommit = overcommit
        self.quant = bool(cfg.kv_quant)
        if layout == "stacked":
            self.cache = cache
            self.cache_groups = None
        else:
            self.cache = None
            self.cache_groups = transformer.unstack_groups(cache, cfg)
        # -- allocator state --------------------------------------------------
        self._refc = np.zeros(num_pages, dtype=np.int64)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # pop() -> 0
        # -- prefix sharing ---------------------------------------------------
        self._registry: "OrderedDict[bytes, Tuple[int, Tuple[int, ...]]]" = \
            OrderedDict()
        self._registry_refc = np.zeros(num_pages, dtype=np.int64)
        self._live_prompts: Dict[bytes, PageTable] = {}
        self._active: List[PageTable] = []
        self.stats = PagePoolStats()

    # -- allocator ------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.num_pages - len(self._free)

    def n_evictable(self) -> int:
        """Pages held ONLY by the prefix registry — freeable on demand."""
        return int(np.sum((self._refc > 0)
                          & (self._refc == self._registry_refc)))

    def _evictable_entry_key(self) -> Optional[bytes]:
        """Oldest (FIFO) registry entry holding at least one registry-only
        page. Evicting such entries makes progress toward a free page (each
        eviction strictly reduces total registry refs, and a registry-only
        page's refs are ALL registry refs); entries whose pages are all
        pinned by live tables would free nothing and are skipped — evicting
        them only throws away future sharing."""
        for key, (_, pages) in self._registry.items():
            if any(self._refc[p] == self._registry_refc[p] for p in pages):
                return key
        return None

    def _alloc_page(self) -> Optional[int]:
        """Pop a free page, evicting registry prefixes FIFO if the list is
        dry — skipping entries that cannot free a page, and stopping once no
        remaining entry can. None means genuinely out of memory (caller
        preempts/defers)."""
        while not self._free:
            key = self._evictable_entry_key()
            if key is None:
                break
            self._evict_one_prefix(key)
        if not self._free:
            return None
        p = self._free.pop()
        assert self._refc[p] == 0, f"page {p} on free list with refc>0"
        self._refc[p] = 1
        get_tracer().instant("page_alloc", page=p, free=len(self._free))
        self.stats.pages_allocated += 1
        self.stats.peak_page_occupancy = max(self.stats.peak_page_occupancy,
                                             self.n_live)
        return p

    def _incref(self, p: int) -> None:
        assert self._refc[p] > 0, f"incref on free page {p}"
        self._refc[p] += 1

    def _decref(self, p: int) -> None:
        assert self._refc[p] > 0, f"decref on free page {p}"
        self._refc[p] -= 1
        if self._refc[p] == 0:
            self._free.append(p)
            self.stats.pages_freed += 1

    def check(self) -> None:
        """Allocator invariants (the property tests drive this after every
        operation): refcounts conserve, the free list is duplicate-free and
        disjoint from live pages, registry refs never exceed total refs."""
        free = self._free
        assert len(set(free)) == len(free), "duplicate pages on the free list"
        assert all(self._refc[p] == 0 for p in free), \
            "live page on the free list"
        n_live = int(np.sum(self._refc > 0))
        assert n_live + len(free) == self.num_pages, \
            f"page conservation violated: {n_live} live + {len(free)} free " \
            f"!= {self.num_pages}"
        assert np.all(self._registry_refc <= self._refc), \
            "registry holds refs on pages it does not reference"
        assert np.all(self._refc >= 0)

    # -- admission ------------------------------------------------------------
    def _match_registry(self, prompt: np.ndarray) -> Tuple[int, Tuple[int, ...]]:
        """Longest registered page-aligned prefix of `prompt` (exact bytes)."""
        T = len(prompt)
        P = self.page_size
        for L in range((T // P) * P, 0, -P):
            hit = self._registry.get(prompt[:L].tobytes())
            if hit is not None:
                return hit
        return 0, ()

    def _match_live(self, prompt: np.ndarray) -> Tuple[int, Optional[PageTable]]:
        """Longest live request whose FULL prompt is a byte-prefix of
        `prompt` (the copy-on-write fork source)."""
        T = len(prompt)
        best_len, best = 0, None
        for key, table in self._live_prompts.items():
            L = table.prompt_len
            if L <= best_len or L > T or table.length < L or table.released:
                continue
            if prompt[:L].tobytes() == key:
                best_len, best = L, table
        return best_len, best

    def plan_admit(self, prompt: np.ndarray, max_new_tokens: int) -> AdmitPlan:
        """Price an admission without touching allocator state."""
        prompt = np.asarray(prompt, dtype=np.int32)
        T = len(prompt)
        P = self.page_size
        L_reg, reg_pages = self._match_registry(prompt)
        L_live, parent = self._match_live(prompt)
        if L_live > L_reg:
            L, shared = L_live, tuple(parent.pages[:cdiv(L_live, P)])
        else:
            L, shared, parent = L_reg, reg_pages, None
        partial = L % P != 0
        n_shared = len(shared)
        shared_full = L // P
        total_prompt_pages = cdiv(T, P)
        # a shared partial page is CoW-replaced the moment this request writes
        # into it: immediately if the prompt extends past L, else on the first
        # decode append
        new_now = total_prompt_pages - n_shared + (1 if partial and T > L else 0)
        budget = cdiv(T + max_new_tokens, P) - shared_full
        n_shared_evictable = sum(
            1 for p in shared if self._refc[p] == self._registry_refc[p])
        return AdmitPlan(shared_len=L, n_shared=n_shared,
                         shared_full=shared_full, new_now=new_now,
                         budget=budget, extra_parent=1 if partial else 0,
                         n_shared_evictable=n_shared_evictable,
                         parent=parent, shared_pages=shared)

    def committed_outstanding(self) -> int:
        """Pages the pool has promised active tables but not yet handed out."""
        return sum(max(t.budget - t.allocated, 0) for t in self._active
                   if not t.released)

    def can_admit(self, plan: AdmitPlan) -> bool:
        """Strict mode reserves the candidate's worst case against everyone
        else's outstanding commitments (admitted => can always finish);
        overcommit gates only the immediate prompt need.

        Shared pages currently held only by the registry stop being
        evictable the instant this candidate pins them (incref), so they are
        subtracted from availability up front — otherwise the gate approves
        admissions the allocator cannot serve, and in strict mode the pinned
        pages would silently invalidate the worst-case reservations already
        promised to active requests."""
        available = (self.n_free + self.n_evictable()
                     - plan.n_shared_evictable)
        if self.overcommit:
            return plan.new_now <= available
        return plan.worst_case <= available - self.committed_outstanding()

    def admit(self, prompt: np.ndarray, max_new_tokens: int, uid: int
              ) -> Tuple[Optional[PageTable], AdmitPlan]:
        """Build a page table for `prompt`: map the matched shared prefix,
        CoW-replace a shared partial page the prompt extends past, allocate
        the rest. Returns (None, plan) only when the pool is dry mid-admission
        (possible in overcommit mode); every partial allocation is rolled
        back, so a failed admit leaves no residue."""
        prompt = np.asarray(prompt, dtype=np.int32)
        T = len(prompt)
        P = self.page_size
        plan = self.plan_admit(prompt, max_new_tokens)
        table = PageTable(uid=uid, prompt_len=T, budget=plan.budget,
                          prompt_key=prompt.tobytes())
        for p in plan.shared_pages:
            self._incref(p)
            table.pages.append(p)
        if plan.shared_len > 0:
            self.stats.prefix_hits += 1
            self.stats.pages_shared += plan.n_shared
        partial_idx = plan.shared_len // P if plan.shared_len % P else -1
        if partial_idx >= 0 and T > plan.shared_len:
            # the prompt extends into the shared partial page: diverge NOW
            if not self._cow(table, partial_idx):
                self._rollback(table)
                return None, plan
        for _ in range(len(table.pages), cdiv(T, P)):
            p = self._alloc_page()
            if p is None:
                self._rollback(table)
                return None, plan
            table.pages.append(p)
            table.allocated += 1
        table.length = T
        if plan.parent is not None and plan.extra_parent:
            # charge the parent's possible CoW only once the admit is final —
            # a rolled-back admit must leave the parent's commitment intact
            plan.parent.budget += plan.extra_parent
        self._active.append(table)
        self._live_prompts.setdefault(table.prompt_key, table)
        return table, plan

    def _rollback(self, table: PageTable) -> None:
        for p in table.pages:
            self._decref(p)
        table.pages.clear()

    # -- arena mutation --------------------------------------------------------
    def _map_arenas(self, fn) -> None:
        """Apply `fn(arena_namedtuple) -> arena_namedtuple` to every paged
        leaf group in whichever layout the pool holds."""
        leaf_types = (PagedKVCache, PagedQuantKVCache)
        if self.layout == "stacked":
            self.cache = {sub: fn(arena) for sub, arena in self.cache.items()
                          if isinstance(arena, leaf_types)}
        else:
            self.cache_groups = [
                {sub: fn(arena) for sub, arena in group.items()
                 if isinstance(arena, leaf_types)}
                for group in self.cache_groups]

    def _copy_page(self, src: int, dst: int) -> None:
        """Copy one physical page across every layer's arena (CoW)."""
        if self.layout == "stacked":
            cp = lambda a: type(a)(*[leaf.at[:, dst].set(leaf[:, src])
                                     for leaf in a])
        else:
            cp = lambda a: type(a)(*[leaf.at[dst].set(leaf[src])
                                     for leaf in a])
        self._map_arenas(cp)

    def _cow(self, table: PageTable, page_idx: int) -> bool:
        """Replace table.pages[page_idx] with a private copy (the page is
        shared — refcount > 1). Sharers and the registry keep the original."""
        src = table.pages[page_idx]
        dst = self._alloc_page()
        if dst is None:
            return False
        self._copy_page(src, dst)
        self._decref(src)
        table.pages[page_idx] = dst
        table.allocated += 1
        get_tracer().instant("cow_copy", uid=table.uid, src=src, dst=dst)
        self.stats.cow_copies += 1
        return True

    def write_prompt(self, table: PageTable, small_cache: Any) -> None:
        """Block-copy a freshly prefilled B=1 contiguous cache into the
        request's pages, skipping pages mapped shared (their bytes are
        identical by construction — same prompt prefix, same deterministic
        prefill). `small_cache` is the stacked `{sub_j: KVCache|QuantKVCache
        [G, 1, S, KV, hd]}` pytree `Model.init_cache(1, ...)` produced."""
        T = table.prompt_len
        P = self.page_size
        n_pages = cdiv(T, P)
        # first page this request owns (refcount 1): shared full pages and a
        # still-shared partial page (exact-match fork) must not be written
        first = 0
        while first < n_pages and self._refc[table.pages[first]] > 1:
            first += 1
        for i in range(first, n_pages):
            lo, hi = i * P, min(T, (i + 1) * P)
            phys = table.pages[i]
            if self.layout == "stacked":
                self.cache = {
                    sub: type(arena)(*[
                        leaf.at[:, phys, :hi - lo].set(
                            s[:, 0, lo:hi].astype(leaf.dtype))
                        for leaf, s in zip(arena, small_cache[sub])])
                    for sub, arena in self.cache.items()}
            else:
                self.cache_groups = [
                    {sub: type(arena)(*[
                        leaf.at[phys, :hi - lo].set(
                            s[g_idx, 0, lo:hi].astype(leaf.dtype))
                        for leaf, s in zip(arena, small_cache[sub])])
                     for sub, arena in group.items()}
                    for g_idx, group in enumerate(self.cache_groups)]

    def register_prefixes(self, prompt: np.ndarray, table: PageTable) -> None:
        """Register every page-aligned prefix of a just-written prompt in the
        prefix registry (full pages only — registered pages are immutable, so
        later sharers never force a copy). Entries hold their own refs and
        outlive the request; `clear_prefix_cache` / FIFO eviction releases
        them."""
        prompt = np.asarray(prompt, dtype=np.int32)
        P = self.page_size
        for L in range(P, len(prompt) + 1, P):
            key = prompt[:L].tobytes()
            if key in self._registry:
                continue
            pages = tuple(table.pages[:L // P])
            for p in pages:
                self._incref(p)
                self._registry_refc[p] += 1
            self._registry[key] = (L, pages)

    # -- decode growth ---------------------------------------------------------
    def prepare_append(self, table: PageTable, position: int) -> bool:
        """Make `position` writable for this request before the decode step:
        grow the table by one page at a page boundary, CoW a shared page at a
        divergence point. False = pool dry even after prefix eviction (the
        server's page-pressure hook preempts and retries)."""
        idx = position // self.page_size
        if idx >= len(table.pages):
            assert idx == len(table.pages), \
                "page tables grow one page at a time"
            p = self._alloc_page()
            if p is None:
                return False
            table.pages.append(p)
            table.allocated += 1
        elif self._refc[table.pages[idx]] > 1:
            if not self._cow(table, idx):
                return False
        table.length = max(table.length, position + 1)
        return True

    def page_table_row(self, table: Optional[PageTable],
                       out: np.ndarray) -> None:
        """Fill one row of the [B, max_pages] page-table array (null-page
        padded; a None table — free slot — stays all-null)."""
        out[:] = self.null_page
        if table is not None:
            out[:len(table.pages)] = table.pages

    # -- reclamation -----------------------------------------------------------
    def release(self, table: PageTable) -> None:
        """Drop every reference a retired request holds. Idempotent; shared
        pages survive through their other holders (registry included)."""
        if table.released:
            return
        table.released = True
        for p in table.pages:
            self._decref(p)
        table.pages.clear()
        if table in self._active:
            self._active.remove(table)
        if self._live_prompts.get(table.prompt_key) is table:
            del self._live_prompts[table.prompt_key]
            # a still-live duplicate of the same prompt is just as good a
            # fork source — re-point instead of losing the sharing
            for t in self._active:
                if t.prompt_key == table.prompt_key:
                    self._live_prompts[table.prompt_key] = t
                    break

    def _evict_one_prefix(self, key: Optional[bytes] = None) -> None:
        if key is None:
            key, (_, pages) = self._registry.popitem(last=False)   # FIFO
        else:
            _, pages = self._registry.pop(key)
        for p in pages:
            self._registry_refc[p] -= 1
            self._decref(p)
        get_tracer().instant("prefix_evict", n_pages=len(pages))
        self.stats.prefix_evictions += 1

    def clear_prefix_cache(self) -> int:
        """Release every registry entry (end-of-run reclamation; the property
        tests assert the free list is full afterwards)."""
        n = len(self._registry)
        while self._registry:
            self._evict_one_prefix()
        return n

    def summary(self) -> Dict[str, Any]:
        """io_summary-style reporting surface (launch/serve.py prints it)."""
        s = self.stats
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "kv_positions": self.num_pages * self.page_size,
            "quantized": self.quant,
            "overcommit": self.overcommit,
            "n_free": self.n_free,
            "n_live": self.n_live,
            "registry_entries": len(self._registry),
            "pages_allocated": s.pages_allocated,
            "pages_freed": s.pages_freed,
            "pages_shared": s.pages_shared,
            "prefix_hits": s.prefix_hits,
            "cow_copies": s.cow_copies,
            "prefix_evictions": s.prefix_evictions,
            "peak_page_occupancy": s.peak_page_occupancy,
        }
