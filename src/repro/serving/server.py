"""InferenceServer — slot-based continuous batching with an explicit request
lifecycle (QUEUED -> PREFILL -> DECODE -> FINISHED).

The one-shot `ServingEngine.serve()` bucketed requests by exact prompt length
and decoded each bucket in lockstep for max(max_new_tokens) steps: mixed-length
traffic never shared a batch, finished requests kept burning compute *and
attributed flash I/O*, and nothing could arrive mid-flight. This module is the
request-lifecycle runtime that replaces that barrier:

  * a fixed pool of `max_slots` KV-cache decode slots, each with its own
    sequence position (`models/transformer.py` decode steps take a per-slot
    position vector);
  * `submit(request) -> RequestHandle`, valid any time — including while other
    requests are decoding (mid-flight admission);
  * `step()` advances the server by one iteration: queued requests are
    admitted into free slots (each gets its own dense prefill, written into
    its slot — no group-by-length barrier), then one batched decode iteration
    runs over the active slots;
  * retirement on `max_new_tokens` ("length") or a stop token ("stop") frees
    the slot immediately: the retired row is dropped from every subsequent
    activation-mask union, so a finished request stops incurring flash I/O
    the step it finishes;
  * streaming via `submit(..., on_token=...)` callbacks or the pull-based
    `stream(handle)` iterator.

Offload mode rides the same loop: the [n_slots, n_neurons] activation-mask
matrix (inactive rows zeroed) feeds `OffloadEngine.step_masks`, per-uid I/O
attribution accumulates on each handle (summing exactly to the engines' merged
read time), and in prefetch mode ONE `PrefetchWorker` stays up across the
whole server run instead of starting/stopping per request group.

Sampling is grouping-invariant: request `uid`'s token `t` is sampled from the
stream `fold_in(fold_in(PRNGKey(seed), uid), t)`, so a request's tokens do not
depend on which batch, group, or slot it landed in — serving a request alone
and serving it inside any continuous batch produce identical output (greedy
AND temperature sampling), which is what the admission-order identity tests
assert.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import IOScheduler
from repro.core.predictor import PredictorParams, predict_mask
from repro.utils import logger
from repro.models import transformer
from repro.models.layers import apply_norm, embed_tokens, unembed
from repro.models.model import Model
from repro.serving.engine import (OffloadedFFNRuntime, Request, Result,
                                  request_key)


class RequestState(enum.Enum):
    """Lifecycle of a request inside the server."""
    QUEUED = "queued"        # submitted, waiting for a free decode slot
    PREFILL = "prefill"      # admitted; its prompt is being prefilled
    DECODE = "decode"        # occupying a slot, generating tokens
    FINISHED = "finished"    # retired; `result` is populated


@dataclasses.dataclass
class RequestHandle:
    """Live view of one submitted request.

    `tokens` grows as the server steps (the streaming surface — read it, or
    register `on_token`, or drive `server.stream(handle)`); `result` is set at
    retirement. Timing fields accumulate while the request is in flight:
    `decode_seconds`/`overlapped_seconds` add each decode iteration's wall
    (every active request shares the batched step, same convention as the
    one-shot path), `io_seconds` adds this request's attributed share of the
    engines' flash reads.
    """
    request: Request
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    # "length" | "stop" | "error" once FINISHED
    finish_reason: Optional[str] = None
    result: Optional[Result] = None
    error: Optional[BaseException] = None    # set iff finish_reason=="error"
    slot: Optional[int] = None
    on_token: Optional[Callable[[int, int], None]] = None   # (uid, token)
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    io_seconds: float = 0.0
    overlapped_seconds: float = 0.0
    _key: Any = None                         # fold_in(base_key, uid)
    _order: int = 0                          # submission order

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED


@dataclasses.dataclass
class ServerStats:
    """Aggregate counters over the server's lifetime (benchmark surface)."""
    n_slots: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0       # wall of the batched decode iterations
    decode_steps: int = 0
    tokens_emitted: int = 0
    admitted: int = 0
    slot_steps_active: int = 0        # Σ over decode steps of active slots

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        denom = self.decode_steps * max(self.n_slots, 1)
        return self.slot_steps_active / denom if denom else 0.0


class InferenceServer:
    """Slot-based continuous-batching front-end over one model.

    Same mode surface as `ServingEngine` (resident | offload, optional
    prefetch pipeline + lookahead source), but requests are individually
    admitted, decoded at per-slot positions, and individually retired.
    `ServingEngine.serve()` is the submit-all + drain compatibility wrapper
    over this class.

    Typical use::

        server = InferenceServer(model, params, max_slots=4, max_len=256)
        h = server.submit(Request(uid=0, prompt=prompt, max_new_tokens=32))
        for tok in server.stream(h):      # pumps server.step() as needed
            ...
        server.close()

    or batch-style: submit many, then `drain()`.
    """

    def __init__(self, model: Model, params: Any, *, max_slots: int = 4,
                 max_len: int = 512, swa: bool = False, mode: str = "resident",
                 offload: Optional[OffloadedFFNRuntime] = None,
                 scheduler: Optional[IOScheduler] = None,
                 oracle: bool = True, prefetch: bool = False,
                 lookahead: Union[str, List[PredictorParams], None] = None,
                 seed: int = 0, decode_fn=None,
                 pack_path: Optional[str] = None):
        """`decode_fn` lets a long-lived caller (ServingEngine) share one
        jitted resident decode across servers; by default the server jits its
        own. `lookahead` follows ServingEngine: predictor params, None (use
        the runtime's trained lookahead), or "oracle" (zero speculation
        depth — the exactness fallback). `pack_path` loads the offload
        runtime from an on-disk NeuronPack artifact
        (`OffloadedFFNRuntime.from_pack`, geometry-validated against the
        model config) instead of a caller-built runtime."""
        if mode not in ("resident", "offload"):
            raise ValueError(f"unknown serving mode {mode!r}")
        cfg = model.cfg
        if cfg.is_encdec:
            raise ValueError("InferenceServer covers decoder-only stacks")
        if pack_path is not None:
            if offload is not None:
                raise ValueError("pass either `offload` or `pack_path`, "
                                 "not both")
            if mode != "offload":
                raise ValueError("pack_path= requires mode='offload'")
            offload = OffloadedFFNRuntime.from_pack(cfg, pack_path)
        if mode == "offload":
            if offload is None:
                raise ValueError("mode='offload' needs an OffloadedFFNRuntime")
            if cfg.family != "dense":
                raise ValueError("offload serving covers dense decoder-only archs")
        if isinstance(lookahead, str) and lookahead != "oracle":
            raise ValueError(f"unknown lookahead mode {lookahead!r}")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.swa = swa
        self.mode = mode
        self.offload = offload
        self._owns_offload = pack_path is not None   # we built it: we close it
        self.oracle = oracle
        self.prefetch = prefetch
        self.lookahead = lookahead
        self.scheduler = scheduler or IOScheduler(overlap=True)
        self.stats = ServerStats(n_slots=max_slots)
        self._base_key = jax.random.PRNGKey(seed)
        self._queue: "collections.deque[RequestHandle]" = collections.deque()
        self._handles: Dict[int, RequestHandle] = {}   # queued + in-flight
        self._finished: List[RequestHandle] = []
        self._n_submitted = 0
        # slot pool: per-slot handle / next-decode position / last token
        self._slot_handle: List[Optional[RequestHandle]] = [None] * max_slots
        self._slot_pos = np.zeros(max_slots, dtype=np.int32)
        self._cur = np.zeros(max_slots, dtype=np.int32)
        if mode == "resident":
            self._cache = model.init_cache(max_slots, max_len, swa=swa)
            self._decode_fn = decode_fn or jax.jit(
                lambda p, t, pos, c: model.decode_step(p, t, pos, c))
        else:
            self._cache_groups = transformer.unstack_groups(
                model.init_cache(max_slots, max_len, swa=swa), cfg)
            self._param_groups = transformer.unstack_groups(
                params["stack"], cfg)
            self._w_ups = _oracle_w_ups(model, params) if oracle else None
            if self._w_ups is not None and len(self._w_ups) != offload.n_layers:
                raise ValueError(
                    f"runtime has {offload.n_layers} layer engines, model has "
                    f"{len(self._w_ups)} dense FFN layers")
            # lookahead source resolution, identical to ServingEngine: params
            # > runtime-trained > "oracle" (depth 0)
            la = lookahead if not isinstance(lookahead, str) else None
            if la is None and lookahead is None:
                la = offload.lookahead
            if la is not None and la is not offload.lookahead:
                offload.lookahead = la
                offload._lookahead_np = None
            self._la_params = la
            if prefetch and la is not None and \
                    cfg.activation not in ("relu", "relu2"):
                # speculative lookahead OVER-predicts by design; both FFN
                # paths (bundles and the fused segment kernel) evaluate the
                # whole SERVED union — speculated neurons included — which is
                # only exact when act(pre <= 0) == 0. Oracle lookahead
                # (la=None, zero speculation depth) stays exact for any
                # activation, on either kernel: the segment path masks
                # covered-but-not-served neurons in-kernel.
                raise ValueError(
                    f"prefetch with speculative lookahead is exact only for "
                    f"relu/relu2 activations, not {cfg.activation!r}; use "
                    f"lookahead='oracle' or serve serially")

    # -- submission ----------------------------------------------------------
    def submit(self, request: Request,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> RequestHandle:
        """Queue a request; valid any time, including mid-decode.

        Raises ValueError if the request cannot fit its slot: the prompt plus
        `max_new_tokens` must fit in `max_len` KV-cache positions (prompt
        tokens occupy [0, T); generated token i is decoded at position T+i-1,
        so the last decode writes position T + max_new_tokens - 2 < max_len).
        """
        T = len(request.prompt)
        if T < 1:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens must be >= 1")
        if T + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({T} tokens) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds the server's max_len "
                f"({self.max_len}); shorten the request or raise max_len")
        if request.uid in self._handles:
            raise ValueError(f"duplicate request uid {request.uid}")
        handle = RequestHandle(request=request, on_token=on_token,
                               _key=request_key(self._base_key, request.uid),
                               _order=self._n_submitted)
        self._n_submitted += 1
        self._handles[request.uid] = handle
        self._queue.append(handle)
        return handle

    # -- introspection -------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(h is not None for h in self._slot_handle)

    @property
    def n_active(self) -> int:
        return sum(h is not None for h in self._slot_handle)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def results(self) -> List[Result]:
        """Finished results the server still holds, in submission order."""
        return [h.result for h in sorted(self._finished,
                                         key=lambda h: h._order)]

    def release_finished(self) -> int:
        """Drop the server's references to finished requests (their handles
        stay valid for the caller). A long-lived server should call this
        periodically — or after consuming `drain()`/`results()` — so memory
        stays bounded by in-flight work, not by total requests served.
        Returns the number of handles released."""
        n = len(self._finished)
        self._finished.clear()
        return n

    # -- the serving loop ----------------------------------------------------
    def step(self) -> int:
        """Advance the server one iteration: admit queued requests into free
        slots (per-request prefill), then run one batched decode iteration
        over the active slots. Returns the number of tokens emitted.

        Error isolation, batch scope: an exception out of the shared decode
        computation (a flash read that exhausted its retries, a failing
        store) cannot be attributed to one request, so every active request
        is retired with `finish_reason="error"` and the exception attached
        — but the SERVER survives: queued and future submissions admit and
        decode normally. Per-request failures (sampling, a raising
        `on_token` callback, a failing prefill) are caught deeper down and
        retire only the offending request."""
        emitted = 0
        while self._queue and None in self._slot_handle:
            emitted += self._admit(self._queue.popleft())
        if any(h is not None for h in self._slot_handle):
            try:
                emitted += self._decode_iteration()
            except Exception as e:  # noqa: BLE001 — isolate, don't crash
                logger.warning("decode iteration failed (%r); retiring the "
                               "active batch with finish_reason='error'", e)
                for h in list(self._slot_handle):
                    if h is not None:
                        self._fail_request(h, e)
        return emitted

    def drain(self) -> List[Result]:
        """Step until every submitted request is finished."""
        while self.has_work:
            self.step()
        return self.results()

    def stream(self, handle: RequestHandle) -> Iterator[int]:
        """Yield `handle`'s tokens as they are generated, pumping `step()`
        whenever the caller is ahead of the server. Other in-flight requests
        advance too — they share the batched decode iterations."""
        i = 0
        while True:
            while i < len(handle.tokens):
                yield handle.tokens[i]
                i += 1
            if handle.done:
                return
            self.step()

    def abort(self, reason: Union[str, BaseException] = "aborted") -> int:
        """Retire every queued and in-flight request with
        `finish_reason="error"` (partial tokens preserved on each Result) —
        the graceful-interrupt path `launch/serve.py` uses on
        KeyboardInterrupt. Returns the number of requests retired; the
        server stays usable for new submissions."""
        exc = (reason if isinstance(reason, BaseException)
               else RuntimeError(str(reason)))
        n = 0
        while self._queue:
            self._fail_request(self._queue.popleft(), exc)
            n += 1
        for h in list(self._slot_handle):
            if h is not None:
                self._fail_request(h, exc)
                n += 1
        return n

    def close(self) -> None:
        """Release background resources: the prefetch worker always; the
        offload runtime's stores too when this server built the runtime
        itself (pack_path=). The server stays usable for inspection;
        further steps would restart the worker."""
        if self.mode == "offload" and self.offload is not None:
            if self._owns_offload:
                self.offload.close()
            else:
                self.offload.stop_prefetch()

    def __enter__(self) -> "InferenceServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission / retirement ----------------------------------------------
    def _admit(self, handle: RequestHandle) -> int:
        """Prefill one queued request into a free slot. Failure-isolated: an
        exception anywhere in admission (prefill, slot write, the first
        token's `on_token` callback) retires THIS request with
        `finish_reason="error"` and leaves the rest of the server intact."""
        slot = self._slot_handle.index(None)
        r = handle.request
        handle.state = RequestState.PREFILL
        handle.slot = slot
        try:
            T = len(r.prompt)
            prompt = jnp.asarray(np.asarray(r.prompt, dtype=np.int32)[None])
            t0 = time.perf_counter()
            small = self.model.init_cache(1, self.max_len, swa=self.swa)
            logits, small = self.model.prefill(self.params, {"tokens": prompt},
                                               small)
            row = np.asarray(logits[0, -1], dtype=np.float32)  # forces the sync
            handle.prefill_seconds = time.perf_counter() - t0
            self.stats.prefill_seconds += handle.prefill_seconds
            self.stats.admitted += 1
            self._write_slot(slot, small)
            self._slot_handle[slot] = handle
            self._slot_pos[slot] = T
            handle.state = RequestState.DECODE
            tok = self._sample_row(handle, row)
            self._cur[slot] = tok
            self._emit(handle, tok)
        except Exception as e:  # noqa: BLE001 — per-request isolation
            self._fail_request(handle, e)
            return 0
        return 1

    def _write_slot(self, slot: int, small_cache: Any) -> None:
        """Copy a freshly prefilled B=1 cache into row `slot` of the pool.

        Stale KV beyond the new prompt is harmless: decode writes a position's
        KV before attending to it, and causal masking hides everything past
        the current position."""
        if self.mode == "resident":
            # stacked leaves are [G, B, ...]: batch is axis 1
            self._cache = jax.tree_util.tree_map(
                lambda big, s: big.at[:, slot].set(s[:, 0]),
                self._cache, small_cache)
        else:
            small_groups = transformer.unstack_groups(small_cache, self.cfg)
            self._cache_groups = [
                jax.tree_util.tree_map(lambda big, s: big.at[slot].set(s[0]),
                                       big_g, small_g)
                for big_g, small_g in zip(self._cache_groups, small_groups)]

    def _emit(self, handle: RequestHandle, tok: int) -> None:
        handle.tokens.append(tok)
        self.stats.tokens_emitted += 1
        if handle.on_token is not None:
            handle.on_token(handle.uid, tok)
        if tok in handle.request.stop_tokens:
            self._retire(handle, "stop")
        elif len(handle.tokens) >= handle.request.max_new_tokens:
            self._retire(handle, "length")

    def _retire(self, handle: RequestHandle, reason: str,
                error: Optional[BaseException] = None) -> None:
        handle.finish_reason = reason
        handle.error = error
        handle.state = RequestState.FINISHED
        handle.result = Result(
            uid=handle.uid, tokens=list(handle.tokens),
            prefill_seconds=handle.prefill_seconds,
            decode_seconds=handle.decode_seconds,
            io_seconds=handle.io_seconds,
            overlapped_seconds=handle.overlapped_seconds,
            finish_reason=reason, error=error)
        if handle.slot is not None:                 # error-retired requests
            self._slot_handle[handle.slot] = None   # may never have held a
            handle.slot = None                      # slot; freed rows leave
        self._handles.pop(handle.uid, None)         # every future mask union
        self._finished.append(handle)

    def _fail_request(self, handle: RequestHandle,
                      exc: BaseException) -> None:
        """Retire one request with `finish_reason="error"`: partial tokens
        stay on the Result, the exception is attached, the slot (if any) is
        freed, and everything else in the batch keeps decoding."""
        if handle.done:
            return
        logger.warning("request %d failed (%r); retiring with "
                       "finish_reason='error'", handle.uid, exc)
        self._retire(handle, "error", error=exc)

    # -- sampling (per-request streams) ---------------------------------------
    def _sample_row(self, handle: RequestHandle, row: np.ndarray) -> int:
        """Sample token t = len(handle.tokens) of this request from its own
        stream. Row-wise, so the value is independent of batch composition."""
        temp = handle.request.temperature
        if temp <= 0:
            return int(np.argmax(row))
        key = jax.random.fold_in(handle._key, len(handle.tokens))
        return int(jax.random.categorical(
            key, jnp.asarray(row, jnp.float32) / temp))

    # -- decode ---------------------------------------------------------------
    def _active_mask(self) -> np.ndarray:
        return np.array([h is not None for h in self._slot_handle], dtype=bool)

    def _decode_iteration(self) -> int:
        active = self._active_mask()
        if self.mode == "resident":
            logits_rows, token_wall, req_io, over = self._decode_resident()
        else:
            logits_rows, token_wall, req_io, over = self._decode_offload(active)
        self.stats.decode_seconds += token_wall
        self.stats.decode_steps += 1
        self.stats.slot_steps_active += int(active.sum())
        # conservation: I/O the engine attributed to now-inactive rows (pure
        # over-speculation splits evenly over ALL rows) is re-billed evenly to
        # the active requests, so Σ per-request io == Σ engine merged reads
        orphan = float(req_io[~active].sum())
        share = orphan / max(int(active.sum()), 1)
        emitted = 0
        for slot in np.flatnonzero(active):
            handle = self._slot_handle[slot]
            handle.decode_seconds += token_wall
            handle.overlapped_seconds += over
            handle.io_seconds += float(req_io[slot]) + share
            # per-request isolation: sampling or a raising on_token callback
            # retires only THIS request; the loop continues for the rest of
            # the batch (the shared compute above already succeeded).
            try:
                tok = self._sample_row(handle, logits_rows[slot])
                self._slot_pos[slot] += 1
                self._cur[slot] = tok
                self._emit(handle, tok)             # may free the slot
                emitted += 1
            except Exception as e:  # noqa: BLE001
                self._fail_request(handle, e)
        return emitted

    def _decode_resident(self):
        t0 = time.perf_counter()
        logits, self._cache = self._decode_fn(
            self.params, jnp.asarray(self._cur)[:, None],
            jnp.asarray(self._slot_pos), self._cache)
        rows = np.asarray(logits[:, 0], dtype=np.float32)   # the per-token sync
        wall = time.perf_counter() - t0
        return rows, wall, np.zeros(self.max_slots), 0.0

    # -- offload decode: masks -> batched engine step -> sparse FFN ----------
    def _true_masks(self, dense_idx: int, h2: jnp.ndarray,
                    active: np.ndarray) -> np.ndarray:
        """[n_slots, n_neurons] activation masks for one layer: the exact ReLU
        oracle (or trained predictor), with retired/free rows zeroed so they
        leave the union — a finished request incurs no further I/O."""
        if self._w_ups is not None:
            masks = np.asarray(h2 @ self._w_ups[dense_idx] > 0)
        else:
            assert self.offload.predictors is not None, \
                "oracle=False needs runtime predictors"
            masks = np.asarray(predict_mask(self.offload.predictors[dense_idx], h2))
        return masks & active[:, None]

    def _decode_offload(self, active: np.ndarray):
        cfg = self.cfg
        runtime = self.offload
        n_slots = self.max_slots
        n_layers = runtime.n_layers
        req_io = np.zeros(n_slots)
        if self.prefetch and not runtime.prefetch_active:
            runtime.start_prefetch()        # one worker for the whole run
        la_params = self._la_params if self.prefetch else None

        # Sync-free serial path: XLA dispatch runs ahead across layers while
        # the engine serves each layer's masks host-side; one end-of-token
        # sync, apportioned across stages by FLOPs (see ServingEngine notes).
        def override(dense_idx: int, normed2: jnp.ndarray) -> jnp.ndarray:
            h2 = normed2[:, 0]
            masks = self._true_masks(dense_idx, h2, active)
            y, res = runtime.ffn_apply_batch(dense_idx, h2, masks)
            flops = (2.0 * n_slots * res.merged.n_activated
                     * runtime.n_mats * cfg.d_model)
            self.scheduler.record_stage(dense_idx,
                                        io_seconds=res.merged.io.seconds,
                                        flops=flops)
            np.add(req_io, res.req_io_seconds, out=req_io)
            return y[:, None]

        # Pipelined path: submit layer k+1's speculated prefetch, then
        # complete layer k against its true mask (top-up for mis-predictions).
        def override_prefetch(dense_idx: int, normed2: jnp.ndarray) -> jnp.ndarray:
            h2 = normed2[:, 0]
            masks_true = self._true_masks(dense_idx, h2, active)
            if dense_idx == 0 or la_params is None:
                runtime.begin_layer(dense_idx, masks_true)   # depth 0
            if la_params is not None and dense_idx + 1 < n_layers:
                spec = runtime.predict_lookahead(dense_idx, np.asarray(h2))
                spec = spec & active[:, None]
                runtime.begin_layer(dense_idx + 1, spec)
            y, res, meas = runtime.complete_layer(dense_idx, h2, masks_true)
            flops = (2.0 * n_slots * res.merged.n_activated
                     * runtime.n_mats * cfg.d_model)
            self.scheduler.record_stage(dense_idx,
                                        io_seconds=res.merged.io.seconds,
                                        flops=flops, measured=meas)
            np.add(req_io, res.req_io_seconds, out=req_io)
            return y[:, None]

        ffn_override = override_prefetch if self.prefetch else override
        t0 = time.perf_counter()
        x = embed_tokens(self.params["embed"],
                         jnp.asarray(self._cur)[:, None], cfg)
        self.scheduler.begin_token()
        h, self._cache_groups = transformer.stack_decode_step_layerwise(
            self._param_groups, x, jnp.asarray(self._slot_pos),
            self._cache_groups, cfg, ffn_override=ffn_override)
        h = apply_norm(self.params["final_norm"], h, cfg)
        logits = unembed(self.params["embed"], h, cfg)
        rows = np.asarray(logits[:, 0], dtype=np.float32)   # ONE sync per token
        token_wall = time.perf_counter() - t0
        timing = self.scheduler.end_token(
            compute_seconds=token_wall,
            wall_seconds=token_wall if self.prefetch else None)
        over = (timing.measured_wall_seconds if self.prefetch
                else timing.overlapped_seconds)
        return rows, token_wall, req_io, over


def _oracle_w_ups(model: Model, params: Any) -> List[jnp.ndarray]:
    """Resident w_up handles per dense layer, in capture order — the exact
    ReLU support oracle the predictor approximates. The simulated flash still
    pays for every neuron the mask selects."""
    cfg = model.cfg
    P = transformer.stack_period(cfg)
    G = cfg.n_layers // P
    ffns = cfg.ffn_kinds()
    w_ups = []
    for g in range(G):
        for j in range(P):
            if ffns[j] == "dense":
                w_ups.append(params["stack"][f"sub_{j}"]["ffn"]["w_up"][g])
    return w_ups
