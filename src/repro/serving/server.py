"""InferenceServer — slot-based continuous batching with an explicit request
lifecycle (QUEUED -> PREFILL -> DECODE -> FINISHED).

The one-shot `ServingEngine.serve()` bucketed requests by exact prompt length
and decoded each bucket in lockstep for max(max_new_tokens) steps: mixed-length
traffic never shared a batch, finished requests kept burning compute *and
attributed flash I/O*, and nothing could arrive mid-flight. This module is the
request-lifecycle runtime that replaces that barrier:

  * a fixed pool of `max_slots` KV-cache decode slots, each with its own
    sequence position (`models/transformer.py` decode steps take a per-slot
    position vector);
  * `submit(request) -> RequestHandle`, valid any time — including while other
    requests are decoding (mid-flight admission);
  * `step()` advances the server by one iteration: queued requests are
    admitted into free slots (each gets its own dense prefill, written into
    its slot — no group-by-length barrier), then one batched decode iteration
    runs over the active slots;
  * retirement on `max_new_tokens` ("length") or a stop token ("stop") frees
    the slot immediately: the retired row is dropped from every subsequent
    activation-mask union, so a finished request stops incurring flash I/O
    the step it finishes;
  * streaming via `submit(..., on_token=...)` callbacks or the pull-based
    `stream(handle)` iterator.

Offload mode rides the same loop: the [n_slots, n_neurons] activation-mask
matrix (inactive rows zeroed) feeds `OffloadEngine.step_masks`, per-uid I/O
attribution accumulates on each handle (summing exactly to the engines' merged
read time), and in prefetch mode ONE `PrefetchWorker` stays up across the
whole server run instead of starting/stopping per request group.

Sampling is grouping-invariant: request `uid`'s token `t` is sampled from the
stream `fold_in(fold_in(PRNGKey(seed), uid), t)`, so a request's tokens do not
depend on which batch, group, or slot it landed in — serving a request alone
and serving it inside any continuous batch produce identical output (greedy
AND temperature sampling), which is what the admission-order identity tests
assert.

Overload robustness (the serving layer's failure mode at scale is overload,
not bad reads — see ROADMAP open item 2):

  * bounded admission queue with explicit backpressure — `queue_limit` caps
    the number of QUEUED requests; a full queue sheds the worst
    strictly-lower-priority queued request in favor of the newcomer, or
    retires the newcomer itself with `finish_reason="rejected"`;
  * priority + earliest-deadline-first admission order: free slots go to the
    highest priority class first, earliest TTFT deadline within a class,
    submission order as the tie-break;
  * per-request SLOs on a monotonic clock (`Request.ttft_slo_s` /
    `Request.itl_slo_s`, with server-wide defaults): a queued request whose
    TTFT deadline passes, or an active request whose inter-token gap blows
    its deadline, is retired with `finish_reason="timeout"` — partial tokens
    preserved, slot freed immediately, per-uid io_seconds attribution still
    conserved (the orphan re-billing below never drops attributed reads);
  * flash-I/O-aware admission (offload mode): before admitting into a freed
    slot, the server predicts the NEXT step's cost — per-layer mask unions of
    the active batch (plus a frequency estimate for the candidate) priced on
    the calibrated `UFSDevice` via `OffloadEngine.predict_read_seconds`, plus
    the scheduler's recent compute-per-token — and leaves the candidate
    QUEUED when that prediction would blow an active request's inter-token
    deadline (`ServerStats.io_deferrals` counts these);
  * a stall watchdog: `stall_limit` consecutive `step()` calls with work
    pending but no progress (nothing admitted, emitted, or retired) raise
    `ServerStalledError` instead of spinning forever in `drain()`;
  * bounded memory: `finished_high_water` auto-releases the oldest delivered
    results past the mark (`ServerStats.results_released` counts them;
    caller-held handles stay valid).

Paged KV cache (`page_size=`/`num_pages=`, see `serving/paging.py`): instead
of one full-`max_len` contiguous KV region per slot, all KV memory lives in a
shared page arena and each request maps exactly the pages it has filled, so
the SAME memory budget serves several times the concurrency (a slot pins
ceil(len/page_size) pages, not max_len positions). Admission is gated by page
availability (`ServerStats.page_deferrals`) on top of the I/O gate; matched
prompt prefixes share pages copy-on-write (`prefix_hits`/`cow_copies`);
retirement on EVERY path — length/stop/timeout/error/rejected/preempted/abort
— releases the request's pages deterministically; and under page pressure
(`page_overcommit=True`) the decode-growth hook preempts the lowest-priority
active request (`finish_reason="preempted"`, partial tokens preserved) rather
than deadlocking. Decoded logits are bitwise identical to the contiguous
layout — the paged attend gathers pages into the same [B, S, KV, hd] view and
runs the identical causal GQA math.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import IOScheduler
from repro.core.predictor import PredictorParams, predict_mask
from repro.obs import get_metrics, get_tracer
from repro.obs import request_timeline as _build_request_timeline
from repro.utils import logger
from repro.models import transformer
from repro.models.layers import apply_norm, embed_tokens, unembed
from repro.models.model import Model
from repro.serving.engine import (OffloadedFFNRuntime, Request, Result,
                                  request_key)
from repro.serving.paging import PagePool, cdiv


class RequestState(enum.Enum):
    """Lifecycle of a request inside the server."""
    QUEUED = "queued"        # submitted, waiting for a free decode slot
    PREFILL = "prefill"      # admitted; its prompt is being prefilled
    DECODE = "decode"        # occupying a slot, generating tokens
    FINISHED = "finished"    # retired; `result` is populated


@dataclasses.dataclass
class RequestHandle:
    """Live view of one submitted request.

    `tokens` grows as the server steps (the streaming surface — read it, or
    register `on_token`, or drive `server.stream(handle)`); `result` is set at
    retirement. Timing fields accumulate while the request is in flight:
    `decode_seconds`/`overlapped_seconds` add each decode iteration's wall
    (every active request shares the batched step, same convention as the
    one-shot path), `io_seconds` adds this request's attributed share of the
    engines' flash reads.
    """
    request: Request
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    # "length" | "stop" | "error" | "timeout" | "rejected" | "preempted"
    # once FINISHED
    finish_reason: Optional[str] = None
    result: Optional[Result] = None
    error: Optional[BaseException] = None    # set iff finish_reason=="error"
    slot: Optional[int] = None
    on_token: Optional[Callable[[int, int], None]] = None   # (uid, token)
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    io_seconds: float = 0.0
    overlapped_seconds: float = 0.0
    # lifecycle stamps on the server's MONOTONIC clock (`time.monotonic` by
    # default) — deadline math and the load harness's TTFT/ITL numbers
    # survive wall-clock adjustments. `token_times` stamps every emitted
    # token (bounded by max_new_tokens), so inter-token gaps are exact.
    queued_at: float = 0.0
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    # resolved SLOs: request-level value if set, else the server default
    ttft_slo: Optional[float] = None
    itl_slo: Optional[float] = None
    _key: Any = None                         # fold_in(base_key, uid)
    _order: int = 0                          # submission order

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def done(self) -> bool:
        return self.state is RequestState.FINISHED

    @property
    def ttft_deadline(self) -> Optional[float]:
        """Monotonic instant this request's first token is due, or None."""
        return None if self.ttft_slo is None else self.queued_at + self.ttft_slo


def _deadline_or_inf(handle: RequestHandle) -> float:
    """TTFT deadline for EDF ordering; no deadline sorts last (infinite
    slack)."""
    d = handle.ttft_deadline
    return math.inf if d is None else d


@dataclasses.dataclass
class ServerStats:
    """Aggregate counters over the server's lifetime (benchmark surface)."""
    n_slots: int = 0
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0       # wall of the batched decode iterations
    decode_steps: int = 0
    tokens_emitted: int = 0
    admitted: int = 0
    slot_steps_active: int = 0        # Σ over decode steps of active slots
    # -- overload-robustness counters ----------------------------------------
    retired: int = 0                  # every retirement, any finish_reason
    rejected: int = 0                 # newcomers bounced off a full queue
    shed: int = 0                     # queued requests evicted for higher prio
    timeouts: int = 0                 # TTFT or inter-token deadline blown
    io_deferrals: int = 0             # admissions deferred by the I/O gate
    results_released: int = 0         # finished handles auto-released past
    #                                   the finished_high_water mark
    peak_queue_depth: int = 0         # max QUEUED depth ever observed
    # -- paged-KV counters (mirrors of PagePoolStats; zero unless paged) ------
    pages_allocated: int = 0          # page allocations over the run
    pages_shared: int = 0             # pages mapped shared at admission
    prefix_hits: int = 0              # admissions that matched a shared prefix
    cow_copies: int = 0               # copy-on-write page copies
    peak_page_occupancy: int = 0      # max pages simultaneously referenced
    prefix_evictions: int = 0         # registry entries evicted under pressure
    page_deferrals: int = 0           # admissions deferred by the page gate
    preemptions: int = 0              # active requests retired for pages

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        denom = self.decode_steps * max(self.n_slots, 1)
        return self.slot_steps_active / denom if denom else 0.0


class ServerStalledError(RuntimeError):
    """`step()` made no progress — nothing admitted, emitted, or retired —
    for `stall_limit` consecutive iterations while work was pending. Raised
    instead of letting `drain()` spin forever; the message carries a queue /
    slot snapshot so the hang is diagnosable from the exception alone."""


class InferenceServer:
    """Slot-based continuous-batching front-end over one model.

    Same mode surface as `ServingEngine` (resident | offload, optional
    prefetch pipeline + lookahead source), but requests are individually
    admitted, decoded at per-slot positions, and individually retired.
    `ServingEngine.serve()` is the submit-all + drain compatibility wrapper
    over this class.

    Typical use::

        server = InferenceServer(model, params, max_slots=4, max_len=256)
        h = server.submit(Request(uid=0, prompt=prompt, max_new_tokens=32))
        for tok in server.stream(h):      # pumps server.step() as needed
            ...
        server.close()

    or batch-style: submit many, then `drain()`.
    """

    def __init__(self, model: Model, params: Any, *, max_slots: int = 4,
                 max_len: int = 512, swa: bool = False, mode: str = "resident",
                 offload: Optional[OffloadedFFNRuntime] = None,
                 scheduler: Optional[IOScheduler] = None,
                 oracle: bool = True, prefetch: bool = False,
                 lookahead: Union[str, List[PredictorParams], None] = None,
                 seed: int = 0, decode_fn=None, prefill_fn=None,
                 pack_path: Optional[str] = None,
                 queue_limit: Optional[int] = None,
                 ttft_slo_s: Optional[float] = None,
                 itl_slo_s: Optional[float] = None,
                 io_admission: bool = True, io_headroom: float = 1.0,
                 stall_limit: int = 256,
                 finished_high_water: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 page_overcommit: bool = False):
        """`decode_fn` / `prefill_fn` let a long-lived caller (ServingEngine)
        share one jitted resident decode / admission prefill across servers;
        by default the server jits its own (prefill compiles once per prompt
        length — eager prefill cost hundreds of ms per admission at small
        geometries, which stalled co-batched requests' inter-token gaps).
        `lookahead` follows ServingEngine: predictor params, None (use
        the runtime's trained lookahead), or "oracle" (zero speculation
        depth — the exactness fallback). `pack_path` loads the offload
        runtime from an on-disk NeuronPack artifact
        (`OffloadedFFNRuntime.from_pack`, geometry-validated against the
        model config) instead of a caller-built runtime.

        Overload knobs: `queue_limit` bounds the admission queue (None =
        unbounded, the legacy behavior); `ttft_slo_s` / `itl_slo_s` are
        server-wide deadline defaults a request's own SLO fields override;
        `io_admission` arms the flash-I/O-aware admission gate (offload mode,
        inert unless some in-flight request has an inter-token SLO) with
        `io_headroom` scaling the budget (predicted step seconds must stay
        under headroom x the tightest active ITL deadline); `stall_limit`
        no-progress iterations raise `ServerStalledError`;
        `finished_high_water` bounds retained finished handles (oldest
        auto-released past the mark); `clock` injects a monotonic clock for
        deterministic deadline tests (default `time.monotonic`).

        Paged KV: set BOTH `page_size` and `num_pages` to replace the
        per-slot contiguous caches with a shared page arena
        (`serving/paging.py`) — decoder-only attention stacks, no `swa`.
        `page_overcommit=False` (strict) admits only requests whose
        worst-case page need is covered, so decode growth never runs dry;
        True gates on the immediate prompt need only, trading possible
        page-pressure preemption for higher admitted concurrency."""
        if mode not in ("resident", "offload"):
            raise ValueError(f"unknown serving mode {mode!r}")
        cfg = model.cfg
        if cfg.is_encdec:
            raise ValueError("InferenceServer covers decoder-only stacks")
        if pack_path is not None:
            if offload is not None:
                raise ValueError("pass either `offload` or `pack_path`, "
                                 "not both")
            if mode != "offload":
                raise ValueError("pack_path= requires mode='offload'")
            offload = OffloadedFFNRuntime.from_pack(cfg, pack_path)
        if mode == "offload":
            if offload is None:
                raise ValueError("mode='offload' needs an OffloadedFFNRuntime")
            if cfg.family != "dense":
                raise ValueError("offload serving covers dense decoder-only archs")
        if isinstance(lookahead, str) and lookahead != "oracle":
            raise ValueError(f"unknown lookahead mode {lookahead!r}")
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if (page_size is None) != (num_pages is None):
            raise ValueError("pass both page_size and num_pages, or neither")
        if page_size is not None and swa:
            raise ValueError("paged KV cache does not combine with swa "
                             "(sliding-window rings are per-slot, not paged)")
        if queue_limit is not None and queue_limit < 1:
            raise ValueError("queue_limit must be >= 1 (or None = unbounded)")
        if stall_limit < 1:
            raise ValueError("stall_limit must be >= 1")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.swa = swa
        self.mode = mode
        self.offload = offload
        self._owns_offload = pack_path is not None   # we built it: we close it
        self.oracle = oracle
        self.prefetch = prefetch
        self.lookahead = lookahead
        self.scheduler = scheduler or IOScheduler(overlap=True)
        self.stats = ServerStats(n_slots=max_slots)
        self.queue_limit = queue_limit
        self.default_ttft_slo = ttft_slo_s
        self.default_itl_slo = itl_slo_s
        self.io_admission = io_admission
        self.io_headroom = io_headroom
        self.stall_limit = stall_limit
        self.finished_high_water = finished_high_water
        self._clock = clock or time.monotonic
        self._stall_steps = 0
        self._base_key = jax.random.PRNGKey(seed)
        # jitted admission prefill (both modes; one compile per prompt length)
        self._prefill_fn = prefill_fn or jax.jit(
            lambda p, toks, c: model.prefill(p, {"tokens": toks}, c))
        self._queue: List[RequestHandle] = []
        self._handles: Dict[int, RequestHandle] = {}   # queued + in-flight
        self._finished: List[RequestHandle] = []
        self._n_submitted = 0
        # I/O-aware admission state (offload): last step's per-layer true
        # masks + an EMA of per-column activation frequency, the candidate
        # estimate for a not-yet-admitted request
        self._last_masks: List[Optional[np.ndarray]] = (
            [None] * offload.n_layers if mode == "offload" else [])
        self._col_freq: List[Optional[np.ndarray]] = list(self._last_masks)
        # slot pool: per-slot handle / next-decode position / last token
        self._slot_handle: List[Optional[RequestHandle]] = [None] * max_slots
        self._slot_pos = np.zeros(max_slots, dtype=np.int32)
        self._cur = np.zeros(max_slots, dtype=np.int32)
        # paged KV: the pool owns ALL KV memory; per-uid page tables map each
        # request onto exactly the pages it has filled
        self._pool: Optional[PagePool] = None
        self._tables: Dict[int, Any] = {}
        if page_size is not None:
            # PagePool/init_paged_stack_cache validate page geometry and
            # reject non-attention (SSM) sublayers with a ValueError — paged
            # serving never silently falls back
            self._pool = PagePool(
                cfg, num_pages=num_pages, page_size=page_size,
                max_len=max_len, overcommit=page_overcommit,
                layout="stacked" if mode == "resident" else "groups")
        if mode == "resident":
            if self._pool is not None:
                self._cache = None        # the arena replaces per-slot caches
                self._decode_fn = decode_fn or jax.jit(
                    lambda p, t, pos, c, pt: model.decode_step(
                        p, t, pos, c, page_tables=pt))
            else:
                self._cache = model.init_cache(max_slots, max_len, swa=swa)
                self._decode_fn = decode_fn or jax.jit(
                    lambda p, t, pos, c: model.decode_step(p, t, pos, c))
        else:
            self._cache_groups = (
                None if self._pool is not None else transformer.unstack_groups(
                    model.init_cache(max_slots, max_len, swa=swa), cfg))
            self._param_groups = transformer.unstack_groups(
                params["stack"], cfg)
            self._w_ups = _oracle_w_ups(model, params) if oracle else None
            if self._w_ups is not None and len(self._w_ups) != offload.n_layers:
                raise ValueError(
                    f"runtime has {offload.n_layers} layer engines, model has "
                    f"{len(self._w_ups)} dense FFN layers")
            # lookahead source resolution, identical to ServingEngine: params
            # > runtime-trained > "oracle" (depth 0)
            la = lookahead if not isinstance(lookahead, str) else None
            if la is None and lookahead is None:
                la = offload.lookahead
            if la is not None and la is not offload.lookahead:
                offload.lookahead = la
                offload._lookahead_np = None
            self._la_params = la
            self.scheduler.register_metrics()
            if prefetch and la is not None and \
                    cfg.activation not in ("relu", "relu2"):
                # speculative lookahead OVER-predicts by design; both FFN
                # paths (bundles and the fused segment kernel) evaluate the
                # whole SERVED union — speculated neurons included — which is
                # only exact when act(pre <= 0) == 0. Oracle lookahead
                # (la=None, zero speculation depth) stays exact for any
                # activation, on either kernel: the segment path masks
                # covered-but-not-served neurons in-kernel.
                raise ValueError(
                    f"prefetch with speculative lookahead is exact only for "
                    f"relu/relu2 activations, not {cfg.activation!r}; use "
                    f"lookahead='oracle' or serve serially")
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Expose live server state through the global `MetricsRegistry` —
        gauge callables reading `ServerStats` and the queue/slot pool, so the
        registry and the legacy stats surface agree by construction. The
        registry keeps only the most recently constructed server per name
        (re-registration re-points the gauge)."""
        reg = get_metrics()
        reg.register_gauge("server.queue_depth", lambda: len(self._queue))
        reg.register_gauge("server.n_active", lambda: self.n_active)
        for field in ("tokens_emitted", "decode_steps", "admitted", "retired",
                      "rejected", "shed", "timeouts", "io_deferrals",
                      "page_deferrals", "preemptions", "prefill_seconds",
                      "decode_seconds"):
            reg.register_gauge(f"server.{field}",
                               lambda f=field: getattr(self.stats, f))
        reg.register_gauge("server.occupancy", lambda: self.stats.occupancy)
        self._step_hist = reg.histogram("server.step_seconds")

    def request_timeline(self, handle: RequestHandle) -> Dict[str, Any]:
        """Per-request timeline for SLO debugging: phase breakdown
        (queued/prefill/decode) from the handle's monotonic lifecycle stamps,
        per-token inter-token gaps, resolved SLOs and whether each was met,
        plus — when tracing is enabled — the trace spans tagged with this
        request's uid (`repro.obs.request_timeline`)."""
        return _build_request_timeline(handle)

    # -- submission ----------------------------------------------------------
    def submit(self, request: Request,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> RequestHandle:
        """Queue a request; valid any time, including mid-decode.

        Raises ValueError if the request cannot fit its slot: the prompt plus
        `max_new_tokens` must fit in `max_len` KV-cache positions (prompt
        tokens occupy [0, T); generated token i is decoded at position T+i-1,
        so the last decode writes position T + max_new_tokens - 2 < max_len).

        Backpressure: with `queue_limit` set and the queue full, either the
        worst STRICTLY-lower-priority queued request is shed in favor of this
        one (`stats.shed`), or — no such victim — this request is retired
        immediately with `finish_reason="rejected"` (`stats.rejected`). The
        returned handle is FINISHED in that case (`handle.done`, empty
        tokens, `result` populated); callers that must not drop work should
        check `handle.finish_reason` and re-submit later.
        """
        T = len(request.prompt)
        if T < 1:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens must be >= 1")
        if T + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({T} tokens) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds the server's max_len "
                f"({self.max_len}); shorten the request or raise max_len")
        if self._pool is not None:
            need = cdiv(T + request.max_new_tokens, self._pool.page_size)
            if need > self._pool.num_pages:
                raise ValueError(
                    f"request {request.uid}: prompt + max_new_tokens needs "
                    f"{need} pages of {self._pool.page_size}, but the pool "
                    f"has only {self._pool.num_pages}; shorten the request "
                    f"or grow the pool")
        if request.uid in self._handles:
            raise ValueError(f"duplicate request uid {request.uid}")
        handle = RequestHandle(request=request, on_token=on_token,
                               queued_at=self._clock(),
                               ttft_slo=(request.ttft_slo_s
                                         if request.ttft_slo_s is not None
                                         else self.default_ttft_slo),
                               itl_slo=(request.itl_slo_s
                                        if request.itl_slo_s is not None
                                        else self.default_itl_slo),
                               _key=request_key(self._base_key, request.uid),
                               _order=self._n_submitted)
        self._n_submitted += 1
        self._handles[request.uid] = handle
        if (self.queue_limit is not None
                and len(self._queue) >= self.queue_limit):
            victim = self._shed_victim(request.priority)
            if victim is None:
                logger.warning("queue full (%d): rejecting request %d "
                               "(priority %d)", len(self._queue),
                               request.uid, request.priority)
                get_tracer().instant("reject", uid=request.uid,
                                     priority=request.priority)
                self.stats.rejected += 1
                self._retire(handle, "rejected")
                return handle
            logger.warning("queue full (%d): shedding queued request %d "
                           "(priority %d) for request %d (priority %d)",
                           len(self._queue), victim.uid,
                           victim.request.priority, request.uid,
                           request.priority)
            get_tracer().instant("shed", uid=victim.uid,
                                 for_uid=request.uid)
            self._queue.remove(victim)
            self.stats.shed += 1
            self._retire(victim, "rejected")
        self._queue.append(handle)
        self.stats.peak_queue_depth = max(self.stats.peak_queue_depth,
                                          len(self._queue))
        return handle

    def _shed_victim(self, priority: int) -> Optional[RequestHandle]:
        """The queued request to shed for a priority-`priority` arrival: the
        lowest STRICTLY-lower priority class; within it, the latest TTFT
        deadline (most slack; no deadline = infinite slack), newest
        submission as the tie-break. None when nothing queued is strictly
        lower priority — the arrival is rejected instead."""
        cands = [h for h in self._queue if h.request.priority < priority]
        if not cands:
            return None
        return min(cands, key=lambda h: (h.request.priority,
                                         -_deadline_or_inf(h), -h._order))

    # -- introspection -------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(h is not None for h in self._slot_handle)

    @property
    def n_active(self) -> int:
        return sum(h is not None for h in self._slot_handle)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def results(self) -> List[Result]:
        """Finished results the server still holds, in submission order."""
        return [h.result for h in sorted(self._finished,
                                         key=lambda h: h._order)]

    def release_finished(self) -> int:
        """Drop the server's references to finished requests (their handles
        stay valid for the caller). A long-lived server should call this
        periodically — or after consuming `drain()`/`results()` — so memory
        stays bounded by in-flight work, not by total requests served.
        Returns the number of handles released."""
        n = len(self._finished)
        self._finished.clear()
        return n

    # -- the serving loop ----------------------------------------------------
    def step(self) -> int:
        """Advance the server one iteration: admit queued requests into free
        slots (per-request prefill), then run one batched decode iteration
        over the active slots. Returns the number of tokens emitted.

        Error isolation, batch scope: an exception out of the shared decode
        computation (a flash read that exhausted its retries, a failing
        store) cannot be attributed to one request, so every active request
        is retired with `finish_reason="error"` and the exception attached
        — but the SERVER survives: queued and future submissions admit and
        decode normally. Per-request failures (sampling, a raising
        `on_token` callback, a failing prefill) are caught deeper down and
        retire only the offending request.

        SLO enforcement happens here, on the monotonic clock: blown
        inter-token deadlines retire active requests (slot freed before
        admission, so the slot is immediately reusable), blown TTFT
        deadlines retire queued requests before they waste a prefill, and
        admission itself runs in priority + earliest-deadline-first order,
        gated (offload mode) by the predicted flash cost of the grown batch.
        A `stall_limit` run of no-progress iterations with work pending
        raises `ServerStalledError`."""
        retired0, admitted0 = self.stats.retired, self.stats.admitted
        with get_tracer().span("step", queued=len(self._queue),
                               active=self.n_active):
            return self._step_inner(retired0, admitted0)

    def _step_inner(self, retired0: int, admitted0: int) -> int:
        emitted = 0
        now = self._clock()
        self._expire_active(now)
        self._expire_queued(now)
        while self._queue and None in self._slot_handle:
            cand = self._next_admission()
            if cand is None:               # an admission gate said "not yet"
                break
            got = self._admit(cand)
            if got is None:                # pool dry mid-admission: requeued
                break
            emitted += got
        if self._pool is not None:
            # make every active row's next position writable BEFORE the
            # batched decode: page-boundary growth, CoW at divergence points,
            # and — pool dry even after prefix eviction — preemption
            self._grow_page_tables()
        if any(h is not None for h in self._slot_handle):
            try:
                emitted += self._decode_iteration()
            except Exception as e:  # noqa: BLE001 — isolate, don't crash
                logger.warning("decode iteration failed (%r); retiring the "
                               "active batch with finish_reason='error'", e)
                for h in list(self._slot_handle):
                    if h is not None:
                        self._fail_request(h, e)
        if self._pool is not None:
            self._sync_page_stats()
        progress = (emitted + (self.stats.retired - retired0)
                    + (self.stats.admitted - admitted0))
        if progress == 0 and self.has_work:
            self._stall_steps += 1
            if self._stall_steps >= self.stall_limit:
                states = [h.state.value if h is not None else "free"
                          for h in self._slot_handle]
                raise ServerStalledError(
                    f"server made no progress for {self._stall_steps} "
                    f"consecutive step() iterations: {len(self._queue)} "
                    f"queued, {self.n_active} active, slots={states}, "
                    f"io_deferrals={self.stats.io_deferrals}; a queued "
                    f"request that can never admit (or an admission gate "
                    f"that never opens) would spin drain() forever")
        else:
            self._stall_steps = 0
        return emitted

    # -- SLO enforcement ------------------------------------------------------
    def _expire_queued(self, now: float) -> None:
        """Retire queued requests whose TTFT deadline already passed — they
        could not possibly meet it, so don't waste a prefill on them."""
        expired = [h for h in self._queue
                   if h.ttft_deadline is not None and now > h.ttft_deadline]
        for h in expired:
            self._queue.remove(h)
            self.stats.timeouts += 1
            logger.warning("request %d blew its TTFT deadline by %.3fs while "
                           "queued; retiring with finish_reason='timeout'",
                           h.uid, now - h.ttft_deadline)
            self._retire(h, "timeout")

    def _expire_active(self, now: float) -> None:
        """Retire active requests whose inter-token deadline has already
        passed since their last emitted token (the between-steps complement
        of the in-step gap check in `_emit`). Partial tokens are preserved;
        the slot frees immediately for the admission pass that follows."""
        for h in list(self._slot_handle):
            if h is None or h.itl_slo is None or not h.token_times:
                continue
            gap = now - h.token_times[-1]
            if gap > h.itl_slo:
                self.stats.timeouts += 1
                logger.warning("request %d blew its inter-token deadline "
                               "(%.3fs > %.3fs SLO) with %d tokens; retiring "
                               "with finish_reason='timeout'", h.uid, gap,
                               h.itl_slo, len(h.tokens))
                self._retire(h, "timeout")

    def _next_admission(self) -> Optional[RequestHandle]:
        """Pop the queued request to admit next — highest priority class
        first, earliest TTFT deadline within a class, submission order as the
        tie-break — unless the flash-I/O admission gate predicts the grown
        batch would blow an in-flight inter-token deadline, in which case the
        request stays QUEUED and None is returned (counted in
        `stats.io_deferrals`)."""
        if not self._queue:
            return None
        best = min(self._queue,
                   key=lambda h: (-h.request.priority, _deadline_or_inf(h),
                                  h._order))
        if self._page_defers(best):
            get_tracer().instant("defer", uid=best.uid, gate="page")
            self.stats.page_deferrals += 1
            return None
        if self._io_defers(best):
            get_tracer().instant("defer", uid=best.uid, gate="io")
            self.stats.io_deferrals += 1
            return None
        self._queue.remove(best)
        return best

    def _page_defers(self, candidate: RequestHandle) -> bool:
        """Page-availability admission gate (paged KV only): True when the
        pool cannot cover the candidate — its worst-case lifetime page need
        in strict mode, its immediate prompt need under `page_overcommit` —
        out of free + registry-evictable pages net of the commitments already
        promised to active requests and of the registry pages the candidate
        itself would pin. Never defers an empty batch: `submit` bounded the
        request to the pool, and with nothing active every non-free page is
        either registry-evictable or a prefix the candidate shares, so even
        after pinning its shares the candidate always fits."""
        if self._pool is None:
            return False
        if not any(h is not None for h in self._slot_handle):
            return False
        r = candidate.request
        plan = self._pool.plan_admit(np.asarray(r.prompt, dtype=np.int32),
                                     r.max_new_tokens)
        return not self._pool.can_admit(plan)

    def _io_defers(self, candidate: RequestHandle) -> bool:
        """Flash-I/O-aware admission gate: True when the UFS model predicts
        the next decode step WITH `candidate` admitted would exceed the
        tightest inter-token SLO among the active batch (+ the candidate),
        scaled by `io_headroom`. Never defers an empty batch (the candidate
        cannot blow anyone's deadline, and deferring would deadlock)."""
        if not self.io_admission or self.mode != "offload":
            return False
        if not any(h is not None for h in self._slot_handle):
            return False
        slos = [h.itl_slo for h in self._slot_handle
                if h is not None and h.itl_slo is not None]
        if candidate.itl_slo is not None:
            slos.append(candidate.itl_slo)
        if not slos:
            return False
        predicted = self._predict_step_seconds()
        if predicted is None:
            return False
        return predicted > self.io_headroom * min(slos)

    def _predict_step_seconds(self) -> Optional[float]:
        """Predicted seconds of the next decode step for the grown batch:
        per-layer extent reads priced on the calibrated `UFSDevice`
        (`OffloadEngine.predict_read_seconds` — cache peeked, thresholds
        read, nothing mutated) over the union of the active rows' last true
        masks plus a frequency-EMA estimate for the incoming request, plus
        the scheduler's recent compute share per token. None until a first
        decode step has recorded masks (cold server: admit freely)."""
        active = self._active_mask()
        unions: List[np.ndarray] = []
        for layer, masks in enumerate(self._last_masks):
            if masks is None:
                return None
            union = (masks & active[:, None]).any(axis=0)
            freq = self._col_freq[layer]
            if freq is not None:      # candidate estimate: typical-row mask
                union = union | (freq >= 0.5)
            unions.append(np.flatnonzero(union))
        io_s = self.offload.predict_step_io_seconds(unions)
        return io_s + self.scheduler.predicted_compute_seconds_per_token()

    def drain(self) -> List[Result]:
        """Step until every submitted request is finished."""
        while self.has_work:
            self.step()
        return self.results()

    def stream(self, handle: RequestHandle) -> Iterator[int]:
        """Yield `handle`'s tokens as they are generated, pumping `step()`
        whenever the caller is ahead of the server. Other in-flight requests
        advance too — they share the batched decode iterations."""
        i = 0
        while True:
            while i < len(handle.tokens):
                yield handle.tokens[i]
                i += 1
            if handle.done:
                return
            self.step()

    def abort(self, reason: Union[str, BaseException] = "aborted") -> int:
        """Retire every queued and in-flight request with
        `finish_reason="error"` (partial tokens preserved on each Result) —
        the graceful-interrupt path `launch/serve.py` uses on
        KeyboardInterrupt. Returns the number of requests retired; the
        server stays usable for new submissions."""
        exc = (reason if isinstance(reason, BaseException)
               else RuntimeError(str(reason)))
        n = 0
        while self._queue:
            self._fail_request(self._queue.pop(0), exc)
            n += 1
        for h in list(self._slot_handle):
            if h is not None:
                self._fail_request(h, exc)
                n += 1
        return n

    def close(self) -> None:
        """Release background resources: the prefetch worker always; the
        offload runtime's stores too when this server built the runtime
        itself (pack_path=). The server stays usable for inspection;
        further steps would restart the worker."""
        if self.mode == "offload" and self.offload is not None:
            if self._owns_offload:
                self.offload.close()
            else:
                self.offload.stop_prefetch()

    def __enter__(self) -> "InferenceServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission / retirement ----------------------------------------------
    def _admit(self, handle: RequestHandle) -> Optional[int]:
        """Prefill one queued request into a free slot. Failure-isolated: an
        exception anywhere in admission (prefill, slot write, the first
        token's `on_token` callback) retires THIS request with
        `finish_reason="error"` and leaves the rest of the server intact.

        Returns the number of tokens emitted (0 or 1), or None when the page
        pool ran dry mid-admission: the request goes BACK to the queue
        (counted as a page deferral, nothing to unwind — the table is built
        before the prefill), and the caller stops admitting this step."""
        slot = self._slot_handle.index(None)
        r = handle.request
        table = prompt_np = None
        if self._pool is not None:
            prompt_np = np.asarray(r.prompt, dtype=np.int32)
            table, _ = self._pool.admit(prompt_np, r.max_new_tokens,
                                        uid=r.uid)
            if table is None:
                # the gate prices pinned shares, so this should not happen —
                # but a dry pool defers rather than killing the request (the
                # stall watchdog catches a gate that never opens)
                logger.warning("page pool dry while admitting request %d; "
                               "deferring it back to the queue", r.uid)
                self.stats.page_deferrals += 1
                self._queue.append(handle)
                return None
            self._tables[r.uid] = table
        handle.state = RequestState.PREFILL
        handle.slot = slot
        handle.admitted_at = self._clock()
        try:
            T = len(r.prompt)
            prompt = jnp.asarray(np.asarray(r.prompt, dtype=np.int32)[None])
            tr = get_tracer()
            t0u = tr.now()
            t0 = time.perf_counter()
            small = self.model.init_cache(1, self.max_len, swa=self.swa)
            logits, small = self._prefill_fn(self.params, prompt, small)
            row = np.asarray(logits[0, -1], dtype=np.float32)  # forces the sync
            handle.prefill_seconds = time.perf_counter() - t0
            t1u = tr.now()
            tr.complete("prefill", t0u, t1u, uid=r.uid, prompt_len=T,
                        slot=slot)
            # mirrored onto the request's own lane, so one Perfetto row shows
            # the request's whole life (prefill + every decode span)
            tr.complete("prefill", t0u, t1u, track=f"req {r.uid}", uid=r.uid)
            self.stats.prefill_seconds += handle.prefill_seconds
            self.stats.admitted += 1
            if self._pool is not None:
                # the table was registered in _tables before the prefill, so
                # any failure below releases the pages via the _retire path
                self._pool.write_prompt(table, small)
                self._pool.register_prefixes(prompt_np, table)
            else:
                self._write_slot(slot, small)
            self._slot_handle[slot] = handle
            self._slot_pos[slot] = T
            handle.state = RequestState.DECODE
            tok = self._sample_row(handle, row)
            self._cur[slot] = tok
            self._emit(handle, tok)
            # the first token comes out of the prefill forward pass; give it
            # its decode span too so "one decode span per emitted token"
            # holds exactly over a whole run
            t2u = tr.now()
            tr.complete("decode", t1u, t2u, track=f"req {r.uid}", uid=r.uid,
                        tok=tok, n_tokens=1, from_prefill=True)
        except Exception as e:  # noqa: BLE001 — per-request isolation
            self._fail_request(handle, e)
            return 0
        return 1

    def _write_slot(self, slot: int, small_cache: Any) -> None:
        """Copy a freshly prefilled B=1 cache into row `slot` of the pool.

        Stale KV beyond the new prompt is harmless: decode writes a position's
        KV before attending to it, and causal masking hides everything past
        the current position."""
        if self.mode == "resident":
            # stacked leaves are [G, B, ...]: batch is axis 1
            self._cache = jax.tree_util.tree_map(
                lambda big, s: big.at[:, slot].set(s[:, 0]),
                self._cache, small_cache)
        else:
            small_groups = transformer.unstack_groups(small_cache, self.cfg)
            self._cache_groups = [
                jax.tree_util.tree_map(lambda big, s: big.at[slot].set(s[0]),
                                       big_g, small_g)
                for big_g, small_g in zip(self._cache_groups, small_groups)]

    def _emit(self, handle: RequestHandle, tok: int) -> None:
        now = self._clock()
        handle.tokens.append(tok)
        handle.token_times.append(now)
        if handle.first_token_at is None:
            handle.first_token_at = now
        self.stats.tokens_emitted += 1
        if handle.on_token is not None:
            handle.on_token(handle.uid, tok)
        if tok in handle.request.stop_tokens:
            self._retire(handle, "stop")
        elif len(handle.tokens) >= handle.request.max_new_tokens:
            self._retire(handle, "length")
        elif (handle.itl_slo is not None and len(handle.token_times) >= 2
              and now - handle.token_times[-2] > handle.itl_slo):
            # the gap to the PREVIOUS token blew the inter-token deadline
            # (completion reasons above take precedence); the late token is
            # preserved — partial output, slot freed immediately
            self.stats.timeouts += 1
            logger.warning("request %d blew its inter-token deadline "
                           "(%.3fs > %.3fs SLO) at token %d; retiring with "
                           "finish_reason='timeout'", handle.uid,
                           now - handle.token_times[-2], handle.itl_slo,
                           len(handle.tokens))
            self._retire(handle, "timeout")

    def _retire(self, handle: RequestHandle, reason: str,
                error: Optional[BaseException] = None) -> None:
        get_tracer().instant("retire", uid=handle.uid, finish_reason=reason,
                             n_tokens=len(handle.tokens))
        handle.finish_reason = reason
        handle.error = error
        handle.state = RequestState.FINISHED
        handle.result = Result(
            uid=handle.uid, tokens=list(handle.tokens),
            prefill_seconds=handle.prefill_seconds,
            decode_seconds=handle.decode_seconds,
            io_seconds=handle.io_seconds,
            overlapped_seconds=handle.overlapped_seconds,
            finish_reason=reason, error=error)
        handle.finished_at = self._clock()
        if handle.slot is not None:                 # error-retired requests
            self._slot_handle[handle.slot] = None   # may never have held a
            handle.slot = None                      # slot; freed rows leave
        self._handles.pop(handle.uid, None)         # every future mask union
        if self._pool is not None:
            # deterministic page reclamation on EVERY retirement path —
            # length/stop/timeout/error/rejected/preempted/abort all land here
            table = self._tables.pop(handle.uid, None)
            if table is not None:
                self._pool.release(table)
        self._finished.append(handle)
        self.stats.retired += 1
        hw = self.finished_high_water
        if hw is not None and len(self._finished) > hw:
            # bounded memory: auto-release the oldest delivered results past
            # the high-water mark (caller-held handles stay valid; only the
            # server's own references are dropped)
            drop = len(self._finished) - hw
            del self._finished[:drop]
            self.stats.results_released += drop

    def _fail_request(self, handle: RequestHandle,
                      exc: BaseException) -> None:
        """Retire one request with `finish_reason="error"`: partial tokens
        stay on the Result, the exception is attached, the slot (if any) is
        freed, and everything else in the batch keeps decoding."""
        if handle.done:
            return
        logger.warning("request %d failed (%r); retiring with "
                       "finish_reason='error'", handle.uid, exc)
        self._retire(handle, "error", error=exc)

    # -- paged-KV growth / preemption -----------------------------------------
    def _grow_page_tables(self) -> None:
        """Pre-decode growth pass: every active row's next write position
        gets a resident, privately-owned page (boundary alloc / CoW). In
        strict admission mode the pool can never be dry here — admission
        reserved every request's worst case. Under `page_overcommit` a dry
        pool preempts: the registry is already drained by the allocator, so
        the lowest-priority active request (latest deadline, newest — the
        `_shed_victim` key) retires with `finish_reason="preempted"`, its
        partial tokens intact and its pages released, and the needer
        retries. The needer can be its own victim."""
        for slot in range(self.max_slots):
            while True:
                h = self._slot_handle[slot]
                if h is None:
                    break
                table = self._tables.get(h.uid)
                if table is None or \
                        self._pool.prepare_append(table,
                                                  int(self._slot_pos[slot])):
                    break
                victim = min(
                    (a for a in self._slot_handle if a is not None),
                    key=lambda a: (a.request.priority, -_deadline_or_inf(a),
                                   -a._order))
                self.stats.preemptions += 1
                get_tracer().instant("preempt", uid=victim.uid,
                                     for_uid=h.uid,
                                     priority=victim.request.priority)
                logger.warning(
                    "page pool dry growing request %d (pos %d): preempting "
                    "request %d (priority %d, %d tokens) with "
                    "finish_reason='preempted'", h.uid,
                    int(self._slot_pos[slot]), victim.uid,
                    victim.request.priority, len(victim.tokens))
                self._retire(victim, "preempted")

    def _page_tables_np(self) -> np.ndarray:
        """[max_slots, max_pages] physical-page array for the decode step;
        free slots (and every unallocated logical page) point at the null
        page, so their garbage writes cannot touch a live page."""
        pool = self._pool
        pt = np.full((self.max_slots, pool.max_pages_per_seq),
                     pool.null_page, dtype=np.int32)
        for slot, h in enumerate(self._slot_handle):
            if h is not None:
                table = self._tables.get(h.uid)
                if table is not None:
                    pool.page_table_row(table, pt[slot])
        return pt

    def _sync_page_stats(self) -> None:
        ps = self._pool.stats
        s = self.stats
        s.pages_allocated = ps.pages_allocated
        s.pages_shared = ps.pages_shared
        s.prefix_hits = ps.prefix_hits
        s.cow_copies = ps.cow_copies
        s.peak_page_occupancy = ps.peak_page_occupancy
        s.prefix_evictions = ps.prefix_evictions

    def page_summary(self) -> Optional[Dict[str, Any]]:
        """Pool configuration + lifetime counters (io_summary-style surface;
        None when the server is not paged)."""
        if self._pool is None:
            return None
        out = self._pool.summary()
        out["page_deferrals"] = self.stats.page_deferrals
        out["preemptions"] = self.stats.preemptions
        return out

    # -- sampling (per-request streams) ---------------------------------------
    def _sample_row(self, handle: RequestHandle, row: np.ndarray) -> int:
        """Sample token t = len(handle.tokens) of this request from its own
        stream. Row-wise, so the value is independent of batch composition."""
        temp = handle.request.temperature
        if temp <= 0:
            return int(np.argmax(row))
        key = jax.random.fold_in(handle._key, len(handle.tokens))
        return int(jax.random.categorical(
            key, jnp.asarray(row, jnp.float32) / temp))

    # -- decode ---------------------------------------------------------------
    def _active_mask(self) -> np.ndarray:
        return np.array([h is not None for h in self._slot_handle], dtype=bool)

    def _decode_iteration(self) -> int:
        active = self._active_mask()
        tr = get_tracer()
        t0u = tr.now()
        if self.mode == "resident":
            logits_rows, token_wall, req_io, over = self._decode_resident()
        else:
            logits_rows, token_wall, req_io, over = self._decode_offload(active)
        t1u = tr.now()
        tr.complete("decode_step", t0u, t1u, batch=int(active.sum()),
                    step=self.stats.decode_steps)
        self._step_hist.observe(token_wall)
        self.stats.decode_seconds += token_wall
        self.stats.decode_steps += 1
        self.stats.slot_steps_active += int(active.sum())
        # conservation: I/O the engine attributed to now-inactive rows (pure
        # over-speculation splits evenly over ALL rows) is re-billed evenly to
        # the active requests, so Σ per-request io == Σ engine merged reads
        orphan = float(req_io[~active].sum())
        share = orphan / max(int(active.sum()), 1)
        emitted = 0
        for slot in np.flatnonzero(active):
            handle = self._slot_handle[slot]
            handle.decode_seconds += token_wall
            handle.overlapped_seconds += over
            handle.io_seconds += float(req_io[slot]) + share
            # per-request isolation: sampling or a raising on_token callback
            # retires only THIS request; the loop continues for the rest of
            # the batch (the shared compute above already succeeded).
            try:
                tok = self._sample_row(handle, logits_rows[slot])
                self._slot_pos[slot] += 1
                self._cur[slot] = tok
                self._emit(handle, tok)             # may free the slot
                emitted += 1
                # one decode span per emitted token on the request's own
                # lane; the duration is the shared batched step's wall
                tr.complete("decode", t0u, t1u, track=f"req {handle.uid}",
                            uid=handle.uid, tok=tok,
                            n_tokens=len(handle.tokens))
            except Exception as e:  # noqa: BLE001
                self._fail_request(handle, e)
        return emitted

    def _decode_resident(self):
        t0 = time.perf_counter()
        if self._pool is not None:
            logits, self._pool.cache = self._decode_fn(
                self.params, jnp.asarray(self._cur)[:, None],
                jnp.asarray(self._slot_pos), self._pool.cache,
                jnp.asarray(self._page_tables_np()))
        else:
            logits, self._cache = self._decode_fn(
                self.params, jnp.asarray(self._cur)[:, None],
                jnp.asarray(self._slot_pos), self._cache)
        rows = np.asarray(logits[:, 0], dtype=np.float32)   # the per-token sync
        wall = time.perf_counter() - t0
        return rows, wall, np.zeros(self.max_slots), 0.0

    # -- offload decode: masks -> batched engine step -> sparse FFN ----------
    def _true_masks(self, dense_idx: int, h2: jnp.ndarray,
                    active: np.ndarray) -> np.ndarray:
        """[n_slots, n_neurons] activation masks for one layer: the exact ReLU
        oracle (or trained predictor), with retired/free rows zeroed so they
        leave the union — a finished request incurs no further I/O."""
        if self._w_ups is not None:
            masks = np.asarray(h2 @ self._w_ups[dense_idx] > 0)
        else:
            assert self.offload.predictors is not None, \
                "oracle=False needs runtime predictors"
            masks = np.asarray(predict_mask(self.offload.predictors[dense_idx], h2))
        masks = masks & active[:, None]
        # feed the admission predictor: this layer's last true masks, plus an
        # EMA of per-column activation frequency over the active rows (the
        # candidate-row estimate for a not-yet-admitted request)
        self._last_masks[dense_idx] = masks
        if self.io_admission and active.any():
            col = masks[active].mean(axis=0)
            prev = self._col_freq[dense_idx]
            self._col_freq[dense_idx] = (col if prev is None
                                         else 0.8 * prev + 0.2 * col)
        return masks

    def _decode_offload(self, active: np.ndarray):
        cfg = self.cfg
        runtime = self.offload
        n_slots = self.max_slots
        n_layers = runtime.n_layers
        req_io = np.zeros(n_slots)
        if self.prefetch and not runtime.prefetch_active:
            runtime.start_prefetch()        # one worker for the whole run
        la_params = self._la_params if self.prefetch else None

        # Sync-free serial path: XLA dispatch runs ahead across layers while
        # the engine serves each layer's masks host-side; one end-of-token
        # sync, apportioned across stages by FLOPs (see ServingEngine notes).
        def override(dense_idx: int, normed2: jnp.ndarray) -> jnp.ndarray:
            h2 = normed2[:, 0]
            masks = self._true_masks(dense_idx, h2, active)
            y, res = runtime.ffn_apply_batch(dense_idx, h2, masks)
            flops = (2.0 * n_slots * res.merged.n_activated
                     * runtime.n_mats * cfg.d_model)
            self.scheduler.record_stage(dense_idx,
                                        io_seconds=res.merged.io.seconds,
                                        flops=flops)
            np.add(req_io, res.req_io_seconds, out=req_io)
            return y[:, None]

        # Pipelined path: submit layer k+1's speculated prefetch, then
        # complete layer k against its true mask (top-up for mis-predictions).
        def override_prefetch(dense_idx: int, normed2: jnp.ndarray) -> jnp.ndarray:
            h2 = normed2[:, 0]
            masks_true = self._true_masks(dense_idx, h2, active)
            if dense_idx == 0 or la_params is None:
                runtime.begin_layer(dense_idx, masks_true)   # depth 0
            if la_params is not None and dense_idx + 1 < n_layers:
                spec = runtime.predict_lookahead(dense_idx, np.asarray(h2))
                spec = spec & active[:, None]
                runtime.begin_layer(dense_idx + 1, spec)
            y, res, meas = runtime.complete_layer(dense_idx, h2, masks_true)
            flops = (2.0 * n_slots * res.merged.n_activated
                     * runtime.n_mats * cfg.d_model)
            self.scheduler.record_stage(dense_idx,
                                        io_seconds=res.merged.io.seconds,
                                        flops=flops, measured=meas)
            np.add(req_io, res.req_io_seconds, out=req_io)
            return y[:, None]

        ffn_override = override_prefetch if self.prefetch else override
        t0 = time.perf_counter()
        x = embed_tokens(self.params["embed"],
                         jnp.asarray(self._cur)[:, None], cfg)
        self.scheduler.begin_token()
        paged = self._pool is not None
        cache_groups = self._pool.cache_groups if paged else self._cache_groups
        h, cache_groups = transformer.stack_decode_step_layerwise(
            self._param_groups, x, jnp.asarray(self._slot_pos),
            cache_groups, cfg, ffn_override=ffn_override,
            page_tables=(jnp.asarray(self._page_tables_np()) if paged
                         else None))
        if paged:
            self._pool.cache_groups = cache_groups
        else:
            self._cache_groups = cache_groups
        h = apply_norm(self.params["final_norm"], h, cfg)
        logits = unembed(self.params["embed"], h, cfg)
        rows = np.asarray(logits[:, 0], dtype=np.float32)   # ONE sync per token
        token_wall = time.perf_counter() - t0
        timing = self.scheduler.end_token(
            compute_seconds=token_wall,
            wall_seconds=token_wall if self.prefetch else None)
        over = (timing.measured_wall_seconds if self.prefetch
                else timing.overlapped_seconds)
        return rows, token_wall, req_io, over


def _oracle_w_ups(model: Model, params: Any) -> List[jnp.ndarray]:
    """Resident w_up handles per dense layer, in capture order — the exact
    ReLU support oracle the predictor approximates. The simulated flash still
    pays for every neuron the mask selects."""
    cfg = model.cfg
    P = transformer.stack_period(cfg)
    G = cfg.n_layers // P
    ffns = cfg.ffn_kinds()
    w_ups = []
    for g in range(G):
        for j in range(P):
            if ffns[j] == "dense":
                w_ups.append(params["stack"][f"sub_{j}"]["ffn"]["w_up"][g])
    return w_ups
