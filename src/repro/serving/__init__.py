"""Serving front-ends: slot-based continuous batching + the one-shot wrapper."""
from repro.serving.engine import (OffloadedFFNRuntime, PrefetchWorker, Request,
                                  Result, ServingEngine, build_offload_runtime,
                                  request_key, sample_token, sample_tokens)
from repro.serving.server import (InferenceServer, RequestHandle, RequestState,
                                  ServerStats)

__all__ = [
    "InferenceServer", "OffloadedFFNRuntime", "PrefetchWorker", "Request",
    "RequestHandle", "RequestState", "Result", "ServerStats", "ServingEngine",
    "build_offload_runtime", "request_key", "sample_token", "sample_tokens",
]
