"""Serving building blocks: requests/results, sampling streams, and the
flash-offloaded FFN runtime shared by both serving front-ends.

Front-ends (see `repro.serving.server` for the primary one):
  * `InferenceServer` (server.py) — slot-based continuous batching with an
    explicit request lifecycle, mid-flight admission, per-request retirement,
    and streaming. The serving runtime proper.
  * `ServingEngine` (here) — the historic one-shot `serve()` API, kept as a
    thin submit-all + drain wrapper over InferenceServer.

Two modes, both front-ends:
  * resident  — all weights in device memory; jit'd prefill/decode only.
  * offload   — the paper's §5 online stage, end-to-end: prefill runs dense
    (the paper offloads only the memory-dominant decode FFN), then every
    decode step drives, per dense-FFN layer and for the WHOLE decode batch,
        predict activated neurons (trained predictor or exact ReLU oracle)
        -> one batched engine step (merged cache probe + single collapsed
           extent read over the simulated UFS layout)
        -> sparse FFN computed from the bundle payloads actually read.

The offload mode EXECUTES the paper's I/O–compute overlap when built with
`prefetch=True`: a background I/O worker runs layer k+1's engine begin phase
(cache probe + collapsed read + staging gather into a double-buffered host
ring) while the device computes layer k's FFN, driven by a cross-layer
lookahead predictor (layer k's pre-FFN hidden -> layer k+1's mask). The
serving thread reconciles each prefetched layer against the true mask — any
mis-predicted neuron is served by a synchronous top-up read, so pipelined
decode is never less exact than serial. `IOScheduler` reports BOTH the
analytic double-buffered schedule (modeled UFS read times) and the MEASURED
overlap (worker busy time vs serving-thread wait time vs token wall clock);
in prefetch mode `Result.overlapped_seconds` carries the measured per-token
wall clock — what actually happened, not a model. Per-request I/O is
attributed by the engine and lands in `Result.io_seconds`.

The offload path intentionally runs layer-by-layer on host (it models a
phone-style single-device runtime); the distributed pjit path is the dense
one exercised by launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import (BatchStepResult, EngineConfig, OffloadEngine,
                               PendingStep)
from repro.core.pipeline import IOScheduler, StageMeasurement
from repro.core.placement import PlacementResult
from repro.core.predictor import (PredictorParams, predict_mask,
                                  train_lookahead_predictors)
from repro.core.sparse_ffn import sparse_ffn_from_bundles
from repro.core.storage import NeuronStore, UFSDevice
from repro.models import transformer
from repro.models.model import Model
from repro.obs import get_tracer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # generation stops the step any of these tokens is sampled (the stop token
    # IS included in the output); honored in resident and offload decode alike
    stop_tokens: tuple = ()
    # -- SLO surface (InferenceServer; ignored by the one-shot serve() path) --
    # admission priority class: higher admits first, and a full queue sheds
    # strictly-lower-priority queued work before rejecting a newcomer
    priority: int = 0
    # deadlines on the server's monotonic clock, None = server default/none:
    # TTFT (submit -> first token) and max inter-token gap; a blown deadline
    # retires the request with finish_reason="timeout", partial tokens kept
    ttft_slo_s: Optional[float] = None
    itl_slo_s: Optional[float] = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prefill_seconds: float
    decode_seconds: float
    io_seconds: float = 0.0            # this request's attributed flash I/O
    # Pipelined decode latency summed over the decode iterations this request
    # was active in. In prefetch mode this is MEASURED: the per-token wall
    # clock of the real overlap pipeline (worker I/O running under device
    # compute) — scheduler.summary()'s measured_* keys carry the
    # reconciliation against the analytic model. In serial offload mode it is
    # the modeled double-buffered schedule (stage compute from the measured
    # token wall apportioned by FLOPs, stage io from the UFS model).
    overlapped_seconds: float = 0.0
    # "length"  — max_new_tokens generated (the normal completion)
    # "stop"    — a stop token was sampled (included in the output)
    # "error"   — an exception retired this request (per-request isolation)
    # "timeout" — an SLO deadline (TTFT or inter-token) expired; partial
    #             tokens are preserved (InferenceServer only)
    # "rejected"— backpressure: the admission queue was full at submit time,
    #             or this queued request was shed for a higher-priority
    #             arrival; no tokens were generated (InferenceServer only)
    finish_reason: str = "length"
    # set iff finish_reason == "error": the exception that retired this
    # request (per-request isolation — co-batched requests keep decoding)
    error: Optional[BaseException] = None


def request_key(base_key, uid: int):
    """Per-request sampling stream root: `fold_in(serve seed, uid)`.

    Token t of request `uid` is sampled from `fold_in(request_key(...), t)`,
    so a request's sampled tokens depend only on (seed, uid, t) and its own
    logits — NOT on which batch, group, or decode slot the request landed in
    (grouping-invariant sampling)."""
    return jax.random.fold_in(base_key, uid)


def sample_tokens(logits: jnp.ndarray, temperatures, key) -> jnp.ndarray:
    """Per-row sampling: row i is greedy if temperatures[i] <= 0, else
    categorical at its own temperature — one vectorized call for the whole
    decode batch, so mixed-temperature groups need no per-request loop."""
    hot = np.asarray(temperatures) > 0
    greedy_all = not bool(hot.any())
    greedy = jnp.argmax(logits, axis=-1)
    if greedy_all:                  # common all-greedy case: skip sampling
        return greedy
    temps = jnp.asarray(temperatures, dtype=logits.dtype)
    safe = jnp.where(jnp.asarray(hot), temps, jnp.ones_like(temps))
    sampled = jax.random.categorical(key, logits / safe[:, None], axis=-1)
    return jnp.where(jnp.asarray(hot), sampled, greedy)


def sample_token(logits: jnp.ndarray, temperature: float, key) -> jnp.ndarray:
    """Single shared temperature for every row (legacy helper)."""
    return sample_tokens(logits, np.full((logits.shape[0],), temperature,
                                         dtype=np.float32), key)


# ---------------------------------------------------------------------------
# Offloaded FFN runtime: per-layer engines + batched apply + prefetch pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PrefetchedLayer:
    """One layer's staged prefetch, produced by the I/O worker: the engine's
    pending split-phase step plus where its payload sits in the staging ring."""
    layer: int
    pending: PendingStep
    k_spec: int                  # staged rows [0, k_spec) = speculated union
    io_host_seconds: float = 0.0  # measured worker wall time for this layer


class PrefetchWorker:
    """Background I/O thread for layer-ahead prefetch.

    The serving thread submits (layer, speculated masks) jobs; the worker
    runs the engine's begin phase (cache probe + read planning + collapsed
    read accounting) and gathers the speculated union's payload into the
    runtime's double-buffered staging ring, then posts the result. Jobs and
    results ride bounded queues (depth 2 = one job in flight + one queued),
    so a stalled consumer can never accumulate unbounded staged state.
    `Exception`s are caught per job on the worker and re-raised on the
    serving thread at `wait()` — the worker survives a failed job, so one
    bad read never costs the pipeline its thread. Non-`Exception` errors
    (`FatalFault`, MemoryError-class havoc) kill the thread; the runtime's
    supervision in `complete_layer` detects the death, restarts the worker
    within its budget, and serves the affected layers through the
    synchronous fallback.
    """

    _SENTINEL = object()

    def __init__(self, runtime: "OffloadedFFNRuntime") -> None:
        self._runtime = runtime
        self._jobs: "queue.Queue" = queue.Queue(maxsize=2)
        self._results: "queue.Queue" = queue.Queue(maxsize=2)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="ripple-prefetch")
        self._thread.start()

    def submit(self, layer: int, masks: np.ndarray) -> None:
        self._jobs.put((layer, masks))

    def wait(self, layer: int) -> PrefetchedLayer:
        """Block until `layer`'s prefetch lands; re-raises worker exceptions.
        Raises RuntimeError promptly (sub-100ms poll) if the worker thread
        died — the supervision hook in `complete_layer` turns that into a
        restart + synchronous fallback instead of a crashed batch."""
        while True:
            try:
                kind, lay, payload = self._results.get(timeout=0.1)
                break
            except queue.Empty:
                if not self._thread.is_alive():
                    raise RuntimeError("prefetch worker died unexpectedly")
        if kind == "exc":
            raise payload
        if lay != layer:
            raise RuntimeError(f"prefetch out of order: wanted layer {layer}, "
                               f"got {lay}")
        return payload

    def _loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is self._SENTINEL:
                return
            layer, masks = job
            try:
                # span lands on the worker's own thread track, so the exported
                # trace shows layer k+1's read overlapping layer k's compute
                with get_tracer().span("prefetch", layer=layer) as sp:
                    t0 = time.perf_counter()
                    staged = self._runtime._stage_layer(layer, masks)
                    staged.io_host_seconds = time.perf_counter() - t0
                    sp.set(n_staged=staged.k_spec)
                self._results.put(("ok", layer, staged))
            except Exception as e:  # noqa: BLE001 — re-raised at wait();
                # BaseException (FatalFault & co.) deliberately falls
                # through and kills the thread: that is the worker-death
                # path supervision exists for.
                self._results.put(("exc", layer, e))

    def shutdown(self) -> None:
        # A dead worker may leave the bounded job queue full; put with a
        # short timeout and re-check aliveness so shutdown never deadlocks
        # behind a queue nobody is draining. While waiting for the join,
        # keep draining stale results: a worker whose staged results were
        # abandoned (supervision fallback) may be blocked on the bounded
        # result queue and needs a consumer to reach the sentinel.
        deadline = time.monotonic() + 30.0
        sent = False
        while self._thread.is_alive() and time.monotonic() < deadline:
            if not sent:
                try:
                    self._jobs.put_nowait(self._SENTINEL)
                    sent = True
                except queue.Full:
                    pass
            try:
                self._results.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()


def _resolve_ffn_kernel(requested: str, placements: List[PlacementResult],
                        bundle_width: int, expected_width: int) -> tuple:
    """Resolve EngineConfig.ffn_kernel to a concrete path + human reason.

    "auto" promotes the fused segment kernel exactly when the layout can
    profit from it: every layer's placement is physical-placement-ordered
    (mode != "identity" — an identity layout carries no co-activation links,
    so segment blocks would cover mostly-inactive neurons) AND the stored
    bundle width maps onto [n_mats * d_model] weight rows (accounting-only
    stores with synthetic widths cannot be reshaped into FFN matrices).
    The segment path is exact for all supported activations: covered-but-
    not-activated neurons are masked in-kernel by the fused scale tiles.
    """
    if requested == "bundles":
        return "bundles", "explicitly requested"
    if requested == "segments":
        if bundle_width != expected_width:
            raise ValueError(
                f"ffn_kernel='segments' needs bundle_width == n_mats*d_model "
                f"({expected_width}), store has {bundle_width}")
        return "segments", "explicitly requested"
    if requested != "auto":
        raise ValueError(f"unknown ffn_kernel {requested!r}")
    if bundle_width != expected_width:
        return "bundles", (f"bundle_width {bundle_width} != n_mats*d_model "
                           f"{expected_width}: payload is not segment-mappable")
    modes = sorted({p.mode for p in placements})
    if not modes or "identity" in modes:
        return "bundles", ("identity layout: physical order carries no "
                           "co-activation links to exploit")
    return "segments", (f"physical-placement-ordered layout "
                        f"(modes: {', '.join(modes)})")


class OffloadedFFNRuntime:
    """Per-layer RIPPLE offload state: engines, predictors, placements,
    lookahead predictors, and the prefetch staging ring."""

    def __init__(
        self,
        cfg: ModelConfig,
        bundles_per_layer: Optional[List[np.ndarray]] = None,  # [L][n, width]
        placements: Optional[List[PlacementResult]] = None,
        predictors: Optional[List[PredictorParams]] = None,
        device: Optional[UFSDevice] = None,
        engine_cfg: Optional[EngineConfig] = None,
        lookahead: Optional[List[PredictorParams]] = None,
        lookahead_threshold: float = 0.35,
        bundle_bytes: Optional[int] = None,
        *,
        stores: Optional[List[NeuronStore]] = None,
        max_worker_restarts: int = 2,
    ) -> None:
        """Either raw `bundles_per_layer` + `placements` (in-memory stores are
        built per layer) or prebuilt `stores` — e.g. `FileNeuronStore`s over a
        NeuronPack, the `from_pack` path."""
        self.cfg = cfg
        self.engine_cfg = engine_cfg or EngineConfig()
        if stores is not None:
            if bundles_per_layer is not None or placements is not None:
                raise ValueError("pass either prebuilt `stores` or raw "
                                 "bundles_per_layer/placements, not both")
            self.engines = [OffloadEngine.from_store(s, config=engine_cfg)
                            for s in stores]
        else:
            if bundles_per_layer is None or placements is None:
                raise ValueError("OffloadedFFNRuntime needs bundles_per_layer"
                                 " + placements, or `stores`")
            self.engines = [
                OffloadEngine(b, placement=pl, device=device,
                              config=engine_cfg, bundle_bytes=bundle_bytes)
                for b, pl in zip(bundles_per_layer, placements)
            ]
        self.predictors = predictors
        # cross-layer lookahead: lookahead[k] predicts layer k+1's mask from
        # layer k's pre-FFN hidden state (the prefetch pipeline's driver)
        self.lookahead = lookahead
        self.lookahead_threshold = lookahead_threshold
        self.n_mats = 3 if cfg.activation == "silu" else 2
        self.ffn_kernel, self.ffn_kernel_reason = _resolve_ffn_kernel(
            self.engine_cfg.ffn_kernel,
            [e.placement for e in self.engines],
            self.engines[0].store.bundle_width if self.engines else 0,
            self.n_mats * cfg.d_model)
        # staging ring: 2 pad-bucketed host buffers per (width, dtype), the
        # worker filling one slot while the serving thread consumes the other
        self._staging: Dict[tuple, np.ndarray] = {}
        self._worker: Optional[PrefetchWorker] = None
        self._segment_weights: Dict[int, tuple] = {}
        self._lookahead_np: Optional[List[tuple]] = None
        self.topup_total = 0       # neurons served by synchronous top-up reads
        # prefetch supervision: on worker death, restart up to
        # `max_worker_restarts` times per prefetch session, then disable the
        # worker and serve every remaining layer through the synchronous
        # fallback. `worker_restarts`/`degraded_steps` are the reporting
        # counters (io_summary); `_inflight` tracks which layers have a
        # submitted-but-not-completed prefetch so completion knows whether a
        # staged result exists to wait for.
        self.max_worker_restarts = max_worker_restarts
        self.worker_restarts = 0
        self.degraded_steps = 0
        self._worker_disabled = False
        self._restarts_used = 0
        self._inflight: set = set()

    @classmethod
    def from_pack(
        cls,
        cfg: ModelConfig,
        pack,                               # path | NeuronPack
        device: Optional[UFSDevice] = None,
        engine_cfg: Optional[EngineConfig] = None,
        predictors: Optional[List[PredictorParams]] = None,
        lookahead: Optional[List[PredictorParams]] = None,
        lookahead_threshold: float = 0.35,
        verify_checksums: bool = False,
        retry=None,
        fault_plans=None,
        max_worker_restarts: int = 2,
    ) -> "OffloadedFFNRuntime":
        """Serve straight from an on-disk NeuronPack artifact: one
        `FileNeuronStore` per layer, placements read from the pack, every
        collapsed extent a REAL positional file read. Raises ValueError when
        the pack's geometry does not match the model config (layer count,
        neuron count, bundle width).

        `verify_checksums=True` has every store check each extent read
        against the pack's per-bundle CRC32 table (v2 packs only; detected
        corruption triggers a re-read). `retry` overrides the stores'
        transient-failure `RetryPolicy`; `fault_plans` (one
        `repro.store.faults.FaultPlan` per layer, None entries allowed)
        arms deterministic fault injection below the retry layer — the
        chaos-test hook."""
        from repro.store.file_store import FileNeuronStore
        from repro.store.format import NeuronPack

        pack = NeuronPack.open(pack)
        validate_pack_for_model(pack, cfg)
        ecfg = engine_cfg or EngineConfig()
        if fault_plans is not None and len(fault_plans) != pack.n_layers:
            raise ValueError(f"fault_plans covers {len(fault_plans)} layers, "
                             f"pack has {pack.n_layers}")
        stores = [FileNeuronStore(
                      pack, l, device=device,
                      reads_per_bundle=ecfg.reads_per_bundle,
                      retry=retry, verify_checksums=verify_checksums,
                      fault_plan=fault_plans[l] if fault_plans else None)
                  for l in range(pack.n_layers)]
        return cls(cfg, stores=stores, predictors=predictors,
                   engine_cfg=engine_cfg, lookahead=lookahead,
                   lookahead_threshold=lookahead_threshold,
                   max_worker_restarts=max_worker_restarts)

    # -- single merged activated set (legacy accounting interface) ----------
    def ffn_apply(self, layer: int, h: np.ndarray, oracle_mask: Optional[np.ndarray] = None):
        """h: [B, d]. Returns (y [B, d], TokenStats).

        Activated set = predictor(h) if trained, else oracle mask (exact ReLU
        support, what the paper's predictor approximates with ~high recall).
        The payload is gathered into the same reused pad-bucketed staging
        buffer as the batched path — no fresh concatenation allocs.
        """
        if oracle_mask is None:
            assert self.predictors is not None, "need predictor or oracle mask"
            oracle_mask = np.asarray(predict_mask(self.predictors[layer], jnp.asarray(h)))
        ids = np.nonzero(np.any(np.atleast_2d(oracle_mask), axis=0))[0]
        _, stats = self.engines[layer].step(ids, fetch_payload=False)
        y = self._ffn_compute(layer, jnp.asarray(h), ids)
        return np.asarray(y), stats

    # -- whole decode batch, per-request attribution -------------------------
    def ffn_apply_batch(
        self,
        layer: int,
        h: jnp.ndarray,                            # [B, d]
        masks: Optional[np.ndarray] = None,        # [B, n_neurons] bool
    ) -> tuple[jnp.ndarray, BatchStepResult]:
        """One batched engine step for all B requests' activated sets.

        Returns (y [B, d], BatchStepResult). The FFN is computed once over
        the union payload — rows not activated for a request contribute 0
        under ReLU, and over-coverage from sharing neurons across requests is
        exact for the same reason. The engine consumes the mask matrix
        directly (`step_masks`) and the union payload is gathered into a
        reused pad-bucketed staging buffer: one buffer fill + one
        host-to-device transfer per layer, no per-request id lists and no
        fresh concatenation allocs in the decode inner loop.
        """
        if masks is None:
            assert self.predictors is not None, "need predictors or oracle masks"
            masks = np.asarray(predict_mask(self.predictors[layer], h))
        masks = np.atleast_2d(np.asarray(masks))
        res = self.engines[layer].step_masks(masks, fetch_payload=False)
        y = self._ffn_compute(layer, h, res.ids)
        return y, res

    # -- asynchronous layer-ahead prefetch -----------------------------------
    def start_prefetch(self) -> None:
        """Spin up a fresh I/O worker (one per served group: a clean worker
        means no stale staged state can leak across serve calls). A no-op
        while the worker is supervision-disabled (restart budget exhausted
        mid-run): the serving loop re-checks `prefetch_active` every step
        and must NOT be allowed to reset the budget until the run ends
        (`stop_prefetch` re-arms it)."""
        if self._worker_disabled:
            return
        if self._worker is not None:
            self.stop_prefetch()
        self._inflight.clear()
        self._worker = PrefetchWorker(self)

    def stop_prefetch(self) -> None:
        """Shut the worker down and re-arm supervision for the next run."""
        if self._worker is not None:
            self._worker.shutdown()
            self._worker = None
        self._inflight.clear()
        self._worker_disabled = False
        self._restarts_used = 0

    @property
    def prefetch_active(self) -> bool:
        return self._worker is not None and self._worker.alive

    def begin_layer(self, layer: int, masks: np.ndarray) -> None:
        """Submit a (possibly speculative) prefetch for `layer` to the
        worker. Degrades instead of crashing: with no live worker (never
        started, died and found dead here, or supervision-disabled) the
        submission is skipped and `complete_layer` serves the layer through
        the synchronous fallback."""
        if self._worker is not None and not self._worker.alive:
            self._handle_worker_death(
                RuntimeError("prefetch worker found dead at submit"))
        if self._worker is None:
            return
        self._worker.submit(layer, masks)
        self._inflight.add(layer)

    def predict_lookahead(self, layer: int, h_np: np.ndarray) -> np.ndarray:
        """Speculative mask for `layer + 1` from layer `layer`'s pre-FFN
        hidden state, evaluated in pure numpy on cached host-side predictor
        params — no jax dispatch competing with the decode computation."""
        from repro.core.predictor import as_numpy_params, predict_mask_np
        if self._lookahead_np is None:
            self._lookahead_np = [as_numpy_params(p) for p in self.lookahead]
        return predict_mask_np(self._lookahead_np[layer], h_np,
                               threshold=self.lookahead_threshold)

    def _stage_layer(self, layer: int, masks: np.ndarray) -> PrefetchedLayer:
        """Worker-side: engine begin phase + staging gather into ring slot
        `layer % 2` (consecutive layers alternate slots, so the serving
        thread's buffer is never the one the worker is filling)."""
        eng = self.engines[layer]
        pending = eng.begin_step_masks(masks, fetch_payload=False)
        k = int(pending.union.size)
        if self.ffn_kernel != "segments":
            store = eng.store
            padded = -(-max(k, 1) // self.PAD_BUCKET) * self.PAD_BUCKET
            # dtype-faithful staging: the ring slot is allocated at the RAW
            # stored dtype, so int8 pack rows stay int8 from pread to device
            # transfer; the companion scale slot rides along and the dequant
            # happens on-device inside sparse_ffn_from_bundles.
            buf = self._ring_slot(store.bundle_width, store.stored_dtype,
                                  padded, layer % 2)
            store.fetch_into(pending.union, buf)
            buf[k:padded] = 0
            if store.quantized:
                sbuf = self._scale_slot(padded, layer % 2)
                store.fetch_scales_into(pending.union, sbuf)
                sbuf[k:padded] = 0
        return PrefetchedLayer(layer=layer, pending=pending, k_spec=k)

    def _handle_worker_death(self, exc: BaseException) -> None:
        """Supervision: the worker thread died (non-Exception fault, OOM,
        ...). All in-flight prefetches are lost with the thread's queues;
        restart within the per-run budget, else disable the worker for the
        rest of the run (every remaining layer serves synchronously)."""
        from repro.utils import logger
        old, self._worker = self._worker, None
        self._inflight.clear()
        if old is not None:
            old.shutdown()
        if self._restarts_used < self.max_worker_restarts:
            self._restarts_used += 1
            self.worker_restarts += 1
            logger.warning(
                "prefetch worker died (%s); restarting (%d/%d)",
                exc, self._restarts_used, self.max_worker_restarts)
            self._worker = PrefetchWorker(self)
        else:
            self._worker_disabled = True
            logger.warning(
                "prefetch worker died (%s); restart budget (%d) exhausted — "
                "decode continues on the synchronous fallback path",
                exc, self.max_worker_restarts)

    def _complete_degraded(
        self, layer: int, h: jnp.ndarray, true_masks: np.ndarray,
    ) -> tuple[jnp.ndarray, BatchStepResult, StageMeasurement]:
        """Synchronous fallback for a layer whose prefetch was lost (worker
        death or per-job failure): one full engine step against the TRUE
        masks plus FFN from a dedicated staging slot — the double-buffered
        ring slots may still hold a live prefetch for a neighbouring layer,
        which must not be clobbered. Output is exact: the payload comes
        from the same store reads the serial path would issue."""
        t0 = time.perf_counter()
        get_tracer().instant("degraded_layer", layer=layer)
        masks = np.atleast_2d(np.asarray(true_masks))
        res = self.engines[layer].step_masks(masks, fetch_payload=False)
        y = self._ffn_compute(layer, h, res.ids, staging_slot="degraded")
        res.merged.io.degraded_steps += 1
        self.degraded_steps += 1
        meas = StageMeasurement(topup_seconds=time.perf_counter() - t0)
        return y, res, meas

    def complete_layer(
        self, layer: int, h: jnp.ndarray, true_masks: np.ndarray,
    ) -> tuple[jnp.ndarray, BatchStepResult, StageMeasurement]:
        """Serving-thread side: wait for `layer`'s prefetch, reconcile against
        the true masks (synchronous top-up read for lookahead misses — the
        mis-predicted payload is fetched and merged before compute, never
        skipped), and evaluate the FFN from the staged ring buffer.

        Fault-tolerant: a layer with no staged prefetch (worker dead /
        disabled / submission skipped), a per-job worker exception, or a
        worker death while waiting all land in `_complete_degraded` — the
        step is served synchronously and decode continues, token-identical
        whenever the underlying payload reads stay correct.
        """
        if self._worker is None or layer not in self._inflight:
            return self._complete_degraded(layer, h, true_masks)
        t0 = time.perf_counter()
        try:
            with get_tracer().span("prefetch_wait", layer=layer):
                pf = self._worker.wait(layer)
        except Exception as e:
            from repro.utils import logger
            self._inflight.discard(layer)
            if self._worker is not None and not self._worker.alive:
                self._handle_worker_death(e)
            else:
                # per-job failure: the worker survived, only this layer's
                # staged read is lost; later in-flight layers stay valid.
                logger.warning("prefetch for layer %d failed (%s); serving "
                               "synchronously", layer, e)
            return self._complete_degraded(layer, h, true_masks)
        self._inflight.discard(layer)
        blocked = time.perf_counter() - t0
        eng = self.engines[layer]
        t1 = time.perf_counter()
        res = eng.complete_step(pf.pending, true_masks)
        extra = res.topup_ids
        self.topup_total += int(extra.size)
        k_total = pf.k_spec + int(extra.size)
        if self.ffn_kernel == "segments":
            served = (pf.pending.union if extra.size == 0
                      else np.concatenate([pf.pending.union, extra]))
            topup = time.perf_counter() - t1
            y = self._ffn_segments(layer, h, served)
        else:
            store = eng.store
            padded = -(-max(k_total, 1) // self.PAD_BUCKET) * self.PAD_BUCKET
            buf = self._ring_slot(store.bundle_width, store.stored_dtype,
                                  padded, layer % 2, preserve_rows=pf.k_spec)
            if extra.size:   # stage the topped-up payload after the prefetch
                store.fetch_into(extra, buf[pf.k_spec:])
            buf[k_total:padded] = 0
            scales = None
            if store.quantized:
                sbuf = self._scale_slot(padded, layer % 2,
                                        preserve_rows=pf.k_spec)
                if extra.size:
                    store.fetch_scales_into(extra, sbuf[pf.k_spec:])
                sbuf[k_total:padded] = 0
                scales = jnp.asarray(sbuf[:padded])
            topup = time.perf_counter() - t1
            valid = jnp.arange(padded) < k_total
            y = sparse_ffn_from_bundles(
                h, jnp.asarray(buf[:padded]), self.cfg.d_model, self.n_mats,
                activation=self.cfg.activation, valid_mask=valid,
                scales=scales)
        meas = StageMeasurement(io_host_seconds=pf.io_host_seconds,
                                blocked_seconds=blocked, topup_seconds=topup)
        return y, res, meas

    # activated-set sizes vary every (step, layer); without bucketing each
    # fresh size triggers a new XLA compilation of the sparse-FFN matmuls.
    PAD_BUCKET = 128

    def _ring_slot(self, width: int, dtype, padded: int, slot: int,
                   preserve_rows: int = 0) -> np.ndarray:
        """One slot of the double-buffered staging ring (pad-bucketed host
        buffers, grown geometrically, shared by all layers of equal bundle
        width). `preserve_rows` keeps already-staged leading rows across a
        growth reallocation (the top-up append path)."""
        key = (width, dtype, slot)
        buf = self._staging.get(key)
        if buf is None or buf.shape[0] < padded:
            size = max(padded, 2 * buf.shape[0] if buf is not None else padded)
            new = np.zeros((size, width), dtype=dtype)
            if buf is not None and preserve_rows:
                new[:preserve_rows] = buf[:preserve_rows]
            buf = new
            self._staging[key] = buf
        return buf

    def _scale_slot(self, padded: int, slot: int,
                    preserve_rows: int = 0) -> np.ndarray:
        """Companion ring slot for per-neuron dequant scales (f32 [k]) —
        staged alongside each quantized payload slot so scales ride the same
        double-buffering discipline as the bundles they describe."""
        key = ("scales", slot)
        buf = self._staging.get(key)
        if buf is None or buf.shape[0] < padded:
            size = max(padded, 2 * buf.shape[0] if buf is not None else padded)
            new = np.zeros((size,), dtype=np.float32)
            if buf is not None and preserve_rows:
                new[:preserve_rows] = buf[:preserve_rows]
            buf = new
            self._staging[key] = buf
        return buf

    def _staging_buffer(self, width: int, dtype, padded: int) -> np.ndarray:
        """Serial-path staging buffer = slot 0 of the ring."""
        return self._ring_slot(width, dtype, padded, 0)

    def _ffn_compute(self, layer: int, h: jnp.ndarray, ids: np.ndarray,
                     staging_slot=0) -> jnp.ndarray:
        """Dispatch the resolved FFN path for an activated-union id list.
        `staging_slot` picks the host staging buffer: the degraded fallback
        uses its own slot so it can never clobber a ring slot holding a
        live neighbouring-layer prefetch."""
        if self.ffn_kernel == "segments":
            return self._ffn_segments(layer, h, ids)
        return self._ffn_from_ids(layer, h, ids, staging_slot)

    def _ffn_from_ids(self, layer: int, h: jnp.ndarray,
                      ids: np.ndarray, staging_slot=0) -> jnp.ndarray:
        store = self.engines[layer].store
        k = int(ids.size)
        padded = -(-max(k, 1) // self.PAD_BUCKET) * self.PAD_BUCKET
        buf = self._ring_slot(store.bundle_width,
                              store.stored_dtype, padded, staging_slot)
        store.fetch_into(ids, buf)
        buf[k:padded] = 0
        scales = None
        if store.quantized:
            sbuf = self._scale_slot(padded, staging_slot)
            store.fetch_scales_into(ids, sbuf)
            sbuf[k:padded] = 0
            scales = jnp.asarray(sbuf[:padded])
        valid = jnp.arange(padded) < k
        return sparse_ffn_from_bundles(
            h, jnp.asarray(buf[:padded]), self.cfg.d_model, self.n_mats,
            activation=self.cfg.activation, valid_mask=valid, scales=scales)

    # -- fused segment-gather kernel path (EngineConfig.ffn_kernel) ----------
    def _segment_weight_mats(self, layer: int) -> tuple:
        """Physical-layout weight matrices for the fused segment kernel,
        cached per layer: the store's RAW flash payload (int8 stays int8 —
        dequant happens in-kernel) reshaped into [N, d] up/down(/gate)
        matrices in placement order, zero-padded to a segment multiple, plus
        the host-side per-neuron base multipliers (dequant scales, or 1.0 for
        float payloads) in physical order."""
        cached = self._segment_weights.get(layer)
        if cached is not None:
            return cached
        store = self.engines[layer].store
        seg = self.engine_cfg.kernel_seg_size
        d = self.cfg.d_model
        parts = np.asarray(store.physical_payload(dequantize=False)).reshape(
            store.n_neurons, self.n_mats, d)
        pad = (-store.n_neurons) % seg
        if pad:
            parts = np.concatenate(
                [parts, np.zeros((pad,) + parts.shape[1:], parts.dtype)])
        base = np.ones(store.n_neurons + pad, dtype=np.float32)
        scales = store.physical_scales()
        if scales is not None:
            base[:store.n_neurons] = scales
        if self.n_mats == 3:     # bundle layout [gate | up | down]
            mats = (jnp.asarray(parts[:, 1]), jnp.asarray(parts[:, 2]),
                    jnp.asarray(parts[:, 0]), base)
        else:                    # [up | down]
            mats = (jnp.asarray(parts[:, 0]), jnp.asarray(parts[:, 1]),
                    None, base)
        self._segment_weights[layer] = mats
        return mats

    SEG_ID_BUCKET = 8

    def _ffn_segments(self, layer: int, h: jnp.ndarray,
                      ids: np.ndarray) -> jnp.ndarray:
        """FFN via the fused segment-gather kernel: the activated union maps
        to seg_size-aligned blocks of the PHYSICAL (placement-permuted)
        layout — contiguous links become few segments, the kernel's DMA
        argument. Exact for every supported activation: each segment carries
        a per-neuron multiplier tile (dequant scale x membership in the
        served union) applied to the weight rows in-kernel, so covered-but-
        not-activated neurons contribute exactly zero and int8 payloads are
        dequantized in VMEM, never on the host. Consumes two reused host
        buffers (segment ids + scale tiles) via jnp.asarray — no fresh
        concatenate/pad in the decode loop."""
        from repro.kernels import ops
        eng = self.engines[layer]
        seg = self.engine_cfg.kernel_seg_size
        w_up, w_down, w_gate, base = self._segment_weight_mats(layer)
        phys = eng.placement.physical_of(np.asarray(ids, dtype=np.int64))
        seg_of = phys // seg
        seg_u = np.unique(seg_of)
        S = int(seg_u.size)
        padded = -(-max(S, 1) // self.SEG_ID_BUCKET) * self.SEG_ID_BUCKET
        id_buf = self._seg_ids_buf(padded)
        id_buf[:S] = seg_u
        id_buf[S:padded] = -1
        tiles = self._seg_tiles_buf(padded, seg)
        tiles[:padded] = 0.0
        rows = np.searchsorted(seg_u, seg_of)
        tiles[rows, phys % seg] = base[phys]
        return ops.sparse_ffn_segments_fused(
            h, w_up, w_down, jnp.asarray(id_buf[:padded]),
            jnp.asarray(tiles[:padded]), w_gate,
            seg_size=seg, activation=self.cfg.activation)

    def _seg_ids_buf(self, padded: int) -> np.ndarray:
        buf = self._staging.get(("seg_ids",))
        if buf is None or buf.shape[0] < padded:
            size = max(padded, 2 * buf.shape[0] if buf is not None else padded)
            buf = np.empty((size,), dtype=np.int32)
            self._staging[("seg_ids",)] = buf
        return buf

    def _seg_tiles_buf(self, padded: int, seg: int) -> np.ndarray:
        buf = self._staging.get(("seg_tiles", seg))
        if buf is None or buf.shape[0] < padded:
            size = max(padded, 2 * buf.shape[0] if buf is not None else padded)
            buf = np.zeros((size, seg), dtype=np.float32)
            self._staging[("seg_tiles", seg)] = buf
        return buf

    @property
    def n_layers(self) -> int:
        return len(self.engines)

    def io_summary(self) -> dict:
        """Aggregate I/O metrics across layers.

        Ratio metrics (bandwidth, hit rate, mean run length) are computed
        from summed numerators and denominators — a mean of per-layer ratios
        would weight layers equally regardless of how much traffic each
        actually served."""
        tokens = [t for e in self.engines for t in e.history]
        io_s = sum(t.io.seconds for t in tokens)
        useful = sum(t.io.bytes_useful for t in tokens)
        hits = sum(e.cache.stats.hits for e in self.engines)
        accesses = sum(e.cache.stats.hits + e.cache.stats.misses
                       for e in self.engines)
        runs = (np.concatenate([np.asarray(t.run_lengths) for t in tokens])
                if tokens else np.zeros(0, dtype=np.int64))
        per_layer = [e.summary() for e in self.engines]
        out = {
            # resolved FFN path + why (the EngineConfig may have said "auto")
            "ffn_kernel": self.ffn_kernel,
            "ffn_kernel_decision": self.ffn_kernel_reason,
            "io_seconds_per_token": sum(s["io_seconds_per_token"]
                                        for s in per_layer),
            "mean_run_length": float(runs.mean()) if runs.size else 0.0,
            "effective_bandwidth": useful / io_s if io_s else 0.0,
            "cache_hit_rate": hits / accesses if accesses else 0.0,
            "ops_per_token": sum(s["ops_per_token"] for s in per_layer),
            # fault-tolerance counters: ALWAYS present (and exactly zero on
            # the clean path — the CI chaos job gates on that). retries /
            # corrupt_extents flow up from the stores' IOStats; degraded
            # steps / worker restarts come from prefetch supervision.
            "retries": sum(t.io.retries for t in tokens),
            "corrupt_extents": sum(t.io.corrupt_extents for t in tokens),
            "degraded_steps": sum(t.io.degraded_steps for t in tokens),
            "worker_restarts": self.worker_restarts,
        }
        # dual accounting: wall-clock of REAL file reads, when the stores
        # perform any (FileNeuronStore over a NeuronPack) — alongside, never
        # instead of, the modeled device seconds above
        meas_ops = sum(t.io.measured_ops for t in tokens)
        if meas_ops:
            n_tok = max(max(len(e.history) for e in self.engines), 1)
            out["measured_file_seconds_per_token"] = (
                sum(t.io.measured_seconds for t in tokens) / n_tok)
            out["measured_extents_total"] = meas_ops
            out["measured_bytes_total"] = sum(t.io.measured_bytes
                                              for t in tokens)
        return out

    def predict_step_io_seconds(self, unions) -> float:
        """Modeled flash seconds one decode step serving `unions` (a per-layer
        sequence of activated-neuron id arrays, one per layer engine) would
        cost right now. Pure: delegates to each engine's
        `predict_read_seconds` (cache peeked, not probed; adaptive thresholds
        read, not updated). The InferenceServer's flash-I/O-aware admission
        gate sums this with its compute estimate to decide whether admitting
        another request would blow active inter-token deadlines."""
        if len(unions) != len(self.engines):
            raise ValueError(f"expected {len(self.engines)} per-layer unions, "
                             f"got {len(unions)}")
        return sum(e.predict_read_seconds(u)
                   for e, u in zip(self.engines, unions))

    def reset_stats(self) -> None:
        for e in self.engines:
            e.reset_stats()
        self.topup_total = 0
        self.worker_restarts = 0
        self.degraded_steps = 0

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Shut down the prefetch worker and close every layer store
        (releases `FileNeuronStore` fds + memmaps; the in-memory store's
        close is a no-op). Idempotent."""
        self.stop_prefetch()
        for e in self.engines:
            e.store.close()

    def __enter__(self) -> "OffloadedFFNRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def dense_ffn_layer_count(cfg: ModelConfig) -> int:
    """Number of dense-FFN layers the offload runtime serves (capture order:
    dense sublayers of the periodic stack prefix, times the group count)."""
    P = transformer.stack_period(cfg)
    return (cfg.n_layers // P) * sum(k == "dense"
                                     for k in cfg.ffn_kinds()[:P])


def validate_pack_for_model(pack, cfg: ModelConfig) -> None:
    """Submit-time geometry check: a NeuronPack can only serve a model whose
    dense-FFN layer count, neuron count (d_ff), and bundle width
    (n_mats * d_model) it matches. Packs built by the offline packer also
    record d_model / n_mats / activation in `meta`, which is checked when
    present — bundle_width alone cannot distinguish a [gate|up|down] silu
    bundle from an [up|down] relu bundle of 1.5x the d_model. Raises
    ValueError listing every mismatch."""
    n_mats = 3 if cfg.activation == "silu" else 2
    expected = dict(n_layers=dense_ffn_layer_count(cfg), n_neurons=cfg.d_ff,
                    bundle_width=n_mats * cfg.d_model)
    mismatches = [f"{k}: pack has {getattr(pack, k)}, model needs {v}"
                  for k, v in expected.items() if getattr(pack, k) != v]
    meta = getattr(pack, "meta", None) or {}
    mismatches += [
        f"meta.{k}: pack built for {meta[k]!r}, model is {v!r}"
        for k, v in (("d_model", cfg.d_model), ("n_mats", n_mats),
                     ("activation", cfg.activation))
        if k in meta and meta[k] != v]
    if mismatches:
        raise ValueError(
            f"NeuronPack {pack.path} does not fit this model config: "
            + "; ".join(mismatches))


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

class ServingEngine:
    """One-shot batch front-end, kept as a thin compatibility wrapper.

    `serve(requests)` submits every request to a fresh slot-based
    `InferenceServer` (one slot per request) and drains it. For greedy
    same-length request groups the output is token-identical to the historic
    group-by-length lockstep path (rows are independent and sampling streams
    are per-request); what changed underneath: mixed-length requests now share
    one continuous batch, each request retires at its own `max_new_tokens` or
    stop token (freed rows leave the activation-mask unions, so finished
    requests stop incurring attributed flash I/O), and in prefetch mode ONE
    `PrefetchWorker` spans the whole call instead of one per group. New code
    should use `repro.serving.server.InferenceServer` directly — it adds
    mid-flight admission and streaming on the same machinery.
    """

    def __init__(self, model: Model, params: Any, max_len: int = 512,
                 swa: bool = False, mode: str = "resident",
                 offload: Optional[OffloadedFFNRuntime] = None,
                 scheduler: Optional[IOScheduler] = None,
                 oracle: bool = True,
                 prefetch: bool = False,
                 lookahead: Union[str, List[PredictorParams], None] = None,
                 pack_path: Optional[str] = None):
        """`prefetch=True` runs offload decode through the asynchronous
        layer-ahead pipeline: a background I/O worker serves layer k+1's
        engine step while the device computes layer k. `lookahead` picks the
        speculation source: a list of cross-layer predictor params (layer k's
        hidden -> layer k+1's mask), None to use the runtime's trained
        `lookahead` (falling back to "oracle"), or "oracle" — the exactness
        fallback where each layer's prefetch is issued with its TRUE mask
        (zero speculation depth, so no overlap, but the split-phase worker
        machinery is exercised bit-identically to serial).

        `pack_path` loads the offload runtime from an on-disk NeuronPack
        artifact (`OffloadedFFNRuntime.from_pack`, geometry-validated against
        the model config) instead of a caller-built runtime.
        """
        if mode not in ("resident", "offload"):
            raise ValueError(f"unknown serving mode {mode!r}")
        if pack_path is not None:
            if offload is not None:
                raise ValueError("pass either `offload` or `pack_path`, "
                                 "not both")
            if mode != "offload":
                raise ValueError("pack_path= requires mode='offload'")
            offload = OffloadedFFNRuntime.from_pack(model.cfg, pack_path)
        if mode == "offload":
            if offload is None:
                raise ValueError("mode='offload' needs an OffloadedFFNRuntime")
            cfg = model.cfg
            if cfg.is_encdec or cfg.family != "dense":
                raise ValueError("offload serving covers dense decoder-only archs")
        if isinstance(lookahead, str) and lookahead != "oracle":
            raise ValueError(f"unknown lookahead mode {lookahead!r}")
        self.model = model
        self.params = params
        self.max_len = max_len
        self.swa = swa
        self.mode = mode
        self.offload = offload
        self._owns_offload = pack_path is not None   # we built it: we close it
        self.oracle = oracle
        self.prefetch = prefetch
        self.lookahead = lookahead
        self.scheduler = scheduler or IOScheduler(overlap=True)
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c))
        # shared across the per-serve() InferenceServers so admission prefill
        # compiles once per prompt length, not once per serve() call
        self._prefill = (None if model.cfg.is_encdec else jax.jit(
            lambda p, toks, c: model.prefill(p, {"tokens": toks}, c)))

    def close(self) -> None:
        """Release the offload runtime's resources; closes the layer stores
        only when this engine built the runtime itself (pack_path=)."""
        if self.offload is not None:
            if self._owns_offload:
                self.offload.close()
            else:
                self.offload.stop_prefetch()

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def serve(self, requests: List[Request], seed: int = 0) -> List[Result]:
        """Submit every request to a fresh InferenceServer (one decode slot
        per request) and drain it. Results come back in request order."""
        from repro.serving.server import InferenceServer
        if not requests:
            return []
        server = InferenceServer(
            self.model, self.params, max_slots=len(requests),
            max_len=self.max_len, swa=self.swa, mode=self.mode,
            offload=self.offload, scheduler=self.scheduler, oracle=self.oracle,
            prefetch=self.prefetch, lookahead=self.lookahead, seed=seed,
            decode_fn=self._decode if self.mode == "resident" else None,
            prefill_fn=self._prefill)
        try:
            handles = [server.submit(r) for r in requests]
            server.drain()
        finally:
            server.close()
        return [h.result for h in handles]


def build_offload_runtime(
    model: Model,
    params: Any,
    rng: Optional[np.random.Generator] = None,
    calib_batch: tuple = (8, 64),
    engine_cfg: Optional[EngineConfig] = None,
    device: Optional[UFSDevice] = None,
    use_placement: bool = True,
    train_lookahead: bool = False,
    lookahead_threshold: float = 0.35,
    lookahead_epochs: int = 4,
) -> OffloadedFFNRuntime:
    """Calibrate placements from a short random-token trace and pack the
    model's dense-FFN weights into flash bundles, one engine per dense layer.

    `use_placement=False` keeps the identity layout (the LLMFlash-style
    baseline arm of the benchmarks). `train_lookahead=True` additionally fits
    the cross-layer lookahead predictors (layer k's pre-FFN hidden -> layer
    k+1's mask) on the same calibration trace, enabling real speculation
    depth in the prefetch pipeline. Works for any stack period: layers are
    enumerated in the same (group, sublayer) order as `ffn_pre_act` capture.
    """
    from repro.core.coactivation import stats_from_masks
    from repro.core.placement import identity_placement, search_placement
    from repro.store.packer import extract_dense_ffn_bundles

    cfg = model.cfg
    if cfg.family != "dense" or cfg.is_encdec:
        raise ValueError("offload runtime covers dense decoder-only archs")
    rng = rng or np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, calib_batch), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    bundles = extract_dense_ffn_bundles(cfg, params)
    placements = []
    for dense_idx in range(len(bundles)):
        if use_placement:
            masks = np.asarray(
                out["ffn_pre_act"][dense_idx] > 0).reshape(-1, cfg.d_ff)
            placements.append(search_placement(
                stats_from_masks(masks).distance_matrix(), mode="auto"))
        else:
            placements.append(identity_placement(cfg.d_ff))
    dense_idx = len(bundles)
    lookahead = None
    if train_lookahead and dense_idx > 1:
        hiddens = np.asarray(out["ffn_inputs"]).reshape(
            dense_idx, -1, cfg.d_model)
        masks = np.asarray(out["ffn_pre_act"] > 0).reshape(
            dense_idx, -1, cfg.d_ff)
        lookahead = train_lookahead_predictors(
            hiddens, masks, threshold=lookahead_threshold,
            epochs=lookahead_epochs)
    return OffloadedFFNRuntime(cfg, bundles, placements, device=device,
                               engine_cfg=engine_cfg, lookahead=lookahead,
                               lookahead_threshold=lookahead_threshold)
