"""Serving engine: batched prefill + decode, resident or flash-offloaded.

Two modes, one `serve()`:
  * resident  — all weights in device memory; jit'd prefill/decode only.
  * offload   — the paper's §5 online stage, end-to-end: prefill runs dense
    (the paper offloads only the memory-dominant decode FFN), then every
    decode step drives, per dense-FFN layer and for the WHOLE decode batch,
        predict activated neurons (trained predictor or exact ReLU oracle)
        -> one batched engine step (merged cache probe + single collapsed
           extent read over the simulated UFS layout)
        -> sparse FFN computed from the bundle payloads actually read,
    while an `IOScheduler` models double-buffered I/O–compute overlap
    (layer L+1's read hides behind layer L's compute). Per-request I/O is
    attributed by the engine and lands in `Result.io_seconds`; batch-level
    overlapped vs serial latency comes from `scheduler.summary()`.

The offload path intentionally runs layer-by-layer on host (it models a
phone-style single-device runtime); the distributed pjit path is the dense
one exercised by launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import BatchStepResult, EngineConfig, OffloadEngine
from repro.core.pipeline import IOScheduler
from repro.core.placement import PlacementResult
from repro.core.predictor import PredictorParams, predict_mask
from repro.core.sparse_ffn import sparse_ffn_from_bundles
from repro.core.storage import UFSDevice
from repro.models import transformer
from repro.models.layers import apply_norm, embed_tokens, unembed
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prefill_seconds: float
    decode_seconds: float
    io_seconds: float = 0.0            # this request's attributed flash I/O
    # Group-level pipelined decode latency. NOTE: a hybrid — stage compute is
    # MEASURED host wall time (eager jax on this machine), stage io is the
    # MODELED UFS read time; benchmarks/serving_pipeline.py reports the fully
    # modeled (machine-independent) counterpart.
    overlapped_seconds: float = 0.0


def sample_tokens(logits: jnp.ndarray, temperatures, key) -> jnp.ndarray:
    """Per-row sampling: row i is greedy if temperatures[i] <= 0, else
    categorical at its own temperature — one vectorized call for the whole
    decode batch, so mixed-temperature groups need no per-request loop."""
    hot = np.asarray(temperatures) > 0
    greedy_all = not bool(hot.any())
    greedy = jnp.argmax(logits, axis=-1)
    if greedy_all:                  # common all-greedy case: skip sampling
        return greedy
    temps = jnp.asarray(temperatures, dtype=logits.dtype)
    safe = jnp.where(jnp.asarray(hot), temps, jnp.ones_like(temps))
    sampled = jax.random.categorical(key, logits / safe[:, None], axis=-1)
    return jnp.where(jnp.asarray(hot), sampled, greedy)


def sample_token(logits: jnp.ndarray, temperature: float, key) -> jnp.ndarray:
    """Single shared temperature for every row (legacy helper)."""
    return sample_tokens(logits, np.full((logits.shape[0],), temperature,
                                         dtype=np.float32), key)


# ---------------------------------------------------------------------------
# Offloaded FFN runtime: per-layer engines + batched apply
# ---------------------------------------------------------------------------

class OffloadedFFNRuntime:
    """Per-layer RIPPLE offload state: engines, predictors, placements."""

    def __init__(
        self,
        cfg: ModelConfig,
        bundles_per_layer: List[np.ndarray],       # [L][n_neurons, bundle_width]
        placements: List[PlacementResult],
        predictors: Optional[List[PredictorParams]] = None,
        device: Optional[UFSDevice] = None,
        engine_cfg: Optional[EngineConfig] = None,
    ) -> None:
        self.cfg = cfg
        self.engines = [
            OffloadEngine(b, placement=pl, device=device, config=engine_cfg)
            for b, pl in zip(bundles_per_layer, placements)
        ]
        self.predictors = predictors
        self.n_mats = 3 if cfg.activation == "silu" else 2
        self._staging: Dict[tuple, np.ndarray] = {}

    # -- single merged activated set (legacy accounting interface) ----------
    def ffn_apply(self, layer: int, h: np.ndarray, oracle_mask: Optional[np.ndarray] = None):
        """h: [B, d]. Returns (y [B, d], TokenStats).

        Activated set = predictor(h) if trained, else oracle mask (exact ReLU
        support, what the paper's predictor approximates with ~high recall).
        """
        if oracle_mask is None:
            assert self.predictors is not None, "need predictor or oracle mask"
            oracle_mask = np.asarray(predict_mask(self.predictors[layer], jnp.asarray(h)))
        ids = np.nonzero(np.any(np.atleast_2d(oracle_mask), axis=0))[0]
        data, stats = self.engines[layer].step(ids)
        y = self._ffn_from_bundles(jnp.asarray(h), data)
        return np.asarray(y), stats

    # -- whole decode batch, per-request attribution -------------------------
    def ffn_apply_batch(
        self,
        layer: int,
        h: jnp.ndarray,                            # [B, d]
        masks: Optional[np.ndarray] = None,        # [B, n_neurons] bool
    ) -> tuple[jnp.ndarray, BatchStepResult]:
        """One batched engine step for all B requests' activated sets.

        Returns (y [B, d], BatchStepResult). The FFN is computed once over
        the union payload — rows not activated for a request contribute 0
        under ReLU, and over-coverage from sharing neurons across requests is
        exact for the same reason. The engine consumes the mask matrix
        directly (`step_masks`) and the union payload is gathered into a
        reused pad-bucketed staging buffer: one buffer fill + one
        host-to-device transfer per layer, no per-request id lists and no
        fresh concatenation allocs in the decode inner loop.
        """
        if masks is None:
            assert self.predictors is not None, "need predictors or oracle masks"
            masks = np.asarray(predict_mask(self.predictors[layer], h))
        masks = np.atleast_2d(np.asarray(masks))
        res = self.engines[layer].step_masks(masks, fetch_payload=False)
        y = self._ffn_from_ids(layer, h, res.ids)
        return y, res

    # activated-set sizes vary every (step, layer); without bucketing each
    # fresh size triggers a new XLA compilation of the sparse-FFN matmuls.
    PAD_BUCKET = 128

    def _staging_buffer(self, width: int, dtype, padded: int) -> np.ndarray:
        """Reused pinned-style host buffer for pad-bucketed bundle payloads,
        grown geometrically and shared by all layers of equal bundle width."""
        buf = self._staging.get((width, dtype))
        if buf is None or buf.shape[0] < padded:
            size = max(padded, 2 * buf.shape[0] if buf is not None else padded)
            buf = np.zeros((size, width), dtype=dtype)
            self._staging[(width, dtype)] = buf
        return buf

    def _ffn_from_ids(self, layer: int, h: jnp.ndarray,
                      ids: np.ndarray) -> jnp.ndarray:
        store = self.engines[layer].store
        k = int(ids.size)
        padded = -(-max(k, 1) // self.PAD_BUCKET) * self.PAD_BUCKET
        buf = self._staging_buffer(store.bundle_width,
                                   store._phys_data.dtype, padded)
        store.fetch_into(ids, buf)
        buf[k:padded] = 0
        valid = jnp.arange(padded) < k
        return sparse_ffn_from_bundles(
            h, jnp.asarray(buf[:padded]), self.cfg.d_model, self.n_mats,
            activation=self.cfg.activation, valid_mask=valid)

    def _ffn_from_bundles(self, h: jnp.ndarray, data: np.ndarray) -> jnp.ndarray:
        k = data.shape[0]
        padded = -(-max(k, 1) // self.PAD_BUCKET) * self.PAD_BUCKET
        if padded != k:
            data = np.concatenate(
                [data, np.zeros((padded - k,) + data.shape[1:], data.dtype)])
        valid = jnp.arange(padded) < k
        return sparse_ffn_from_bundles(
            h, jnp.asarray(data), self.cfg.d_model, self.n_mats,
            activation=self.cfg.activation, valid_mask=valid)

    @property
    def n_layers(self) -> int:
        return len(self.engines)

    def io_summary(self) -> dict:
        """Aggregate I/O metrics across layers.

        Ratio metrics (bandwidth, hit rate, mean run length) are computed
        from summed numerators and denominators — a mean of per-layer ratios
        would weight layers equally regardless of how much traffic each
        actually served."""
        tokens = [t for e in self.engines for t in e.history]
        io_s = sum(t.io.seconds for t in tokens)
        useful = sum(t.io.bytes_useful for t in tokens)
        hits = sum(e.cache.stats.hits for e in self.engines)
        accesses = sum(e.cache.stats.hits + e.cache.stats.misses
                       for e in self.engines)
        runs = (np.concatenate([np.asarray(t.run_lengths) for t in tokens])
                if tokens else np.zeros(0, dtype=np.int64))
        per_layer = [e.summary() for e in self.engines]
        return {
            "io_seconds_per_token": sum(s["io_seconds_per_token"]
                                        for s in per_layer),
            "mean_run_length": float(runs.mean()) if runs.size else 0.0,
            "effective_bandwidth": useful / io_s if io_s else 0.0,
            "cache_hit_rate": hits / accesses if accesses else 0.0,
            "ops_per_token": sum(s["ops_per_token"] for s in per_layer),
        }

    def reset_stats(self) -> None:
        for e in self.engines:
            e.reset_stats()


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

class ServingEngine:
    """Continuous-batching-lite: fixed decode batch, greedy/temperature sampling."""

    def __init__(self, model: Model, params: Any, max_len: int = 512,
                 swa: bool = False, mode: str = "resident",
                 offload: Optional[OffloadedFFNRuntime] = None,
                 scheduler: Optional[IOScheduler] = None,
                 oracle: bool = True):
        if mode not in ("resident", "offload"):
            raise ValueError(f"unknown serving mode {mode!r}")
        if mode == "offload":
            if offload is None:
                raise ValueError("mode='offload' needs an OffloadedFFNRuntime")
            cfg = model.cfg
            if cfg.is_encdec or cfg.family != "dense":
                raise ValueError("offload serving covers dense decoder-only archs")
        self.model = model
        self.params = params
        self.max_len = max_len
        self.swa = swa
        self.mode = mode
        self.offload = offload
        self.oracle = oracle
        self.scheduler = scheduler or IOScheduler(overlap=True)
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c))

    def serve(self, requests: List[Request], seed: int = 0) -> List[Result]:
        results = []
        key = jax.random.PRNGKey(seed)
        for g, group in enumerate(_group_by_len(requests)):
            # distinct sampling stream per prompt-length group
            group_key = jax.random.fold_in(key, g)
            if self.mode == "offload":
                results.extend(self._serve_group_offload(group, group_key))
            else:
                results.extend(self._serve_group_resident(group, group_key))
        return results

    # -- resident (dense jit) path ------------------------------------------
    def _serve_group_resident(self, group: List[Request], key) -> List[Result]:
        toks = np.stack([r.prompt for r in group])
        temps = np.array([r.temperature for r in group], dtype=np.float32)
        B, T = toks.shape
        cache = self.model.init_cache(B, self.max_len, swa=self.swa)
        t0 = time.perf_counter()
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        max_new = max(r.max_new_tokens for r in group)
        outs = [[] for _ in group]
        cur = sample_tokens(logits[:, -1], temps, key)
        t0 = time.perf_counter()
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i]))
            key = jax.random.fold_in(key, step)
            logits, cache = self._decode(
                self.params, cur[:, None].astype(jnp.int32),
                jnp.int32(T + step), cache)
            cur = sample_tokens(logits[:, 0], temps, key)
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t0
        return [Result(uid=r.uid, tokens=o[: r.max_new_tokens],
                       prefill_seconds=t_prefill, decode_seconds=t_decode)
                for r, o in zip(group, outs)]

    # -- offloaded (paper §5) path ------------------------------------------
    def _oracle_w_ups(self) -> List[jnp.ndarray]:
        """Resident w_up handles per dense layer, in capture order — the exact
        ReLU support oracle the predictor approximates. The simulated flash
        still pays for every neuron the mask selects."""
        cfg = self.model.cfg
        P = transformer.stack_period(cfg)
        G = cfg.n_layers // P
        ffns = cfg.ffn_kinds()
        w_ups = []
        for g in range(G):
            for j in range(P):
                if ffns[j] == "dense":
                    w_ups.append(self.params["stack"][f"sub_{j}"]["ffn"]["w_up"][g])
        return w_ups

    def _serve_group_offload(self, group: List[Request], key) -> List[Result]:
        cfg = self.model.cfg
        runtime = self.offload
        toks = np.stack([r.prompt for r in group])
        temps = np.array([r.temperature for r in group], dtype=np.float32)
        B, T = toks.shape
        cache = self.model.init_cache(B, self.max_len, swa=self.swa)
        t0 = time.perf_counter()
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        param_groups = transformer.unstack_groups(self.params["stack"], cfg)
        cache_groups = transformer.unstack_groups(cache, cfg)
        w_ups = self._oracle_w_ups() if self.oracle else None
        if w_ups is not None and len(w_ups) != runtime.n_layers:
            raise ValueError(
                f"runtime has {runtime.n_layers} layer engines, model has "
                f"{len(w_ups)} dense FFN layers")

        max_new = max(r.max_new_tokens for r in group)
        outs = [[] for _ in group]
        req_io = np.zeros(B)

        # Sync-free layerwise decode: the FFN override never blocks on its
        # output — XLA dispatch runs ahead across layers while the engine
        # (host-side) serves the NEXT layer's masks and payload gather. The
        # only per-layer host materialisation is the small activation-mask
        # matrix the engine needs. One end-of-token sync measures the whole
        # token; the scheduler apportions it across stages by modeled FFN
        # FLOPs instead of per-layer wall clocks (which would each force a
        # device sync).
        def ffn_override(dense_idx: int, normed2: jnp.ndarray) -> jnp.ndarray:
            h2 = normed2[:, 0]                                     # [B, d]
            if w_ups is not None:
                masks = np.asarray(h2 @ w_ups[dense_idx] > 0)      # exact support
            else:
                masks = None                                       # predictor path
            y, res = runtime.ffn_apply_batch(dense_idx, h2, masks)
            flops = 2.0 * B * res.merged.n_activated * runtime.n_mats * cfg.d_model
            self.scheduler.record_stage(dense_idx,
                                        io_seconds=res.merged.io.seconds,
                                        flops=flops)
            np.add(req_io, res.req_io_seconds, out=req_io)
            return y[:, None]

        cur = sample_tokens(logits[:, -1], temps, key)
        t0 = time.perf_counter()
        overlapped_total = 0.0
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i]))
            key = jax.random.fold_in(key, step)
            token_t0 = time.perf_counter()
            x = embed_tokens(self.params["embed"], cur[:, None].astype(jnp.int32), cfg)
            self.scheduler.begin_token()
            h, cache_groups = transformer.stack_decode_step_layerwise(
                param_groups, x, jnp.int32(T + step), cache_groups, cfg,
                ffn_override=ffn_override)
            h = apply_norm(self.params["final_norm"], h, cfg)
            logits = unembed(self.params["embed"], h, cfg)
            cur = sample_tokens(logits[:, 0], temps, key)
            cur.block_until_ready()                   # ONE sync per token
            timing = self.scheduler.end_token(
                compute_seconds=time.perf_counter() - token_t0)
            overlapped_total += timing.overlapped_seconds
        t_decode = time.perf_counter() - t0
        return [Result(uid=r.uid, tokens=o[: r.max_new_tokens],
                       prefill_seconds=t_prefill, decode_seconds=t_decode,
                       io_seconds=float(io), overlapped_seconds=overlapped_total)
                for r, o, io in zip(group, outs, req_io)]


def build_offload_runtime(
    model: Model,
    params: Any,
    rng: Optional[np.random.Generator] = None,
    calib_batch: tuple = (8, 64),
    engine_cfg: Optional[EngineConfig] = None,
    device: Optional[UFSDevice] = None,
    use_placement: bool = True,
) -> OffloadedFFNRuntime:
    """Calibrate placements from a short random-token trace and pack the
    model's dense-FFN weights into flash bundles, one engine per dense layer.

    `use_placement=False` keeps the identity layout (the LLMFlash-style
    baseline arm of the benchmarks). Works for any stack period: layers are
    enumerated in the same (group, sublayer) order as `ffn_pre_act` capture.
    """
    from repro.core.coactivation import stats_from_masks
    from repro.core.placement import identity_placement, search_placement
    from repro.core.sparse_ffn import FFNWeights, make_bundles

    cfg = model.cfg
    if cfg.family != "dense" or cfg.is_encdec:
        raise ValueError("offload runtime covers dense decoder-only archs")
    rng = rng or np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, calib_batch), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    P = transformer.stack_period(cfg)
    G = cfg.n_layers // P
    ffns = cfg.ffn_kinds()
    placements, bundles = [], []
    dense_idx = 0
    for g in range(G):
        for j in range(P):
            if ffns[j] != "dense":
                continue
            ffn_p = params["stack"][f"sub_{j}"]["ffn"]
            w = FFNWeights(
                w_up=ffn_p["w_up"][g].T, w_down=ffn_p["w_down"][g],
                w_gate=(ffn_p["w_gate"][g].T if "w_gate" in ffn_p else None))
            bundles.append(np.asarray(make_bundles(w)))
            if use_placement:
                masks = np.asarray(
                    out["ffn_pre_act"][dense_idx] > 0).reshape(-1, cfg.d_ff)
                placements.append(search_placement(
                    stats_from_masks(masks).distance_matrix(), mode="auto"))
            else:
                placements.append(identity_placement(cfg.d_ff))
            dense_idx += 1
    return OffloadedFFNRuntime(cfg, bundles, placements, device=device,
                               engine_cfg=engine_cfg)


def _group_by_len(requests: List[Request]) -> List[List[Request]]:
    by_len: Dict[int, List[Request]] = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    return list(by_len.values())
