"""Serving engine: batched prefill + decode with the RIPPLE offload path.

Two modes:
  * resident  — all weights in device memory; jit'd prefill/decode only.
  * offload   — the paper's scenario: FFN neuron bundles live in (simulated)
    flash; per layer and per token the OffloadEngine predicts/reads/caches the
    activated neurons, and the layer FFN is computed *from the bytes read*.
    I/O latency per token is accounted by the UFS device model and reported
    alongside compute.

The offload path intentionally runs layer-by-layer on host (it models a
phone-style single-device runtime); the distributed pjit path is the dense
one exercised by launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import EngineConfig, OffloadEngine
from repro.core.placement import PlacementResult
from repro.core.predictor import PredictorParams, predict_mask
from repro.core.sparse_ffn import sparse_ffn_from_bundles
from repro.core.storage import UFSDevice
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [T] int32
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]
    prefill_seconds: float
    decode_seconds: float
    io_seconds: float = 0.0


def sample_token(logits: jnp.ndarray, temperature: float, key) -> jnp.ndarray:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ServingEngine:
    """Continuous-batching-lite: fixed decode batch, greedy/temperature sampling."""

    def __init__(self, model: Model, params: Any, max_len: int = 512,
                 swa: bool = False):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.swa = swa
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c))

    def serve(self, requests: List[Request], seed: int = 0) -> List[Result]:
        results = []
        key = jax.random.PRNGKey(seed)
        for group in _group_by_len(requests):
            toks = np.stack([r.prompt for r in group])
            B, T = toks.shape
            cache = self.model.init_cache(B, self.max_len, swa=self.swa)
            t0 = time.perf_counter()
            logits, cache = self.model.prefill(
                self.params, {"tokens": jnp.asarray(toks)}, cache)
            logits.block_until_ready()
            t_prefill = time.perf_counter() - t0
            max_new = max(r.max_new_tokens for r in group)
            outs = [[] for _ in group]
            cur = sample_token(logits[:, -1], group[0].temperature, key)
            t0 = time.perf_counter()
            for step in range(max_new):
                for i in range(B):
                    outs[i].append(int(cur[i]))
                key = jax.random.fold_in(key, step)
                logits, cache = self._decode(
                    self.params, cur[:, None].astype(jnp.int32),
                    jnp.int32(T + step), cache)
                cur = sample_token(logits[:, 0], group[0].temperature, key)
            jax.block_until_ready(cur)
            t_decode = time.perf_counter() - t0
            for r, o in zip(group, outs):
                results.append(Result(uid=r.uid, tokens=o[: r.max_new_tokens],
                                      prefill_seconds=t_prefill,
                                      decode_seconds=t_decode))
        return results


def _group_by_len(requests: List[Request]) -> List[List[Request]]:
    by_len: Dict[int, List[Request]] = {}
    for r in requests:
        by_len.setdefault(len(r.prompt), []).append(r)
    return list(by_len.values())


# ---------------------------------------------------------------------------
# Offloaded serving: the paper's pipeline around a host-side layer loop
# ---------------------------------------------------------------------------

class OffloadedFFNRuntime:
    """Per-layer RIPPLE offload state: engines, predictors, placements."""

    def __init__(
        self,
        cfg: ModelConfig,
        bundles_per_layer: List[np.ndarray],       # [L][n_neurons, bundle_width]
        placements: List[PlacementResult],
        predictors: Optional[List[PredictorParams]] = None,
        device: Optional[UFSDevice] = None,
        engine_cfg: Optional[EngineConfig] = None,
    ) -> None:
        self.cfg = cfg
        self.engines = [
            OffloadEngine(b, placement=pl, device=device, config=engine_cfg)
            for b, pl in zip(bundles_per_layer, placements)
        ]
        self.predictors = predictors
        self.n_mats = 3 if cfg.activation == "silu" else 2

    def ffn_apply(self, layer: int, h: np.ndarray, oracle_mask: Optional[np.ndarray] = None):
        """h: [B, d]. Returns (y [B, d], TokenStats).

        Activated set = predictor(h) if trained, else oracle mask (exact ReLU
        support, what the paper's predictor approximates with ~high recall).
        """
        if oracle_mask is None:
            assert self.predictors is not None, "need predictor or oracle mask"
            oracle_mask = np.asarray(predict_mask(self.predictors[layer], jnp.asarray(h)))
        ids = np.nonzero(np.any(np.atleast_2d(oracle_mask), axis=0))[0]
        data, stats = self.engines[layer].step(ids)
        y = sparse_ffn_from_bundles(
            jnp.asarray(h), jnp.asarray(data), self.cfg.d_model, self.n_mats,
            activation=self.cfg.activation)
        return np.asarray(y), stats

    def io_summary(self) -> dict:
        per_layer = [e.summary() for e in self.engines]
        io_s = sum(s["io_seconds_per_token"] for s in per_layer)
        return {
            "io_seconds_per_token": io_s,
            "mean_run_length": float(np.mean([s["mean_run_length"] for s in per_layer])),
            "effective_bandwidth": float(np.mean([s["effective_bandwidth"] for s in per_layer])),
            "cache_hit_rate": float(np.mean([s["cache_hit_rate"] for s in per_layer])),
            "ops_per_token": sum(s["ops_per_token"] for s in per_layer),
        }
