"""Pallas TPU kernels: sliding-window and PAGED GQA decode attention
(flash-decode style).

`swa_decode_kernel`: one new token attends to a ring-buffer KV cache of width
W under a sliding window — the long_500k dense decode path. Online-softmax
accumulation over KV blocks; grid (B, KV_heads, W/blk) with fp32 (m, l, acc)
scratch in VMEM. Slot validity is positional: slot j holds position pos[j]; it
participates iff pos[j] >= 0 and cur - window < pos[j] <= cur. `cur` arrives
via scalar prefetch.

`paged_decode_kernel`: the paged-KV variant (vLLM-style PagedAttention). K/V
live in a physical page arena [num_pages+1, KV, page_size, hd]; each batch
row's logical pages are resolved through a scalar-prefetched page table
[B, max_pages] whose entries drive the K/V BlockSpec index maps — the page
gather IS the block DMA, the same scalar-prefetch-indexed-BlockSpec pattern as
`sparse_ffn_segments_fused_kernel`'s segment gather. Logical slot p*page_size+o
holds position p*page_size+o; validity is causal (slot <= cur[b]), identical to
`attend_full_cache`'s masking, so unallocated logical pages may point at the
null page (arena row num_pages) and contribute exactly zero. Optional int8
support dequantises per-(page, offset, head) scales in-kernel, post-DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(cur_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, blocks: int, scale: float):
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                    # [G, hd]
    k = k_ref[0, 0]                    # [blk, hd]
    v = v_ref[0, 0]                    # [blk, hd]
    pos = pos_ref[0]                   # [blk] int32
    cur = cur_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale   # [G, blk]
    valid = (pos >= 0) & (pos > cur - window) & (pos <= cur)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]                # [G, 1]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    # exp(NEG_INF - NEG_INF) would be 1 for fully-masked blocks: force 0.
    p = jnp.where(valid[None, :], jnp.exp(s - m_new), 0.0)            # [G, blk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(blk == blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def swa_decode_kernel(
    q: jnp.ndarray,          # [B, KV, G, hd] — grouped query heads
    k: jnp.ndarray,          # [B, KV, W, hd] — ring buffer
    v: jnp.ndarray,          # [B, KV, W, hd]
    pos: jnp.ndarray,        # [B, W] int32 position per slot (-1 empty)
    cur_pos: jnp.ndarray,    # [1] int32 current position (scalar prefetch)
    *,
    window: int,
    block_w: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, KV, G, hd = q.shape
    W = k.shape[2]
    assert W % block_w == 0, "wrapper must pad ring to block multiple"
    blocks = W // block_w
    grid = (B, KV, blocks)
    scale = hd ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, w, cur: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_w, hd), lambda b, h, w, cur: (b, h, w, 0)),
            pl.BlockSpec((1, 1, block_w, hd), lambda b, h, w, cur: (b, h, w, 0)),
            pl.BlockSpec((1, block_w), lambda b, h, w, cur: (b, w)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, w, cur: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),      # running max
            pltpu.VMEM((G, 1), jnp.float32),      # running denom
            pltpu.VMEM((G, hd), jnp.float32),     # output accumulator
        ],
    )
    kern = functools.partial(_kernel, window=window, blocks=blocks, scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(cur_pos, q, k, v, pos)


# -- paged-attention decode ----------------------------------------------------

def _paged_core(q, k, v, scale_row, cur, page, page_size, pages, scale,
                m_ref, l_ref, acc_ref, o_ref):
    """One page's online-softmax step. q: [G, hd]; k/v: [page_size, hd];
    scale_row: [page_size, 1] dequant scales (None for float arenas)."""
    @pl.when(page == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if scale_row is not None:
        k = k.astype(jnp.float32) * scale_row[0]
        v = v.astype(jnp.float32) * scale_row[1]
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32) * scale      # [G, page_size]
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    slot = page * page_size + offs                               # [1, page_size]
    valid = slot <= cur                    # causal; trash past cur masks away
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                    # [G, 1]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    # exp(NEG_INF - NEG_INF) would be 1 for fully-masked pages: force 0.
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)                # [G, page_size]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(page == pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_kernel(
    q: jnp.ndarray,          # [B, KV, G, hd] — grouped query heads, one token
    k: jnp.ndarray,          # [num_pages + 1, KV, page_size, hd] page arena
    v: jnp.ndarray,          # (row num_pages is the null page)
    page_tables: jnp.ndarray,  # [B, max_pages] int32 physical page per logical
    cur_pos: jnp.ndarray,    # [B] int32 current position (scalar prefetch)
    k_scale: jnp.ndarray = None,  # [num_pages + 1, KV, page_size] (int8 arena)
    v_scale: jnp.ndarray = None,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged-attention decode: grid (B, KV, max_pages); the page-table entry
    for (b, p) selects the K/V (and scale) blocks via the scalar-prefetch
    index map, so each grid step DMAs exactly one physical page."""
    B, KV, G, hd = q.shape
    page_size = k.shape[2]
    pages = page_tables.shape[1]
    grid = (B, KV, pages)
    scale = hd ** -0.5
    quant = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, p, pt, cur: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, page_size, hd),
                     lambda b, h, p, pt, cur: (pt[b, p], h, 0, 0)),
        pl.BlockSpec((1, 1, page_size, hd),
                     lambda b, h, p, pt, cur: (pt[b, p], h, 0, 0)),
    ]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, page_size),
                         lambda b, h, p, pt, cur: (pt[b, p], h, 0)),
            pl.BlockSpec((1, 1, page_size),
                         lambda b, h, p, pt, cur: (pt[b, p], h, 0)),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, p, pt, cur: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),      # running max
            pltpu.VMEM((G, 1), jnp.float32),      # running denom
            pltpu.VMEM((G, hd), jnp.float32),     # output accumulator
        ],
    )
    if quant:
        def kern(pt_ref, cur_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref):
            b, page = pl.program_id(0), pl.program_id(2)
            scales = (ks_ref[0, 0][:, None], vs_ref[0, 0][:, None])
            _paged_core(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], scales,
                        cur_ref[b], page, page_size, pages, scale,
                        m_ref, l_ref, acc_ref, o_ref)
        args = (page_tables, cur_pos, q, k, v, k_scale, v_scale)
    else:
        def kern(pt_ref, cur_ref, q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref):
            b, page = pl.program_id(0), pl.program_id(2)
            _paged_core(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], None,
                        cur_ref[b], page, page_size, pages, scale,
                        m_ref, l_ref, acc_ref, o_ref)
        args = (page_tables, cur_pos, q, k, v)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(*args)
