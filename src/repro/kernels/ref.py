"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _act(pre, name: str):
    if name == "relu":
        return jnp.maximum(pre, 0.0)
    if name == "relu2":
        return jnp.square(jnp.maximum(pre, 0.0))
    if name == "gelu":
        return jax.nn.gelu(pre)
    if name == "silu":
        return jax.nn.silu(pre)
    raise ValueError(name)


def sparse_ffn_segments_ref(
    x: jnp.ndarray,            # [B, D]
    w_up: jnp.ndarray,         # [N, D]
    w_down: jnp.ndarray,       # [N, D]
    seg_ids: jnp.ndarray,      # [S] int32 (may repeat; repeats double-count by design)
    w_gate: Optional[jnp.ndarray] = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
) -> jnp.ndarray:
    """Sum over segments of act(x up_s^T)[* gate] down_s, in fp32."""
    xf = x.astype(jnp.float32)
    out = jnp.zeros((x.shape[0], x.shape[1]), jnp.float32)
    for s in list(seg_ids):
        lo = int(s) * seg_size
        up = w_up[lo : lo + seg_size].astype(jnp.float32)
        down = w_down[lo : lo + seg_size].astype(jnp.float32)
        pre = xf @ up.T
        act = _act(pre, activation)
        if w_gate is not None:
            act = act * (xf @ w_gate[lo : lo + seg_size].astype(jnp.float32).T)
        out = out + act @ down
    return out


def sparse_ffn_segments_fused_ref(
    x: jnp.ndarray,            # [B, D]
    w_up: jnp.ndarray,         # [N, D] raw storage dtype (int8 or float)
    w_down: jnp.ndarray,       # [N, D]
    seg_ids: jnp.ndarray,      # [S] int32 (-1 = padding, contributes 0)
    scale_tiles: jnp.ndarray,  # [S, seg] f32 dequant-scale x activated-mask
    w_gate: Optional[jnp.ndarray] = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
) -> jnp.ndarray:
    """Per-segment python loop applying the scale multiplier pre-matmul."""
    xf = x.astype(jnp.float32)
    out = jnp.zeros((x.shape[0], x.shape[1]), jnp.float32)
    for i, s in enumerate(list(seg_ids)):
        if int(s) < 0:
            continue
        lo = int(s) * seg_size
        sv = scale_tiles[i].astype(jnp.float32)[:, None]       # [seg, 1]
        up = w_up[lo : lo + seg_size].astype(jnp.float32) * sv
        down = w_down[lo : lo + seg_size].astype(jnp.float32) * sv
        pre = xf @ up.T
        act = _act(pre, activation)
        if w_gate is not None:
            gate_w = w_gate[lo : lo + seg_size].astype(jnp.float32) * sv
            act = act * (xf @ gate_w.T)
        out = out + act @ down
    return out


def coact_accumulate_ref(masks: jnp.ndarray) -> jnp.ndarray:
    m = masks.astype(jnp.float32)
    return m.T @ m


def swa_decode_ref(
    q: jnp.ndarray,            # [B, KV, G, hd]
    k: jnp.ndarray,            # [B, KV, W, hd]
    v: jnp.ndarray,            # [B, KV, W, hd]
    pos: jnp.ndarray,          # [B, W]
    cur_pos: int,
    *,
    window: int,
) -> jnp.ndarray:
    hd = q.shape[-1]
    s = jnp.einsum("bkgh,bkwh->bkgw", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    valid = (pos >= 0) & (pos > cur_pos - window) & (pos <= cur_pos)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform softmax; zero them like the kernel does
    any_valid = jnp.any(valid, axis=-1)[:, None, None, None]
    out = jnp.einsum("bkgw,bkwh->bkgh", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)
