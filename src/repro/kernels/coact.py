"""Pallas TPU kernel: co-activation adjacency accumulation A += M^T M.

The offline pattern-extraction hot spot (paper §4.1 Eq. 2): M is a [T, N]
activation-mask block; the co-activation count matrix needs the N x N
outer-product sum. Tiled so each grid step does a [tt, tn]^T @ [tt, tm]
MXU matmul with an fp32 [tn, tm] accumulator tile resident in VMEM.

Grid order (i, j, t): t innermost so the output tile (i, j) is revisited
across t steps and accumulated in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(m1_ref, m2_ref, o_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(m1_ref[...].T, m2_ref[...],
                          preferred_element_type=jnp.float32)


def coact_accumulate_kernel(
    masks: jnp.ndarray,      # [T, N] float (0/1)
    *,
    tile_n: int = 256,
    tile_t: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    T, N = masks.shape
    assert T % tile_t == 0 and N % tile_n == 0, "wrapper must pad to tiles"
    grid = (N // tile_n, N // tile_n, T // tile_t)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, tile_n), lambda i, j, t: (t, i)),
            pl.BlockSpec((tile_t, tile_n), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        interpret=interpret,
    )(masks, masks)
