"""Public jit'd wrappers around the Pallas kernels.

Handle padding/alignment (batch to 8, neuron axis to segment multiples, ring
width to block multiples), append the zero pad segment, and select interpret
mode automatically on CPU (the kernels TARGET TPU; interpret=True executes the
kernel body faithfully on CPU for validation).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.coact import coact_accumulate_kernel
from repro.kernels.sparse_ffn import sparse_ffn_segments_kernel
from repro.kernels.swa_decode import swa_decode_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(a: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@partial(jax.jit, static_argnames=("seg_size", "activation", "interpret"))
def sparse_ffn_segments(
    x: jnp.ndarray,              # [B, D]
    w_up: jnp.ndarray,           # [N, D]
    w_down: jnp.ndarray,         # [N, D]
    seg_ids: jnp.ndarray,        # [S] int32 segment block-indices (pad with -1)
    w_gate: Optional[jnp.ndarray] = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Segment-gather FFN. seg_ids entries of -1 are padding (contribute 0)."""
    interpret = _on_cpu() if interpret is None else interpret
    B, D = x.shape
    N = w_up.shape[0]
    assert N % seg_size == 0, "neuron axis must be a segment multiple"
    pad_block = N // seg_size            # index of the appended zero segment
    zpad = jnp.zeros((seg_size, D), w_up.dtype)
    w_up_p = jnp.concatenate([w_up, zpad], axis=0)
    w_down_p = jnp.concatenate([w_down, zpad], axis=0)
    w_gate_p = None if w_gate is None else jnp.concatenate([w_gate, zpad], axis=0)
    ids = jnp.where(seg_ids < 0, pad_block, seg_ids).astype(jnp.int32)
    x_p = _pad_axis(x, 0, 8)
    out = sparse_ffn_segments_kernel(
        x_p, w_up_p, w_down_p, ids, w_gate_p,
        seg_size=seg_size, activation=activation, interpret=interpret)
    return out[:B]


@partial(jax.jit, static_argnames=("tile_n", "tile_t", "interpret"))
def coact_accumulate(
    masks: jnp.ndarray,          # [T, N] bool/float
    *,
    tile_n: int = 256,
    tile_t: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """A = M^T M co-activation counts, fp32 [N, N] (zero padding is exact)."""
    interpret = _on_cpu() if interpret is None else interpret
    T, N = masks.shape
    m = masks.astype(jnp.float32)
    m = _pad_axis(_pad_axis(m, 0, tile_t), 1, tile_n)
    out = coact_accumulate_kernel(m, tile_n=tile_n, tile_t=tile_t, interpret=interpret)
    return out[:N, :N]


@partial(jax.jit, static_argnames=("window", "block_w", "interpret"))
def swa_decode_attention(
    q: jnp.ndarray,              # [B, H, hd] query for ONE new token
    k_cache: jnp.ndarray,        # [B, W, KV, hd] ring buffer
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,            # [B, W] slot positions (-1 empty)
    cur_pos: jnp.ndarray,        # scalar int32
    *,
    window: int,
    block_w: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Returns [B, H, hd] attention output."""
    interpret = _on_cpu() if interpret is None else interpret
    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    kt = jnp.swapaxes(k_cache, 1, 2)     # [B, KV, W, hd]
    vt = jnp.swapaxes(v_cache, 1, 2)
    block_w = min(block_w, W)
    padW = (-W) % block_w
    if padW:
        kt = _pad_axis(kt, 2, block_w)
        vt = _pad_axis(vt, 2, block_w)
        pos = jnp.pad(pos, ((0, 0), (0, padW)), constant_values=-1)
    out = swa_decode_kernel(
        qg, kt, vt, pos.astype(jnp.int32),
        jnp.reshape(cur_pos.astype(jnp.int32), (1,)),
        window=window, block_w=block_w, interpret=interpret)
    return out.reshape(B, H, hd)
