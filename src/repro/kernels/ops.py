"""Public jit'd wrappers around the Pallas kernels.

Handle padding/alignment (batch to 8, neuron axis to segment multiples, ring
width to block multiples), append the zero pad segment, and select interpret
mode automatically on CPU (the kernels TARGET TPU; interpret=True executes the
kernel body faithfully on CPU for validation).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.coact import coact_accumulate_kernel
from repro.kernels.sparse_ffn import (_apply_act, sparse_ffn_segments_fused_kernel,
                                      sparse_ffn_segments_kernel)
from repro.kernels.swa_decode import paged_decode_kernel, swa_decode_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_axis(a: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@partial(jax.jit, static_argnames=("seg_size", "activation", "interpret"))
def sparse_ffn_segments(
    x: jnp.ndarray,              # [B, D]
    w_up: jnp.ndarray,           # [N, D]
    w_down: jnp.ndarray,         # [N, D]
    seg_ids: jnp.ndarray,        # [S] int32 segment block-indices (pad with -1)
    w_gate: Optional[jnp.ndarray] = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Segment-gather FFN. seg_ids entries of -1 are padding (contribute 0)."""
    interpret = _on_cpu() if interpret is None else interpret
    B, D = x.shape
    N = w_up.shape[0]
    assert N % seg_size == 0, "neuron axis must be a segment multiple"
    pad_block = N // seg_size            # index of the appended zero segment
    zpad = jnp.zeros((seg_size, D), w_up.dtype)
    w_up_p = jnp.concatenate([w_up, zpad], axis=0)
    w_down_p = jnp.concatenate([w_down, zpad], axis=0)
    w_gate_p = None if w_gate is None else jnp.concatenate([w_gate, zpad], axis=0)
    ids = jnp.where(seg_ids < 0, pad_block, seg_ids).astype(jnp.int32)
    x_p = _pad_axis(x, 0, 8)
    out = sparse_ffn_segments_kernel(
        x_p, w_up_p, w_down_p, ids, w_gate_p,
        seg_size=seg_size, activation=activation, interpret=interpret)
    return out[:B]


@partial(jax.jit, static_argnames=("seg_size", "activation"))
def _sparse_ffn_segments_fused_xla(x, w_up, w_down, seg_ids, scale_tiles, w_gate,
                                   *, seg_size: int, activation: str) -> jnp.ndarray:
    """Pure-XLA twin of the fused kernel for the CPU serving path.

    Same math in the same order as the Pallas kernel — gather raw [seg, D]
    tiles, upcast, multiply by the per-neuron scale column pre-matmul — so
    outputs are bitwise comparable with the interpreted kernel. The Pallas
    interpreter executes one Python iteration per grid step, which is far too
    slow for the decode hot loop; XLA fuses the whole thing instead.
    """
    S = seg_ids.shape[0]
    D = x.shape[1]
    tiles = jnp.where(seg_ids < 0, 0, seg_ids).astype(jnp.int32)
    sv = jnp.where((seg_ids < 0)[:, None], 0.0,
                   scale_tiles.astype(jnp.float32)).reshape(S * seg_size, 1)

    def eff(w):
        t = w.reshape(-1, seg_size, D)[tiles].reshape(S * seg_size, D)
        return t.astype(jnp.float32) * sv

    pre = jnp.dot(x.astype(jnp.float32), eff(w_up).T,
                  preferred_element_type=jnp.float32)
    act = _apply_act(pre, activation)
    if w_gate is not None:
        act = act * jnp.dot(x.astype(jnp.float32), eff(w_gate).T,
                            preferred_element_type=jnp.float32)
    return jnp.dot(act, eff(w_down), preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("seg_size", "activation", "interpret"))
def _sparse_ffn_segments_fused_pallas(x, w_up, w_down, seg_ids, scale_tiles, w_gate,
                                      *, seg_size: int, activation: str,
                                      interpret: bool) -> jnp.ndarray:
    B, D = x.shape
    ids = jnp.where(seg_ids < 0, 0, seg_ids).astype(jnp.int32)
    sv = jnp.where((seg_ids < 0)[:, None], 0.0, scale_tiles.astype(jnp.float32))
    x_p = _pad_axis(x.astype(jnp.float32), 0, 8)
    out = sparse_ffn_segments_fused_kernel(
        x_p, w_up, w_down, ids, sv, w_gate,
        seg_size=seg_size, activation=activation, interpret=interpret)
    return out[:B]


def sparse_ffn_segments_fused(
    x: jnp.ndarray,              # [B, D]
    w_up: jnp.ndarray,           # [N, D] raw storage dtype (int8 stays int8)
    w_down: jnp.ndarray,         # [N, D]
    seg_ids: jnp.ndarray,        # [S] int32 segment block-indices (pad with -1)
    scale_tiles: jnp.ndarray,    # [S, seg] f32 dequant-scale x activated-mask
    w_gate: Optional[jnp.ndarray] = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused dequant + mask + segment-gather FFN.

    `scale_tiles[s, j]` multiplies the weight rows of physical neuron
    `seg_ids[s] * seg_size + j` before both matmuls: the int8 dequant scale
    (1.0 for float payloads) for neurons in the activated union, 0.0 for
    covered-but-not-activated neurons — exact for relu/relu2/gelu/silu since
    act(0) == 0. seg_ids entries of -1 are padding (their scale row is forced
    to 0, so they contribute exactly 0 regardless of the clamped gather).

    interpret=None picks the fused-XLA twin on CPU (fast) and the Pallas
    kernel elsewhere; interpret=True forces the Pallas interpreter (tests).
    """
    assert w_up.shape[0] % seg_size == 0, "neuron axis must be a segment multiple"
    if interpret is None:
        if _on_cpu():
            return _sparse_ffn_segments_fused_xla(
                x, w_up, w_down, seg_ids, scale_tiles, w_gate,
                seg_size=seg_size, activation=activation)
        interpret = False
    return _sparse_ffn_segments_fused_pallas(
        x, w_up, w_down, seg_ids, scale_tiles, w_gate,
        seg_size=seg_size, activation=activation, interpret=interpret)


@partial(jax.jit, static_argnames=("tile_n", "tile_t", "interpret"))
def coact_accumulate(
    masks: jnp.ndarray,          # [T, N] bool/float
    *,
    tile_n: int = 256,
    tile_t: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """A = M^T M co-activation counts, fp32 [N, N] (zero padding is exact)."""
    interpret = _on_cpu() if interpret is None else interpret
    T, N = masks.shape
    m = masks.astype(jnp.float32)
    m = _pad_axis(_pad_axis(m, 0, tile_t), 1, tile_n)
    out = coact_accumulate_kernel(m, tile_n=tile_n, tile_t=tile_t, interpret=interpret)
    return out[:N, :N]


@partial(jax.jit, static_argnames=("window", "block_w", "interpret"))
def swa_decode_attention(
    q: jnp.ndarray,              # [B, H, hd] query for ONE new token
    k_cache: jnp.ndarray,        # [B, W, KV, hd] ring buffer
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,            # [B, W] slot positions (-1 empty)
    cur_pos: jnp.ndarray,        # scalar int32
    *,
    window: int,
    block_w: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Returns [B, H, hd] attention output."""
    interpret = _on_cpu() if interpret is None else interpret
    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    kt = jnp.swapaxes(k_cache, 1, 2)     # [B, KV, W, hd]
    vt = jnp.swapaxes(v_cache, 1, 2)
    block_w = min(block_w, W)
    padW = (-W) % block_w
    if padW:
        kt = _pad_axis(kt, 2, block_w)
        vt = _pad_axis(vt, 2, block_w)
        pos = jnp.pad(pos, ((0, 0), (0, padW)), constant_values=-1)
    out = swa_decode_kernel(
        qg, kt, vt, pos.astype(jnp.int32),
        jnp.reshape(cur_pos.astype(jnp.int32), (1,)),
        window=window, block_w=block_w, interpret=interpret)
    return out.reshape(B, H, hd)


@jax.jit
def _paged_decode_xla(q, k_pages, v_pages, page_tables, cur_pos,
                      k_scale, v_scale):
    """Pure-XLA gather twin of `paged_decode_kernel` for the CPU serving path.

    Gathers each row's pages into the contiguous [B, S, KV, hd] layout and
    runs the exact `attend_full_cache` math (`gqa_attend` with positional
    causal masking) — same masking, same contraction order — so its output is
    bitwise identical to contiguous-cache decode attention; the Pallas
    kernel's online-softmax accumulation is equivalent to tolerance and is
    exercised through the interpret-mode oracle in tests."""
    from repro.models.layers import gqa_attend
    B, H, hd = q.shape
    P = k_pages.shape[1]
    S = page_tables.shape[1] * P
    gather = lambda a: a[page_tables].reshape((B, S) + a.shape[2:])
    k, v = gather(k_pages), gather(v_pages)
    if k_scale is not None:
        k = k.astype(jnp.float32) * gather(k_scale)[..., None].astype(jnp.float32)
        v = v.astype(jnp.float32) * gather(v_scale)[..., None].astype(jnp.float32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    out = gqa_attend(q[:, None].astype(k.dtype), k, v,
                     cur_pos.astype(jnp.int32)[:, None], k_pos, causal=True)
    return out[:, 0].reshape(B, H, hd).astype(jnp.float32)


@partial(jax.jit, static_argnames=("interpret",))
def _paged_decode_pallas(q, k_pages, v_pages, page_tables, cur_pos,
                         k_scale, v_scale, *, interpret: bool):
    B, H, hd = q.shape
    KV = k_pages.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    kt = jnp.swapaxes(k_pages, 1, 2)       # [num_pages+1, KV, page_size, hd]
    vt = jnp.swapaxes(v_pages, 1, 2)
    ks = None if k_scale is None else jnp.swapaxes(k_scale, 1, 2).astype(jnp.float32)
    vs = None if v_scale is None else jnp.swapaxes(v_scale, 1, 2).astype(jnp.float32)
    out = paged_decode_kernel(
        qg.astype(jnp.float32) if k_scale is not None else qg,
        kt, vt, page_tables.astype(jnp.int32), cur_pos.astype(jnp.int32),
        ks, vs, interpret=interpret)
    return out.reshape(B, H, hd)


def paged_decode_attention(
    q: jnp.ndarray,              # [B, H, hd] query for ONE new token
    k_pages: jnp.ndarray,        # [num_pages + 1, page_size, KV, hd] arena
    v_pages: jnp.ndarray,        #   (the trailing page is the null page)
    page_tables: jnp.ndarray,    # [B, max_pages] int32
    cur_pos: jnp.ndarray,        # [B] int32 current (query) position per row
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages + 1, page_size, KV]
    v_scale: Optional[jnp.ndarray] = None,  # (int8 arenas only)
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Paged-attention decode over a page arena; returns [B, H, hd] fp32.

    interpret=None routes CPU to the fused-XLA gather twin (bitwise identical
    to `attend_full_cache` on the equivalent contiguous layout; the Pallas
    interpreter is far too slow for the decode hot loop) and elsewhere to the
    Pallas kernel; interpret=True forces the in-kernel oracle (tests). On a
    real TPU the page_size should be a multiple of the dtype's sublane tile
    (8 for fp32, 32 for int8) so each page is a legal block."""
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    if interpret is None:
        if _on_cpu():
            return _paged_decode_xla(q, k_pages, v_pages, page_tables,
                                     cur_pos, k_scale, v_scale)
        interpret = False
    return _paged_decode_pallas(q, k_pages, v_pages, page_tables, cur_pos,
                                k_scale, v_scale, interpret=interpret)
