"""Pallas TPU kernels: segment-gather sparse FFN, unfused and fused variants.

The TPU-native expression of RIPPLE's contiguous neuron links: the activated
neuron set is delivered as *segment ids* (each segment = `seg` consecutive
neurons in the permuted physical layout). A scalar-prefetch id array drives the
BlockSpec index_map, so each grid step DMAs one contiguous [seg, d_model] tile
of each weight matrix HBM->VMEM and feeds 128-aligned tiles to the MXU:

    y = sum_s act(x @ W_up[seg_s]^T) [* (x @ W_gate[seg_s]^T)] @ W_down[seg_s]

Contiguity => one DMA descriptor per segment per matrix — the same IOPS
argument as the paper's flash reads, at the HBM->VMEM tier.

Two kernel variants:

  * `sparse_ffn_segments_kernel` — the original float-tile kernel. Padding
    convention: the wrapper (ops.py) appends one all-zero segment at block
    index N/seg; padded entries of `seg_ids` point there and contribute 0.
    Exact only when act(pre <= 0) == 0 (relu/relu2): covered-but-inactive
    neurons inside a segment are computed unmasked.

  * `sparse_ffn_segments_fused_kernel` — int8-dequant + neuron-mask + FFN in
    one pass. Weight tiles may be int8 (the NeuronPack storage dtype) or any
    float dtype; a second gathered input `scale_tiles` [S, seg] float32
    carries a per-neuron multiplier = dequant scale x activated-mask. Each
    grid step upcasts its raw [seg, D] tiles in VMEM and multiplies by the
    scale column BEFORE the MXU dots:

        W_eff[seg_s] = raw_tile.astype(f32) * scale_tiles[s][:, None]

    so (a) int8 packs never materialize float32 rows outside VMEM — per-
    neuron symmetric quantization (format.py) makes q * scale the exact
    `dequantize_int8` value, and (b) a zero multiplier exactly zeroes a
    neuron's contribution for EVERY activation (act(0) == 0 for relu, relu2,
    gelu and silu; gated models also zero the gate), which is what makes the
    segment path exact for non-ReLU models: covered-but-not-activated
    neurons are masked in-kernel. Padded `seg_ids` entries are clamped to
    block 0 with an all-zero scale row — no appended zero segment needed.

int8 tile convention: tiles are the raw [seg, d_model] slices of the pack's
physical-order payload; `scale_tiles[s, j]` is the symmetric per-neuron scale
of physical neuron `seg_ids[s] * seg + j` (1.0 for float payloads), times 0/1
activated-union membership.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_act(pre, name: str):
    if name == "relu":
        return jnp.maximum(pre, 0.0)
    if name == "relu2":
        return jnp.square(jnp.maximum(pre, 0.0))
    if name == "gelu":
        return jax.nn.gelu(pre)
    if name == "silu":
        return jax.nn.silu(pre)
    raise ValueError(name)


def _kernel(ids_ref, x_ref, up_ref, down_ref, o_ref, *, activation: str):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pre = jnp.dot(x_ref[...], up_ref[...].T,
                  preferred_element_type=jnp.float32)          # [B, seg]
    act = _apply_act(pre, activation)
    o_ref[...] += jnp.dot(act.astype(down_ref.dtype), down_ref[...],
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel_gated(ids_ref, x_ref, up_ref, gate_ref, down_ref, o_ref, *, activation: str):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pre = jnp.dot(x_ref[...], up_ref[...].T, preferred_element_type=jnp.float32)
    gate = jnp.dot(x_ref[...], gate_ref[...].T, preferred_element_type=jnp.float32)
    act = _apply_act(pre, activation) * gate
    o_ref[...] += jnp.dot(act.astype(down_ref.dtype), down_ref[...],
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel_fused(ids_ref, x_ref, scale_ref, up_ref, down_ref, o_ref, *, activation: str):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sv = scale_ref[...].astype(jnp.float32).T                   # [seg, 1]
    up = up_ref[...].astype(jnp.float32) * sv                   # dequant+mask in VMEM
    pre = jnp.dot(x_ref[...], up.T, preferred_element_type=jnp.float32)
    act = _apply_act(pre, activation)
    down = down_ref[...].astype(jnp.float32) * sv
    o_ref[...] += jnp.dot(act, down,
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _kernel_fused_gated(ids_ref, x_ref, scale_ref, up_ref, gate_ref, down_ref, o_ref,
                        *, activation: str):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sv = scale_ref[...].astype(jnp.float32).T
    up = up_ref[...].astype(jnp.float32) * sv
    gate_w = gate_ref[...].astype(jnp.float32) * sv
    pre = jnp.dot(x_ref[...], up.T, preferred_element_type=jnp.float32)
    gate = jnp.dot(x_ref[...], gate_w.T, preferred_element_type=jnp.float32)
    act = _apply_act(pre, activation) * gate
    down = down_ref[...].astype(jnp.float32) * sv
    o_ref[...] += jnp.dot(act, down,
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def sparse_ffn_segments_fused_kernel(
    x: jnp.ndarray,            # [B, D] float
    w_up: jnp.ndarray,         # [N, D] raw storage dtype (int8 or float)
    w_down: jnp.ndarray,       # [N, D]
    seg_ids: jnp.ndarray,      # [S] int32 block indices, pads pre-clamped to 0
    scale_tiles: jnp.ndarray,  # [S, seg] f32 per-neuron dequant-scale x mask
    w_gate: jnp.ndarray | None = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
    interpret: bool = False,
) -> jnp.ndarray:
    B, D = x.shape
    S = seg_ids.shape[0]
    wspec = pl.BlockSpec((seg_size, D), lambda s, ids: (ids[s], 0))
    sspec = pl.BlockSpec((1, seg_size), lambda s, ids: (s, 0))
    in_specs = [
        pl.BlockSpec((B, D), lambda s, ids: (0, 0)),   # x resident in VMEM
        sspec,                                         # per-neuron multiplier
        wspec,                                         # up
    ]
    if w_gate is not None:
        in_specs.append(wspec)                         # gate
    in_specs.append(wspec)                             # down
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, D), lambda s, ids: (0, 0)),
    )
    kern = (functools.partial(_kernel_fused_gated, activation=activation)
            if w_gate is not None
            else functools.partial(_kernel_fused, activation=activation))
    args = ((seg_ids, x, scale_tiles, w_up)
            + ((w_gate,) if w_gate is not None else ()) + (w_down,))
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(*args)


def sparse_ffn_segments_kernel(
    x: jnp.ndarray,          # [B, D]
    w_up: jnp.ndarray,       # [N + seg, D]  (zero pad segment appended)
    w_down: jnp.ndarray,     # [N + seg, D]
    seg_ids: jnp.ndarray,    # [S] int32 block indices into the segment axis
    w_gate: jnp.ndarray | None = None,
    *,
    seg_size: int = 128,
    activation: str = "relu",
    interpret: bool = False,
) -> jnp.ndarray:
    B, D = x.shape
    S = seg_ids.shape[0]
    wspec = pl.BlockSpec((seg_size, D), lambda s, ids: (ids[s], 0))
    in_specs = [
        pl.BlockSpec((B, D), lambda s, ids: (0, 0)),   # x resident in VMEM
        wspec,                                         # up
    ]
    if w_gate is not None:
        in_specs.append(wspec)                         # gate
    in_specs.append(wspec)                             # down
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, D), lambda s, ids: (0, 0)),
    )
    kern = (functools.partial(_kernel_gated, activation=activation) if w_gate is not None
            else functools.partial(_kernel, activation=activation))
    args = (seg_ids, x, w_up) + ((w_gate,) if w_gate is not None else ()) + (w_down,)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(*args)
