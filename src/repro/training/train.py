"""Training step: loss -> grads -> AdamW, with optional gradient accumulation.

`make_train_step(model, opt_cfg, microbatches)` returns a pure function
(train_state, batch) -> (train_state, metrics) suitable for jax.jit/pjit.
Gradient accumulation scans over microbatch slices of the global batch so the
peak activation memory is that of one microbatch (needed for the biggest
assigned archs at train_4k).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(model: Model, key: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=init_adamw(params, opt_cfg))


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int) -> Dict[str, jnp.ndarray]:
    def split(a):
        B = a.shape[0]
        assert B % n == 0, f"batch {B} not divisible by microbatches {n}"
        return a.reshape((n, B // n) + a.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_train_step(model: Model, opt_cfg: AdamWConfig, microbatches: int = 1):
    loss_fn = model.loss_fn

    def grads_of(params, mb):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        return loss, aux, grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        if microbatches == 1:
            loss, aux, grads = grads_of(state.params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def acc_fn(carry, mb):
                loss_acc, grads_acc = carry
                loss, aux, grads = grads_of(state.params, mb)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), aux

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss_sum, grads), aux = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zero_grads), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            aux = jax.tree_util.tree_map(lambda a: a.mean(), aux)
        new_params, new_opt, opt_metrics = adamw_update(grads, state.opt,
                                                        state.params, opt_cfg)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def train_loop(model: Model, data_iter, steps: int, opt_cfg: AdamWConfig,
               seed: int = 0, microbatches: int = 1, log_every: int = 10,
               callback=None):
    """Single-host training loop (examples/ and integration tests)."""
    state = init_train_state(model, jax.random.PRNGKey(seed), opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches))
    history = []
    for step in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            if callback:
                callback(step, m)
    return state, history
