"""Checkpointing: flat .npz tensors + JSON metadata, sharding-aware.

Arrays are flattened by pytree path ("stack/sub_0/mixer/wq"), gathered to host
if sharded, and written atomically. Restore rebuilds the pytree onto the
current device layout (caller re-applies shardings with device_put).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path: str, state: Any, metadata: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(state)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    if metadata is not None:
        with open(path + ".json", "w") as f:
            json.dump(metadata, f, indent=2)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (a pytree of arrays/structs)."""
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathkeys, leaf in flat_like:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in pathkeys)
        if key not in data:
            raise KeyError(f"checkpoint missing tensor '{key}'")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for '{key}': ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    meta = {}
    meta_path = path + ".json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves), meta
