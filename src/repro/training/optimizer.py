"""Optimizers implemented from scratch in JAX: AdamW + cosine LR schedule.

No optax in this environment — the optimizer is a pure pytree transformation,
which also makes the ZeRO-1 sharding of moments trivial (moments shard exactly
like their parameters; see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    mu: Any                # first moment, like params
    nu: Any                # second moment, like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"   # bf16 halves optimizer HBM for the big archs


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init_adamw(params: Any, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _is_matrix(path: Tuple) -> bool:
    """Weight decay applies to matrices, not norms/biases (leaf ndim >= 2)."""
    return True   # decided per-leaf by ndim below


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, mu, nu) for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
