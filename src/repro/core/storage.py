"""Simulated UFS flash device + placement-aware neuron store.

The paper's runtime reads neuron bundles from UFS flash. This container has no
UFS device, so I/O cost comes from a calibrated device model implementing the
paper's Figure-4 law: effective bandwidth grows ~linearly with continuous I/O
size until the IOPS x io_size product reaches the link bandwidth (crossover at
~24 KB for UFS 4.0), then flattens. The additive form

    T(batch) = n_ops / IOPS_max + total_bytes / B_max        (+ fixed base)

reproduces exactly that curve and both asymptotes. The *algorithms* (placement,
collapse, caching) are the paper's, bit-for-bit; only the device is a model.

`NeuronStore` owns the physical layout of one FFN block's neuron bundles and
serves logical-id reads as contiguous extent reads, with optional access
collapse. Actual bundle payloads are backed by a numpy array so the serving
path computes with the very bytes it "read".
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collapse import (AdaptiveThreshold, BottleneckDetector, Extent,
                                 collapse_extents, run_bounds_from_sorted,
                                 runs_from_positions)
from repro.core.placement import PlacementResult, identity_placement


# -- device models ----------------------------------------------------------

UFS40 = dict(iops_max=150_000.0, bandwidth_max=3.6e9, base_latency=40e-6)   # OnePlus 12 / Ace 3
UFS31 = dict(iops_max=90_000.0, bandwidth_max=1.9e9, base_latency=60e-6)    # OnePlus Ace 2


@dataclasses.dataclass
class UFSDevice:
    """Additive IOPS + bandwidth latency model (paper Fig. 4)."""

    iops_max: float = UFS40["iops_max"]
    bandwidth_max: float = UFS40["bandwidth_max"]
    base_latency: float = UFS40["base_latency"]

    def read_time(self, n_ops: int, total_bytes: int) -> float:
        if n_ops == 0:
            return 0.0
        return self.base_latency + n_ops / self.iops_max + total_bytes / self.bandwidth_max

    def crossover_bytes(self) -> float:
        """Continuous I/O size where IOPS cost == byte cost (~24 KB for UFS4.0)."""
        return self.bandwidth_max / self.iops_max

    def bandwidth_at_io_size(self, io_size_bytes: float, queue_depth: int = 32) -> float:
        """Achieved bandwidth when streaming reads of a fixed size (Fig. 4)."""
        t = self.read_time(queue_depth, int(io_size_bytes * queue_depth))
        return queue_depth * io_size_bytes / t


@dataclasses.dataclass
class IOStats:
    n_ops: int = 0
    bytes_read: int = 0
    bytes_useful: int = 0
    seconds: float = 0.0
    n_requests: int = 0
    # Measured (wall-clock) accounting for stores that perform REAL reads
    # (`repro.store.FileNeuronStore`): what the filesystem actually did,
    # recorded alongside — never instead of — the calibrated UFS model above.
    # In-memory stores leave all three at zero.
    measured_ops: int = 0
    measured_bytes: int = 0
    measured_seconds: float = 0.0
    # Fault-tolerance accounting (repro.store.faults): `retries` counts
    # re-issued extent reads (transient-error retry or checksum-triggered
    # re-read), `corrupt_extents` counts CRC mismatches detected by the
    # opt-in verification mode, and `degraded_steps` marks engine steps the
    # prefetch pipeline served through the synchronous fallback after a
    # worker failure. All three stay zero on the clean path — the CI chaos
    # gate asserts exactly that.
    retries: int = 0
    corrupt_extents: int = 0
    degraded_steps: int = 0
    # pre-collapse run lengths of the requested neurons in flash order — a
    # by-product of read planning (the positions are already sorted there),
    # recorded so callers don't re-derive runs from scratch. Per-read only:
    # `add` resets it to None (see below).
    run_lengths: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    def add(self, other: "IOStats") -> None:
        """Aggregate counters. `run_lengths` is a per-read by-product, not an
        aggregate: merging two reads' runs element-wise is meaningless, and
        silently keeping `self`'s array would hand callers a stale view of
        only the FIRST read. The contract is therefore that an aggregated
        IOStats never carries run lengths — `add` explicitly clears them;
        callers that want runs across reads concatenate them per read (as the
        engine's split-phase step does)."""
        self.n_ops += other.n_ops
        self.bytes_read += other.bytes_read
        self.bytes_useful += other.bytes_useful
        self.seconds += other.seconds
        self.n_requests += other.n_requests
        self.measured_ops += other.measured_ops
        self.measured_bytes += other.measured_bytes
        self.measured_seconds += other.measured_seconds
        self.retries += other.retries
        self.corrupt_extents += other.corrupt_extents
        self.degraded_steps += other.degraded_steps
        self.run_lengths = None

    @property
    def effective_bandwidth(self) -> float:
        """Paper's metric: *useful* (activated) bytes per second."""
        return self.bytes_useful / self.seconds if self.seconds > 0 else 0.0

    @property
    def raw_bandwidth(self) -> float:
        return self.bytes_read / self.seconds if self.seconds > 0 else 0.0

    @property
    def iops(self) -> float:
        return self.n_ops / self.seconds if self.seconds > 0 else 0.0

    @property
    def measured_bandwidth(self) -> float:
        """Real bytes actually read per real second — only meaningful for
        file-backed stores; 0.0 on the pure device model."""
        return (self.measured_bytes / self.measured_seconds
                if self.measured_seconds > 0 else 0.0)


class NeuronStore:
    """Flash-resident neuron bundles for one FFN block under a physical layout.

    data: [n_neurons, bundle_width] — bundle i holds the gate/up rows + down
    column for neuron i, flattened. Physical layout is data[placement], i.e.
    physical slot p stores logical neuron placement[p].
    """

    # In-memory stores hold the payload at its serving dtype; quantized
    # subclasses (FileNeuronStore over an int8 pack) override these.
    quantized: bool = False

    def __init__(
        self,
        data: np.ndarray,
        placement: Optional[PlacementResult] = None,
        device: Optional[UFSDevice] = None,
        reads_per_bundle: int = 1,
        bundle_bytes: Optional[int] = None,
    ) -> None:
        self.n_neurons, self.bundle_width = data.shape
        self.placement = placement or identity_placement(self.n_neurons)
        self.device = device or UFSDevice()
        # llama.cpp stores each weight matrix separately -> an activated neuron
        # costs `reads_per_bundle` scattered ops (2 for OPT, 3 for Llama).
        # Bundled layouts (LLMFlash, RIPPLE) use 1.
        self.reads_per_bundle = reads_per_bundle
        # bundle_bytes may exceed the backing payload width (accounting-only
        # runs with huge bundles, e.g. MoE experts, pass a small payload).
        self.bundle_bytes = (int(bundle_bytes) if bundle_bytes
                             else int(self.bundle_width * data.dtype.itemsize))
        self._phys_data = np.ascontiguousarray(data[self.placement.placement])

    # -- payload surface -----------------------------------------------------
    @property
    def payload_dtype(self) -> np.dtype:
        """dtype of the bundle payloads this store SERVES by default
        (file-backed int8 packs store int8 but `fetch` dequantizes to
        float32 unless the caller asks for the raw dtype)."""
        return self._phys_data.dtype

    @property
    def stored_dtype(self) -> np.dtype:
        """Raw on-media dtype — equals payload_dtype unless the store
        quantizes. Dtype-faithful staging allocates ring slots at this dtype
        so int8 pack rows never become float32 on the host."""
        return self._phys_data.dtype

    def physical_payload(self, dequantize: bool = True) -> np.ndarray:
        """Full [n_neurons, bundle_width] payload in PHYSICAL (placement)
        order — the segment-kernel weight source. Zero modelled I/O.
        dequantize=False returns the raw stored dtype (a no-op here; int8
        file stores return the raw memmap rows)."""
        return self._phys_data

    def physical_scales(self) -> Optional[np.ndarray]:
        """Per-neuron dequant scales in PHYSICAL order, or None for float
        payloads (consumers then use an implicit scale of 1.0)."""
        return None

    def fetch_scales_into(self, logical_ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        """Gather per-neuron scales for logical ids into `out[:k]` (the
        staged companion of `fetch_into` on quantized stores)."""
        raise RuntimeError("store is not quantized: no scales to fetch")

    # -- zero-cost payload access -------------------------------------------
    def fetch(self, logical_ids: np.ndarray) -> np.ndarray:
        """Bundle payloads for logical ids, in id order, at zero modelled I/O.

        This is the DRAM-side read: callers use it for neurons whose bytes are
        already resident (cache hits, or bytes just admitted by `read`). It is
        the public replacement for poking `_phys_data` directly — the serving
        engine accounts flash I/O exclusively through `read`/`ManagedReader`
        and serves every payload through this method.
        """
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        if logical_ids.size == 0:
            return np.zeros((0, self.bundle_width), dtype=self.payload_dtype)
        return self._phys_data[self.placement.physical_of(logical_ids)]

    def fetch_into(self, logical_ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        """`fetch` into a caller-provided buffer (no allocation): the serving
        engine keeps one padded host staging buffer per layer and gathers
        bundle payloads straight into it, so the decode loop performs a
        single buffer fill + one host-to-device transfer per layer."""
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        if logical_ids.size:
            np.take(self._phys_data, self.placement.physical_of(logical_ids),
                    axis=0, out=out[:logical_ids.size])
        return out

    # -- read planning -------------------------------------------------------
    def _plan(self, phys: np.ndarray,
              collapse_threshold: int) -> Tuple[List[Extent], np.ndarray]:
        """(read extents, pre-collapse run lengths) from physical positions."""
        phys_sorted = np.unique(phys)
        starts, ends = run_bounds_from_sorted(phys_sorted)
        extents = [(int(phys_sorted[s]), int(phys_sorted[e] - phys_sorted[s] + 1))
                   for s, e in zip(starts, ends)]
        run_lengths = (phys_sorted[ends] - phys_sorted[starts] + 1
                       if starts.size else np.zeros(0, dtype=np.int64))
        if collapse_threshold > 0:
            extents = collapse_extents(extents, collapse_threshold)
        return extents, run_lengths

    def plan_extents(self, logical_ids: np.ndarray, collapse_threshold: int = 0) -> List[Extent]:
        phys = self.placement.physical_of(np.asarray(logical_ids, dtype=np.int64))
        extents, _ = self._plan(phys, collapse_threshold)
        return extents

    def read(self, logical_ids: np.ndarray, collapse_threshold: int = 0,
             fetch_payload: bool = True) -> Tuple[Optional[np.ndarray], IOStats]:
        """Read bundles for logical ids; returns (data [k, w] in id order, stats).

        `stats.run_lengths` carries the pre-collapse run lengths (the maximal
        contiguous runs of the requested neurons in flash order) — computed
        here once from the already-sorted positions instead of by callers.
        `fetch_payload=False` skips materialising the payload (data is None):
        the engine's probe/read accounting path discards it anyway because the
        full activated-union payload — hits included — is gathered separately
        into a staging buffer via `fetch_into`.
        """
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        stats = IOStats(n_requests=1)
        if logical_ids.size == 0:
            stats.run_lengths = np.zeros(0, dtype=np.int64)
            empty = (np.zeros((0, self.bundle_width), dtype=self.payload_dtype)
                     if fetch_payload else None)
            return empty, stats
        phys = self.placement.physical_of(logical_ids)
        extents, stats.run_lengths = self._plan(phys, collapse_threshold)
        n_read = sum(length for _, length in extents)
        n_unique = int(stats.run_lengths.sum())   # runs partition unique ids
        stats.n_ops = len(extents) * self.reads_per_bundle
        stats.bytes_read = n_read * self.bundle_bytes * self.reads_per_bundle
        stats.bytes_useful = n_unique * self.bundle_bytes * self.reads_per_bundle
        stats.seconds = self.device.read_time(stats.n_ops, stats.bytes_read)
        data = self._serve_extents(extents, phys, fetch_payload, stats)
        return data, stats

    def _serve_extents(self, extents: List[Extent], phys: np.ndarray,
                       fetch_payload: bool,
                       stats: IOStats) -> Optional[np.ndarray]:
        """Payload-materialisation hook behind `read`'s accounting.

        The in-memory store serves straight from the DRAM-backed physical
        array — the extent plan affects accounting only, and the payload is
        identical regardless of it. File-backed stores
        (`repro.store.FileNeuronStore`) override this to issue one REAL
        positional file read per collapsed extent and record the measured_*
        fields on `stats` (the read happens even with `fetch_payload=False`:
        the extent reads ARE the I/O; only the row-gathered payload array is
        skipped)."""
        del extents, stats
        return self._phys_data[phys] if fetch_payload else None

    def close(self) -> None:
        """Release any backing resources. The in-memory store holds none —
        this no-op anchors the lifecycle contract so runtimes can close
        every store uniformly (`FileNeuronStore` overrides it to release
        its fd and memmap)."""


class ManagedReader:
    """Read path with adaptive collapse + bottleneck detection (paper §5.1)."""

    def __init__(self, store: NeuronStore, adaptive: bool = True,
                 initial_threshold: Optional[int] = None) -> None:
        """`initial_threshold=None` starts at the device break-even gap; an
        explicit value wins over the anchor (clamped to the adaptation band,
        which stays break-even-derived either way)."""
        self.store = store
        self.adaptive = adaptive
        break_even = store.device.bandwidth_max / (
            store.device.iops_max * max(store.bundle_bytes, 1))
        self.threshold = AdaptiveThreshold(initial=initial_threshold,
                                           break_even=break_even)
        self.detector = BottleneckDetector(store.device.bandwidth_max)
        self.total = IOStats()

    def read(self, logical_ids: np.ndarray,
             fetch_payload: bool = True) -> Tuple[Optional[np.ndarray], IOStats]:
        thr = self.threshold.threshold if (self.adaptive and self.detector.collapse_enabled) else 0
        data, stats = self.store.read(logical_ids, collapse_threshold=thr,
                                      fetch_payload=fetch_payload)
        if self.adaptive and stats.n_ops:
            op_cost = stats.n_ops / self.store.device.iops_max
            byte_cost = stats.bytes_read / self.store.device.bandwidth_max
            self.threshold.update(op_cost, byte_cost)
            self.detector.record(stats.bytes_read, stats.seconds)
        self.total.add(stats)
        return data, stats

    def predict_seconds(self, logical_ids: np.ndarray) -> float:
        """Modeled seconds a `read(logical_ids)` would cost RIGHT NOW, without
        issuing it: plan extents at the current adaptive threshold, apply the
        store's op/byte accounting, and price it on the calibrated UFSDevice.
        Pure — no threshold update, no detector sample, no `total` accrual —
        so SLO-aware admission (serving/server.py) can cost candidate steps
        as often as it likes without steering the adaptation it predicts."""
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        if logical_ids.size == 0:
            return 0.0
        thr = self.threshold.threshold if (self.adaptive and self.detector.collapse_enabled) else 0
        extents = self.store.plan_extents(logical_ids, collapse_threshold=thr)
        n_read = sum(length for _, length in extents)
        n_ops = len(extents) * self.store.reads_per_bundle
        bytes_read = n_read * self.store.bundle_bytes * self.store.reads_per_bundle
        return self.store.device.read_time(n_ops, bytes_read)
