"""I/O–compute pipeline model: double-buffered prefetch across FFN layers.

The paper's online stage (and PowerInfer-2 / LLM-in-a-flash before it) hides
flash latency behind computation: while layer L's FFN is computing, the
predicted neurons of layer L+1 are already being read. This module models that
schedule for the simulated UFS device so the serving engine can report BOTH

  * serial latency      — sum(compute_l + io_l): no overlap, the naive driver;
  * overlapped latency  — the double-buffered schedule below, which in steady
    state costs ~ sum(max(compute_l, io_l)) plus a residual for the first
    read that nothing can hide.

Schedule (prefetch depth 1, one I/O channel, one compute stream):
  * the read for layer l is issued once layer l-1's compute has STARTED
    (its predictor input is available then) and the channel is free;
  * layer l's compute starts when both its read and layer l-1's compute
    have finished.

Invariants (tested): overlapped <= serial, overlapped >= max(sum io,
sum compute), and overlap disabled => overlapped == serial.

MEASURED mode: when the serving engine runs the real prefetch pipeline it
passes per-stage host measurements (`StageMeasurement`) and the per-token
wall clock to `end_token(wall_seconds=...)` — `summary()` then reports the
`measured_*` counterparts next to the analytic model: wall per token, I/O
worker busy time, serving-thread blocked/top-up time, hidden time
(busy − blocked, clamped at 0), and the measured overlap efficiency. The
analytic schedule predicts; the measured columns are what actually happened.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.obs import get_metrics, get_tracer


@dataclasses.dataclass
class Stage:
    """One pipeline stage: a layer's (read, compute) pair for one token.

    `flops` is the modeled work of the stage; it is only used when the
    caller defers compute timing to `end_token(compute_seconds=...)`, which
    apportions one end-of-token measurement across stages by FLOPs share.
    """
    layer: int
    compute_seconds: float
    io_seconds: float
    flops: float = 0.0


@dataclasses.dataclass
class StageMeasurement:
    """Measured host timings of one pipelined stage (prefetch serving mode).

    `io_host_seconds` is the wall time the background I/O worker spent on the
    stage's begin phase (cache probe + read planning + staging gather);
    `blocked_seconds` is how long the serving thread actually waited for that
    prefetch; `topup_seconds` is the synchronous complete-phase work on the
    serving thread (mis-prediction top-up + admission + attribution).
    """
    io_host_seconds: float = 0.0
    blocked_seconds: float = 0.0
    topup_seconds: float = 0.0


@dataclasses.dataclass
class TokenTiming:
    serial_seconds: float
    overlapped_seconds: float
    n_stages: int
    # total modeled flash I/O across the token's stages; serial_seconds minus
    # this is the token's compute share (the admission predictor's input)
    io_seconds: float = 0.0
    # Measured counterpart (zero unless the caller ran the real prefetch
    # pipeline and passed wall/stage measurements): what actually happened on
    # this host, as opposed to the analytic schedule above.
    measured_wall_seconds: float = 0.0      # real end-to-end token time
    measured_io_busy_seconds: float = 0.0   # worker time spent on I/O stages
    measured_exposed_seconds: float = 0.0   # serving-thread waits + top-ups

    @property
    def hidden_seconds(self) -> float:
        return self.serial_seconds - self.overlapped_seconds

    @property
    def measured_hidden_seconds(self) -> float:
        """I/O host time that did NOT extend the token: worker busy time minus
        the time the serving thread actually spent waiting for it."""
        return max(0.0, self.measured_io_busy_seconds
                   - self.measured_exposed_seconds)

    @property
    def measured_serial_seconds(self) -> float:
        """What this token would have cost with the same work fully serial:
        the measured wall clock plus the I/O host time that was hidden."""
        return self.measured_wall_seconds + self.measured_hidden_seconds


def overlapped_latency(stages: Sequence[Stage]) -> float:
    """End-to-end latency of the double-buffered schedule over `stages`."""
    io_free = 0.0          # when the I/O channel finishes its current read
    compute_end = 0.0      # when the compute stream finishes the current layer
    prev_compute_start = 0.0
    for i, s in enumerate(stages):
        issue_at = 0.0 if i == 0 else prev_compute_start
        io_done = max(io_free, issue_at) + s.io_seconds
        io_free = io_done
        start = max(compute_end, io_done)
        prev_compute_start = start
        compute_end = start + s.compute_seconds
    return compute_end


def serial_latency(stages: Sequence[Stage]) -> float:
    return sum(s.compute_seconds + s.io_seconds for s in stages)


class IOScheduler:
    """Per-token stage recorder + overlap accountant for the serving engine.

    Usage per decode step:
        scheduler.begin_token()
        for each FFN layer: scheduler.record_stage(layer, compute_s, io_s)
        timing = scheduler.end_token()

    `summary()` aggregates over all recorded tokens; with `overlap=False` the
    overlapped latency degenerates to the serial one (the ablation arm of the
    benchmark sweep).
    """

    def __init__(self, overlap: bool = True) -> None:
        self.overlap = overlap
        self.history: List[TokenTiming] = []
        self._stages: List[Stage] = []
        self._measured: List[StageMeasurement] = []

    def begin_token(self) -> None:
        self._stages = []
        self._measured = []

    def record_stage(self, layer: int, compute_seconds: float = 0.0,
                     io_seconds: float = 0.0, flops: float = 0.0,
                     measured: Optional[StageMeasurement] = None) -> None:
        """Record one layer's stage. Callers either pass a measured
        `compute_seconds` directly (legacy per-layer wall clocks, which
        require a host sync per layer), or pass `flops` and defer timing to
        `end_token(compute_seconds=...)` — the sync-free path: XLA dispatch
        runs ahead all token, one end-of-token sync measures the whole token,
        and the measurement is apportioned across stages by FLOPs share.
        The prefetch pipeline additionally passes `measured` host timings so
        `end_token(wall_seconds=...)` can reconcile the analytic schedule
        against what actually happened."""
        self._stages.append(Stage(layer=layer,
                                  compute_seconds=float(compute_seconds),
                                  io_seconds=float(io_seconds),
                                  flops=float(flops)))
        if measured is not None:
            self._measured.append(measured)

    def end_token(self, compute_seconds: Optional[float] = None,
                  wall_seconds: Optional[float] = None) -> TokenTiming:
        if compute_seconds is not None and self._stages:
            total_flops = sum(s.flops for s in self._stages)
            for s in self._stages:
                share = (s.flops / total_flops if total_flops
                         else 1.0 / len(self._stages))
                s.compute_seconds += compute_seconds * share
        serial = serial_latency(self._stages)
        over = overlapped_latency(self._stages) if self.overlap else serial
        timing = TokenTiming(serial_seconds=serial, overlapped_seconds=over,
                             n_stages=len(self._stages),
                             io_seconds=sum(s.io_seconds
                                            for s in self._stages))
        if wall_seconds is not None:
            timing.measured_wall_seconds = float(wall_seconds)
            timing.measured_io_busy_seconds = sum(
                m.io_host_seconds for m in self._measured)
            timing.measured_exposed_seconds = sum(
                m.blocked_seconds + m.topup_seconds for m in self._measured)
        self.history.append(timing)
        self._stages = []
        self._measured = []
        tracer = get_tracer()
        if tracer.enabled:
            # counter tracks in the exported trace, so the Perfetto timeline
            # and BENCH_prefetch.json agree by construction (ISSUE 10)
            tracer.counter("io_model_ms",
                           serial=timing.serial_seconds * 1e3,
                           overlapped=timing.overlapped_seconds * 1e3,
                           io=timing.io_seconds * 1e3)
            if wall_seconds is not None:
                tracer.counter(
                    "io_measured_ms",
                    wall=timing.measured_wall_seconds * 1e3,
                    io_busy=timing.measured_io_busy_seconds * 1e3,
                    exposed=timing.measured_exposed_seconds * 1e3,
                    hidden=timing.measured_hidden_seconds * 1e3)
        return timing

    def predicted_compute_seconds_per_token(self, window: int = 8) -> float:
        """I/O-prediction hook for SLO-aware admission (serving/server.py):
        the compute share of recent tokens — mean (serial − modeled io) over
        the last `window` recorded tokens. The server adds this to the UFS
        model's predicted extent-read seconds for a candidate batch to
        estimate the next step's inter-token latency before admitting into a
        freed slot. Returns 0.0 with no history (cold server: admit freely)."""
        hist = self.history[-window:] if window > 0 else self.history
        if not hist:
            return 0.0
        return sum(t.serial_seconds - t.io_seconds for t in hist) / len(hist)

    def register_metrics(self, registry=None, prefix: str = "scheduler"):
        """Register this scheduler's summary fields as live gauges — the
        measured-mode columns (`wall/busy/exposed/hidden`,
        `overlap_efficiency`) plus the analytic model, all read lazily from
        `summary()` so the registry and the legacy reporting surface cannot
        disagree. Returns the registry used."""
        reg = registry if registry is not None else get_metrics()
        keys = (
            "tokens",
            "overlap_efficiency",
            "serial_seconds_per_token",
            "overlapped_seconds_per_token",
            "hidden_seconds_per_token",
            "measured_wall_seconds_per_token",
            "measured_serial_seconds_per_token",
            "measured_io_busy_seconds_per_token",
            "measured_exposed_seconds_per_token",
            "measured_hidden_seconds_per_token",
            "measured_overlap_efficiency",
        )
        for key in keys:
            reg.register_gauge(f"{prefix}.{key}",
                               lambda k=key: self.summary().get(k, 0.0))
        return reg

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        n = max(len(self.history), 1)
        serial = sum(t.serial_seconds for t in self.history)
        over = sum(t.overlapped_seconds for t in self.history)
        out = dict(
            tokens=len(self.history),
            overlap_enabled=self.overlap,
            serial_seconds_per_token=serial / n,
            overlapped_seconds_per_token=over / n,
            hidden_seconds_per_token=(serial - over) / n,
            overlap_efficiency=(1.0 - over / serial) if serial > 0 else 0.0,
        )
        wall = sum(t.measured_wall_seconds for t in self.history)
        if wall > 0:           # the real prefetch pipeline ran: report both
            hidden = sum(t.measured_hidden_seconds for t in self.history)
            exposed = sum(t.measured_exposed_seconds for t in self.history)
            busy = sum(t.measured_io_busy_seconds for t in self.history)
            out.update(
                measured_wall_seconds_per_token=wall / n,
                measured_serial_seconds_per_token=(wall + hidden) / n,
                measured_hidden_seconds_per_token=hidden / n,
                measured_exposed_seconds_per_token=exposed / n,
                measured_io_busy_seconds_per_token=busy / n,
                measured_overlap_efficiency=(hidden / (wall + hidden)
                                             if wall + hidden > 0 else 0.0),
            )
        return out

    def reset(self) -> None:
        self.history.clear()
        self._stages = []
        self._measured = []
