"""I/O–compute pipeline model: double-buffered prefetch across FFN layers.

The paper's online stage (and PowerInfer-2 / LLM-in-a-flash before it) hides
flash latency behind computation: while layer L's FFN is computing, the
predicted neurons of layer L+1 are already being read. This module models that
schedule for the simulated UFS device so the serving engine can report BOTH

  * serial latency      — sum(compute_l + io_l): no overlap, the naive driver;
  * overlapped latency  — the double-buffered schedule below, which in steady
    state costs ~ sum(max(compute_l, io_l)) plus a residual for the first
    read that nothing can hide.

Schedule (prefetch depth 1, one I/O channel, one compute stream):
  * the read for layer l is issued once layer l-1's compute has STARTED
    (its predictor input is available then) and the channel is free;
  * layer l's compute starts when both its read and layer l-1's compute
    have finished.

Invariants (tested): overlapped <= serial, overlapped >= max(sum io,
sum compute), and overlap disabled => overlapped == serial.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass
class Stage:
    """One pipeline stage: a layer's (read, compute) pair for one token.

    `flops` is the modeled work of the stage; it is only used when the
    caller defers compute timing to `end_token(compute_seconds=...)`, which
    apportions one end-of-token measurement across stages by FLOPs share.
    """
    layer: int
    compute_seconds: float
    io_seconds: float
    flops: float = 0.0


@dataclasses.dataclass
class TokenTiming:
    serial_seconds: float
    overlapped_seconds: float
    n_stages: int

    @property
    def hidden_seconds(self) -> float:
        return self.serial_seconds - self.overlapped_seconds


def overlapped_latency(stages: Sequence[Stage]) -> float:
    """End-to-end latency of the double-buffered schedule over `stages`."""
    io_free = 0.0          # when the I/O channel finishes its current read
    compute_end = 0.0      # when the compute stream finishes the current layer
    prev_compute_start = 0.0
    for i, s in enumerate(stages):
        issue_at = 0.0 if i == 0 else prev_compute_start
        io_done = max(io_free, issue_at) + s.io_seconds
        io_free = io_done
        start = max(compute_end, io_done)
        prev_compute_start = start
        compute_end = start + s.compute_seconds
    return compute_end


def serial_latency(stages: Sequence[Stage]) -> float:
    return sum(s.compute_seconds + s.io_seconds for s in stages)


class IOScheduler:
    """Per-token stage recorder + overlap accountant for the serving engine.

    Usage per decode step:
        scheduler.begin_token()
        for each FFN layer: scheduler.record_stage(layer, compute_s, io_s)
        timing = scheduler.end_token()

    `summary()` aggregates over all recorded tokens; with `overlap=False` the
    overlapped latency degenerates to the serial one (the ablation arm of the
    benchmark sweep).
    """

    def __init__(self, overlap: bool = True) -> None:
        self.overlap = overlap
        self.history: List[TokenTiming] = []
        self._stages: List[Stage] = []

    def begin_token(self) -> None:
        self._stages = []

    def record_stage(self, layer: int, compute_seconds: float = 0.0,
                     io_seconds: float = 0.0, flops: float = 0.0) -> None:
        """Record one layer's stage. Callers either pass a measured
        `compute_seconds` directly (legacy per-layer wall clocks, which
        require a host sync per layer), or pass `flops` and defer timing to
        `end_token(compute_seconds=...)` — the sync-free path: XLA dispatch
        runs ahead all token, one end-of-token sync measures the whole token,
        and the measurement is apportioned across stages by FLOPs share."""
        self._stages.append(Stage(layer=layer,
                                  compute_seconds=float(compute_seconds),
                                  io_seconds=float(io_seconds),
                                  flops=float(flops)))

    def end_token(self, compute_seconds: Optional[float] = None) -> TokenTiming:
        if compute_seconds is not None and self._stages:
            total_flops = sum(s.flops for s in self._stages)
            for s in self._stages:
                share = (s.flops / total_flops if total_flops
                         else 1.0 / len(self._stages))
                s.compute_seconds += compute_seconds * share
        serial = serial_latency(self._stages)
        over = overlapped_latency(self._stages) if self.overlap else serial
        timing = TokenTiming(serial_seconds=serial, overlapped_seconds=over,
                             n_stages=len(self._stages))
        self.history.append(timing)
        self._stages = []
        return timing

    # -- reporting ----------------------------------------------------------
    def summary(self) -> dict:
        n = max(len(self.history), 1)
        serial = sum(t.serial_seconds for t in self.history)
        over = sum(t.overlapped_seconds for t in self.history)
        return dict(
            tokens=len(self.history),
            overlap_enabled=self.overlap,
            serial_seconds_per_token=serial / n,
            overlapped_seconds_per_token=over / n,
            hidden_seconds_per_token=(serial - over) / n,
            overlap_efficiency=(1.0 - over / serial) if serial > 0 else 0.0,
        )

    def reset(self) -> None:
        self.history.clear()
        self._stages = []
