"""Offline neuron-placement search (paper §4.2-4.3, Algorithm 1).

The problem: place neurons on a 1-D flash layout so frequently co-activated
neurons are adjacent — i.e. find the shortest Hamiltonian path on the complete
graph with dist(i, j) = 1 - P(ij). NP-hard (reduces to TSP), so Algorithm 1
greedily merges neuron *links* (paths), nearest endpoints first, using a
priority queue + union-find + per-node neighbour counts.

Two modes:
  * exact  — enumerate all O(n^2) pairs (paper's formulation). Fine to ~8k
    neurons in numpy (the sort dominates).
  * topk   — only the K nearest partners per neuron enter the queue. Pairs with
    P(ij) == 0 all tie at distance 1 and contribute nothing to the objective, so
    dropping them preserves the greedy's choices whenever each neuron has < K
    co-activation partners; leftover path fragments are chained afterwards.
    This keeps the largest paper models (n = 43k) tractable in pure Python.

Complexity: O(E log E) for E queue entries (E = n^2 exact, nK topk) — matching
the paper's O(n^2 log n).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np


class _DSU:
    """Union-find with path compression + union by size."""

    def __init__(self, n: int) -> None:
        self.parent = list(range(n))
        self.size = [1] * n

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]
        return True


@dataclasses.dataclass
class PlacementResult:
    placement: np.ndarray          # [n] neuron ids in physical order
    inverse: np.ndarray            # [n] physical position of each neuron id
    edges_used: int
    search_seconds: float
    mode: str

    def physical_of(self, ids: np.ndarray) -> np.ndarray:
        return self.inverse[ids]


def _edge_candidates_exact(dist: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """All i<j pairs sorted by distance. Returns (us, vs) int32 arrays."""
    n = dist.shape[0]
    iu, ju = np.triu_indices(n, k=1)
    order = np.argsort(dist[iu, ju], kind="stable")
    return iu[order].astype(np.int32), ju[order].astype(np.int32)


def _edge_candidates_topk(dist: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-node k nearest partners, deduped and sorted by distance."""
    n = dist.shape[0]
    k = min(k, n - 1)
    nbr = np.argpartition(dist, kth=k - 1, axis=1)[:, :k]          # [n, k]
    us = np.repeat(np.arange(n, dtype=np.int64), k)
    vs = nbr.reshape(-1).astype(np.int64)
    lo, hi = np.minimum(us, vs), np.maximum(us, vs)
    keys = lo * n + hi
    uniq = np.unique(keys)
    lo, hi = (uniq // n).astype(np.int32), (uniq % n).astype(np.int32)
    order = np.argsort(dist[lo, hi], kind="stable")
    return lo[order], hi[order]


def search_placement(
    dist: np.ndarray,
    mode: Literal["auto", "exact", "topk"] = "auto",
    topk: int = 64,
) -> PlacementResult:
    """Algorithm 1: greedy link merging over the co-activation graph."""
    t0 = time.perf_counter()
    n = dist.shape[0]
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        return PlacementResult(empty, empty.copy(), 0, 0.0, mode)
    if n == 1:
        one = np.zeros(1, dtype=np.int64)
        return PlacementResult(one, one.copy(), 0, 0.0, mode)
    if mode == "auto":
        mode = "exact" if n <= 4096 else "topk"
    if mode == "exact":
        us, vs = _edge_candidates_exact(dist)
    else:
        us, vs = _edge_candidates_topk(dist, topk)

    nbr_cnt = np.zeros(n, dtype=np.int8)          # NbrCnt in Algorithm 1
    adj = [[] for _ in range(n)]                  # path adjacency (degree <= 2)
    dsu = _DSU(n)
    edges_used = 0
    for u, v in zip(us.tolist(), vs.tolist()):
        if nbr_cnt[u] == 2 or nbr_cnt[v] == 2:    # skip if inside a link
            continue
        if not dsu.union(u, v):                   # would close a cycle
            continue
        nbr_cnt[u] += 1
        nbr_cnt[v] += 1
        adj[u].append(v)
        adj[v].append(u)
        edges_used += 1
        if edges_used == n - 1:
            break

    # Chain any leftover path fragments (topk mode may exhaust candidates).
    if edges_used < n - 1:
        endpoints_by_root: dict[int, list[int]] = {}
        for node in range(n):
            if nbr_cnt[node] <= 1:
                endpoints_by_root.setdefault(dsu.find(node), []).append(node)
        frags = list(endpoints_by_root.values())
        for a, b in zip(frags, frags[1:]):
            u = a[-1] if len(a) > 1 else a[0]      # tail of previous fragment
            v = b[0]
            dsu.union(u, v)
            nbr_cnt[u] += 1
            nbr_cnt[v] += 1
            adj[u].append(v)
            adj[v].append(u)
            edges_used += 1

    # Walk the single remaining path from one endpoint.
    start = next(i for i in range(n) if len(adj[i]) <= 1)
    placement = np.empty(n, dtype=np.int64)
    prev, cur = -1, start
    for pos in range(n):
        placement[pos] = cur
        nxt = -1
        for cand in adj[cur]:
            if cand != prev:
                nxt = cand
                break
        prev, cur = cur, nxt
        if nxt == -1 and pos != n - 1:
            raise AssertionError("placement walk ended early — path is broken")

    inverse = np.empty(n, dtype=np.int64)
    inverse[placement] = np.arange(n)
    return PlacementResult(placement, inverse, edges_used, time.perf_counter() - t0, mode)


# ---------------------------------------------------------------------------
# Baseline placements (evaluation baselines in §6)
# ---------------------------------------------------------------------------

def identity_placement(n: int) -> PlacementResult:
    """Model-structure order — llama.cpp / LLMFlash layout."""
    p = np.arange(n, dtype=np.int64)
    return PlacementResult(p, p.copy(), 0, 0.0, "identity")


def frequency_placement(activation_rate: np.ndarray) -> PlacementResult:
    """Hot-first layout: sort by activation frequency (a natural strawman)."""
    p = np.argsort(-np.asarray(activation_rate), kind="stable").astype(np.int64)
    inv = np.empty_like(p)
    inv[p] = np.arange(len(p))
    return PlacementResult(p, inv, 0, 0.0, "frequency")


def path_length(dist: np.ndarray, placement: np.ndarray) -> float:
    """Total Hamiltonian-path length under dist — the search objective."""
    a, b = placement[:-1], placement[1:]
    return float(dist[a, b].sum())
