"""Offline neuron-placement search (paper §4.2-4.3, Algorithm 1).

The problem: place neurons on a 1-D flash layout so frequently co-activated
neurons are adjacent — i.e. find the shortest Hamiltonian path on the complete
graph with dist(i, j) = 1 - P(ij). NP-hard (reduces to TSP), so Algorithm 1
greedily merges neuron *links* (paths), nearest endpoints first, using a
priority queue + union-find + per-node neighbour counts.

Two modes:
  * exact  — enumerate all O(n^2) pairs (paper's formulation). Fine to ~8k
    neurons in numpy (the sort dominates).
  * topk   — only the K nearest partners per neuron enter the queue. Pairs with
    P(ij) == 0 all tie at distance 1 and contribute nothing to the objective, so
    dropping them preserves the greedy's choices whenever each neuron has < K
    co-activation partners; leftover path fragments are chained afterwards.
    This keeps the largest paper models (n = 43k) tractable in pure Python.

Complexity: O(E log E) for E queue entries (E = n^2 exact, nK topk) — matching
the paper's O(n^2 log n).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np


class _DSU:
    """Union-find with path compression + union by size."""

    def __init__(self, n: int) -> None:
        self.parent = list(range(n))
        self.size = [1] * n

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]
        return True


@dataclasses.dataclass
class PlacementResult:
    placement: np.ndarray          # [n] neuron ids in physical order
    inverse: np.ndarray            # [n] physical position of each neuron id
    edges_used: int
    search_seconds: float
    mode: str

    def physical_of(self, ids: np.ndarray) -> np.ndarray:
        return self.inverse[ids]


def _edge_candidates_exact(dist: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """All i<j pairs sorted by distance. Returns (us, vs) int32 arrays."""
    n = dist.shape[0]
    iu, ju = np.triu_indices(n, k=1)
    order = np.argsort(dist[iu, ju], kind="stable")
    return iu[order].astype(np.int32), ju[order].astype(np.int32)


def _edge_candidates_topk(dist: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-node k nearest partners, deduped and sorted by distance."""
    n = dist.shape[0]
    k = min(k, n - 1)
    nbr = np.argpartition(dist, kth=k - 1, axis=1)[:, :k]          # [n, k]
    us = np.repeat(np.arange(n, dtype=np.int64), k)
    vs = nbr.reshape(-1).astype(np.int64)
    lo, hi = np.minimum(us, vs), np.maximum(us, vs)
    keys = lo * n + hi
    uniq = np.unique(keys)
    lo, hi = (uniq // n).astype(np.int32), (uniq % n).astype(np.int32)
    order = np.argsort(dist[lo, hi], kind="stable")
    return lo[order], hi[order]


def _merge_links_loop(us: np.ndarray, vs: np.ndarray, n: int):
    """Reference greedy (the paper's Algorithm 1 inner loop): one Python
    iteration per candidate edge. Kept as the equivalence oracle for the
    batched implementation below; `search_placement(greedy_impl='loop')`
    routes here."""
    nbr_cnt = np.zeros(n, dtype=np.int8)          # NbrCnt in Algorithm 1
    dsu = _DSU(n)
    acc_u: list[int] = []
    acc_v: list[int] = []
    for u, v in zip(us.tolist(), vs.tolist()):
        if nbr_cnt[u] == 2 or nbr_cnt[v] == 2:    # skip if inside a link
            continue
        if not dsu.union(u, v):                   # would close a cycle
            continue
        nbr_cnt[u] += 1
        nbr_cnt[v] += 1
        acc_u.append(u)
        acc_v.append(v)
        if len(acc_u) == n - 1:
            break
    roots = np.fromiter((dsu.find(i) for i in range(n)), np.int64, n)
    return (np.asarray(acc_u, dtype=np.int64), np.asarray(acc_v, dtype=np.int64),
            nbr_cnt, roots)


def _batch_roots(parent: np.ndarray, xs: np.ndarray) -> np.ndarray:
    """Vectorized union-find root lookup with path compression on `xs`."""
    r = parent[xs]
    while True:
        rr = parent[r]
        if np.array_equal(rr, r):
            break
        r = parent[rr]
    parent[xs] = r
    return r


def _edgewise_first(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Per-edge independence mask: True for edge i iff neither a[i] nor b[i]
    occurs in any EARLIER edge (values interleaved in edge order, so edge j
    claims both its slots before edge j+1 claims either)."""
    m = int(a.size)
    inter = np.empty(2 * m, dtype=a.dtype)
    inter[0::2], inter[1::2] = a, b
    first = np.zeros(2 * m, dtype=bool)
    _, idx = np.unique(inter, return_index=True)
    first[idx] = True
    return first[0::2] & first[1::2]


_GREEDY_BATCH = 8192


def _merge_links_batched(us: np.ndarray, vs: np.ndarray, n: int):
    """Array-native greedy link merging, bit-identical to `_merge_links_loop`.

    Candidate edges are processed in numpy batches. Two rejections are FINAL
    regardless of position — a saturated endpoint (degree never decreases)
    and a same-component pair (components never split) — so they are filtered
    with one vectorized pass per batch. Of the survivors, every edge whose
    endpoints AND component roots appear for the first time within the batch
    is independent of all earlier batch edges: the sequential loop would
    accept each one with exactly the state it sees here, so they are applied
    wholesale (degree bump + union-by-size, all disjoint). Dependent edges
    are re-examined on the next inner pass with the updated state — i.e. in
    the same index order the sequential loop would reach them. Each inner
    pass accepts at least one edge, so termination is immediate.
    """
    parent = np.arange(n, dtype=np.int64)
    size = np.ones(n, dtype=np.int64)
    nbr_cnt = np.zeros(n, dtype=np.int8)
    acc_u: list[np.ndarray] = []
    acc_v: list[np.ndarray] = []
    edges_used = 0
    need = n - 1
    for pos in range(0, int(us.size), _GREEDY_BATCH):
        if edges_used >= need:
            break
        bu = us[pos:pos + _GREEDY_BATCH].astype(np.int64)
        bv = vs[pos:pos + _GREEDY_BATCH].astype(np.int64)
        while bu.size and edges_used < need:
            keep = (nbr_cnt[bu] < 2) & (nbr_cnt[bv] < 2)   # final reject
            bu, bv = bu[keep], bv[keep]
            if not bu.size:
                break
            ru = _batch_roots(parent, bu)
            rv = _batch_roots(parent, bv)
            keep = ru != rv                                # final reject (cycle)
            bu, bv, ru, rv = bu[keep], bv[keep], ru[keep], rv[keep]
            if not bu.size:
                break
            indep = _edgewise_first(bu, bv) & _edgewise_first(ru, rv)
            au, av = bu[indep], bv[indep]
            aru, arv = ru[indep], rv[indep]
            if edges_used + au.size > need:                # sequential break
                # The cap can only bind with ONE edge left: k accepted edges
                # have 2k distinct roots, and the component count is exactly
                # (need - edges_used) + 1, so k <= (remaining + 1) / 2 — the
                # cut below therefore always keeps just the batch's first
                # survivor, which is independent by construction, exactly the
                # edge the sequential loop would stop after. No acceptable
                # dependent edge can be skipped by the early exit.
                cut = need - edges_used
                au, av, aru, arv = au[:cut], av[:cut], aru[:cut], arv[:cut]
            if au.size:
                nbr_cnt[au] += 1                           # endpoints disjoint
                nbr_cnt[av] += 1
                swap = size[aru] < size[arv]               # union by size
                ra = np.where(swap, arv, aru)
                rb = np.where(swap, aru, arv)
                parent[rb] = ra                            # roots disjoint
                size[ra] += size[rb]
                acc_u.append(au)
                acc_v.append(av)
                edges_used += int(au.size)
            dep = ~indep
            bu, bv = bu[dep], bv[dep]
    roots = _batch_roots(parent, np.arange(n, dtype=np.int64))
    out_u = (np.concatenate(acc_u) if acc_u else np.zeros(0, dtype=np.int64))
    out_v = (np.concatenate(acc_v) if acc_v else np.zeros(0, dtype=np.int64))
    return out_u, out_v, nbr_cnt, roots


def search_placement(
    dist: np.ndarray,
    mode: Literal["auto", "exact", "topk"] = "auto",
    topk: int = 64,
    greedy_impl: Literal["batched", "loop"] = "batched",
) -> PlacementResult:
    """Algorithm 1: greedy link merging over the co-activation graph.

    The merge loop runs array-native by default (`greedy_impl='batched'`,
    processing candidate edges in numpy batches with a vectorized DSU/degree
    filter); `'loop'` is the per-edge reference implementation, kept for the
    bit-identical equivalence tests and the before/after benchmark.
    """
    t0 = time.perf_counter()
    n = dist.shape[0]
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        return PlacementResult(empty, empty.copy(), 0, 0.0, mode)
    if n == 1:
        one = np.zeros(1, dtype=np.int64)
        return PlacementResult(one, one.copy(), 0, 0.0, mode)
    if mode == "auto":
        mode = "exact" if n <= 4096 else "topk"
    if mode == "exact":
        us, vs = _edge_candidates_exact(dist)
    else:
        us, vs = _edge_candidates_topk(dist, topk)

    merge = _merge_links_batched if greedy_impl == "batched" else _merge_links_loop
    acc_u, acc_v, nbr_cnt, roots = merge(us, vs, n)
    edges_used = int(acc_u.size)
    adj = [[] for _ in range(n)]                  # path adjacency (degree <= 2)
    for u, v in zip(acc_u.tolist(), acc_v.tolist()):
        adj[u].append(v)
        adj[v].append(u)

    # Chain any leftover path fragments (topk mode may exhaust candidates).
    if edges_used < n - 1:
        endpoints_by_root: dict[int, list[int]] = {}
        for node in np.flatnonzero(nbr_cnt <= 1).tolist():
            endpoints_by_root.setdefault(int(roots[node]), []).append(node)
        frags = list(endpoints_by_root.values())
        for a, b in zip(frags, frags[1:]):
            u = a[-1] if len(a) > 1 else a[0]      # tail of previous fragment
            v = b[0]
            nbr_cnt[u] += 1
            nbr_cnt[v] += 1
            adj[u].append(v)
            adj[v].append(u)
            edges_used += 1

    # Walk the single remaining path from one endpoint.
    start = next(i for i in range(n) if len(adj[i]) <= 1)
    placement = np.empty(n, dtype=np.int64)
    prev, cur = -1, start
    for pos in range(n):
        placement[pos] = cur
        nxt = -1
        for cand in adj[cur]:
            if cand != prev:
                nxt = cand
                break
        prev, cur = cur, nxt
        if nxt == -1 and pos != n - 1:
            raise AssertionError("placement walk ended early — path is broken")

    inverse = np.empty(n, dtype=np.int64)
    inverse[placement] = np.arange(n)
    return PlacementResult(placement, inverse, edges_used, time.perf_counter() - t0, mode)


# ---------------------------------------------------------------------------
# Baseline placements (evaluation baselines in §6)
# ---------------------------------------------------------------------------

def identity_placement(n: int) -> PlacementResult:
    """Model-structure order — llama.cpp / LLMFlash layout."""
    p = np.arange(n, dtype=np.int64)
    return PlacementResult(p, p.copy(), 0, 0.0, "identity")


def frequency_placement(activation_rate: np.ndarray) -> PlacementResult:
    """Hot-first layout: sort by activation frequency (a natural strawman)."""
    p = np.argsort(-np.asarray(activation_rate), kind="stable").astype(np.int64)
    inv = np.empty_like(p)
    inv[p] = np.arange(len(p))
    return PlacementResult(p, inv, 0, 0.0, "frequency")


def path_length(dist: np.ndarray, placement: np.ndarray) -> float:
    """Total Hamiltonian-path length under dist — the search objective."""
    a, b = placement[:-1], placement[1:]
    return float(dist[a, b].sum())
