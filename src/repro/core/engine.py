"""OffloadEngine — the paper's online serving pipeline for one FFN block.

Per token: predict activated neurons -> probe DRAM cache -> plan reads over the
flash layout (with access collapse) -> simulated-UFS read -> admit into cache
(linking-aligned) -> compute the sparse FFN from the bundles actually read.

Three serving granularities:
  * `step(ids)`       — one activated set (one token / one request);
  * `step_batch(ids_per_request)` — one decode *batch* from per-request id
    arrays: the activated sets of all requests are merged, the cache is
    probed once, and all misses are served by a single collapsed extent read
    (shared neurons are read once — the batching win);
  * `step_masks(masks)` — same batched step but straight from the [B, n]
    boolean activation-mask matrix the predictor produces. This is the
    serving hot path: the union, the per-request attribution
    (searchsorted/bincount-style), and the run statistics are all computed
    with array ops — no per-request or per-neuron Python iteration.

Split-phase steps (the asynchronous prefetch pipeline): `begin_step_masks`
runs the probe + read planning + collapsed read for a *speculated* mask
matrix (a lookahead prediction of the next layer's activated set, issued by
a background I/O worker while the device computes the current layer), and
`complete_step` later reconciles against the true masks — any truly
activated neuron the speculation missed is served by a synchronous top-up
read (correctness is never traded for overlap), then admission, history,
and per-request attribution happen exactly as in the one-shot step.
`step_masks` IS `complete_step(begin_step_masks(masks))`, so the split is
stats-identical to the fused step by construction.

Per-request attribution comes back columnar in `BatchStepResult`
(`req_io_seconds` etc.); `per_request` materialises the `RequestStats` view
on demand for reporting code.

The engine is deliberately deterministic and fully instrumented: every paper
figure (latency, IOPS, effective bandwidth, run lengths, cache behaviour) is
derived from `TokenStats` streams produced here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cache import make_linking_aligned_cache
from repro.core.placement import PlacementResult
from repro.core.storage import IOStats, ManagedReader, NeuronStore, UFSDevice
from repro.obs import get_tracer


@dataclasses.dataclass
class TokenStats:
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io: IOStats = dataclasses.field(default_factory=IOStats)
    run_lengths: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))

    @property
    def io_seconds(self) -> float:
        return self.io.seconds


@dataclasses.dataclass
class RequestStats:
    """Per-request attribution of one batched engine step.

    The device performs ONE merged read; each request is billed a share of
    the read TIME proportional to the misses it asked for, so `io_seconds`
    always sums to exactly the merged read. A neuron missed by several
    requests splits its time cost among them — that split IS the batching
    saving, vs. each request paying for its own read in the unbatched loop.
    `bytes_useful` is different on purpose: it counts the bytes a request
    asked to have read (its own missed bundles), so summing it across
    requests double-counts shared neurons — compare it against
    `merged.io.bytes_useful` to measure exactly that sharing."""
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io_seconds: float = 0.0
    bytes_useful: int = 0


@dataclasses.dataclass
class BatchStepResult:
    """Result of one batched step: merged payload + stats at both granularities.

    Per-request attribution is stored columnar (one array per field, row =
    request) so the serving engine can consume it without constructing
    per-request Python objects; `per_request` builds the object view lazily.
    """
    ids: np.ndarray                     # served union (activated ∪ prefetched), sorted unique
    data: Optional[np.ndarray]          # [len(ids), bundle_width] payloads
    merged: TokenStats                  # what the device actually did
    req_n_activated: np.ndarray         # [R] int
    req_n_misses: np.ndarray            # [R] int
    req_io_seconds: np.ndarray          # [R] float, sums to merged.io.seconds
    req_bytes_useful: np.ndarray        # [R] int
    # split-phase extras: neurons the lookahead speculation missed, served by
    # the synchronous top-up read (always empty on the fused path, where the
    # speculated union IS the true union and n_speculated == ids.size).
    topup_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    n_speculated: int = 0               # speculated-union size

    @property
    def per_request(self) -> List[RequestStats]:
        return [RequestStats(
            n_activated=int(a), n_hits=int(a) - int(m), n_misses=int(m),
            io_seconds=float(s), bytes_useful=int(b))
            for a, m, s, b in zip(self.req_n_activated, self.req_n_misses,
                                  self.req_io_seconds, self.req_bytes_useful)]

    def rows_for(self, request_ids: np.ndarray) -> np.ndarray:
        """Row indices into `data` for one request's activated ids."""
        return np.searchsorted(self.ids, np.unique(np.asarray(request_ids,
                                                              dtype=np.int64)))


@dataclasses.dataclass
class PendingStep:
    """In-flight half of a split-phase step (`begin_step_masks` output).

    Produced on the prefetch worker while the device computes the previous
    layer; consumed by `complete_step` on the serving thread. Holds exactly
    the state the complete phase needs to reconcile speculation with truth.
    """
    masks: np.ndarray          # [B, n] speculated activation masks
    union: np.ndarray          # speculated union, sorted unique
    miss_mask: np.ndarray      # over `union`: not DRAM-resident at begin time
    io: IOStats                # the speculative collapsed read (0 ops if none)
    data: Optional[np.ndarray]  # [len(union), w] payloads if requested


@dataclasses.dataclass
class EngineConfig:
    cache_ratio: float = 0.1          # fraction of neurons resident in DRAM
    collapse: bool = True             # paper §5.1
    linking_aligned_cache: bool = True  # paper §5.2
    reads_per_bundle: int = 1         # 1 = bundled (LLMFlash/RIPPLE); n_mats = llama.cpp
    # None anchors the adaptive collapse threshold at the device break-even
    # gap; an explicit value overrides the anchor (clamped to its band)
    initial_collapse_threshold: Optional[int] = None
    segment_min_len: int = 4
    segment_admit_p: float = 0.25
    cache_impl: str = "array"         # "array" (vectorized) | "dict" (reference)
    # FFN compute source for the serving runtime: "bundles" evaluates the
    # sparse FFN straight from the staged flash payloads; "segments" routes
    # through the fused segment-gather kernel (kernels/sparse_ffn.py) over
    # seg_size-aligned blocks of the permuted physical layout — exact for all
    # supported activations (covered-but-not-activated neurons are masked
    # in-kernel via the per-neuron scale tiles). "auto" promotes segments
    # when the layout is physical-placement-ordered (no identity-placement
    # layer, and the payload maps onto [n_mats * d_model] bundles) and falls
    # back to bundles otherwise; the decision is logged in io_summary().
    ffn_kernel: str = "auto"          # "auto" | "bundles" | "segments"
    kernel_seg_size: int = 128
    # Temporally faithful device emulation: actually wait out each modeled
    # flash read (a real UFS link stalls the pipeline for exactly this long —
    # DMA time, not CPU time). Off by default (pure accounting); the measured
    # prefetch benchmark turns it on for BOTH arms so serial decode stalls on
    # "flash" exactly where a phone would, and the pipelined arm's win is the
    # overlap a real device would allow.
    emulate_read_latency: bool = False


class OffloadEngine:
    """Flash-offloaded sparse-FFN serving for one FFN block."""

    def __init__(
        self,
        bundles: Optional[np.ndarray] = None,      # [n_neurons, bundle_width]
        placement: Optional[PlacementResult] = None,
        device: Optional[UFSDevice] = None,
        config: Optional[EngineConfig] = None,
        bundle_bytes: Optional[int] = None,
        *,
        store: Optional[NeuronStore] = None,
    ) -> None:
        """Either pass raw `bundles` (+ optional placement/device, defaulted by
        `NeuronStore` — the single constructor path) or a prebuilt `store`.
        The engine never re-defaults placement/device itself: `self.placement`
        and the device model are always the store's."""
        if store is None:
            self.cfg = config or EngineConfig()
            if bundles is None:
                raise ValueError("OffloadEngine needs `bundles` or `store`")
            store = NeuronStore(
                bundles, placement, device,
                reads_per_bundle=self.cfg.reads_per_bundle,
                bundle_bytes=bundle_bytes,
            )
        else:
            if any(a is not None for a in (bundles, placement, device, bundle_bytes)):
                raise ValueError(
                    "pass either a prebuilt `store` or raw bundles/placement/"
                    "device/bundle_bytes, not both — the store already fixes them")
            if config is None:   # adopt the store's layout cost model
                self.cfg = dataclasses.replace(
                    EngineConfig(), reads_per_bundle=store.reads_per_bundle)
            elif config.reads_per_bundle != store.reads_per_bundle:
                raise ValueError(
                    f"config.reads_per_bundle={config.reads_per_bundle} "
                    f"conflicts with store.reads_per_bundle={store.reads_per_bundle}")
            else:
                self.cfg = config
        self.store = store
        self.placement = store.placement
        self.reader = ManagedReader(
            self.store,
            adaptive=self.cfg.collapse,
            initial_threshold=self.cfg.initial_collapse_threshold,
        )
        self.cache = make_linking_aligned_cache(
            capacity=int(self.cfg.cache_ratio * store.n_neurons),
            n_keys=store.n_neurons,
            segment_min_len=self.cfg.segment_min_len,
            segment_admit_p=self.cfg.segment_admit_p,
            linking_aligned=self.cfg.linking_aligned_cache,
            impl=self.cfg.cache_impl,
        )
        self.history: List[TokenStats] = []

    @classmethod
    def from_store(cls, store: NeuronStore,
                   config: Optional[EngineConfig] = None) -> "OffloadEngine":
        return cls(store=store, config=config)

    # ------------------------------------------------------------------
    def _probe_and_read(self, union: np.ndarray) -> tuple[np.ndarray, IOStats]:
        """Begin-phase primitive: probe the cache for one sorted-unique set and
        serve all misses with one collapsed read. Returns (miss mask over
        `union`, read IOStats). Mutates cache hit/miss stats and the adaptive
        reader, but does NOT admit or append history — that is the
        complete-phase (`_admit_and_record`), so a background worker can run
        this ahead of time."""
        tracer = get_tracer()
        with tracer.span("probe") as sp:
            hit_mask = self.cache.lookup_mask(union)
            miss_mask = ~hit_mask
            misses = union[miss_mask]
            sp.set(n_union=int(union.size), n_misses=int(misses.size))
        io = IOStats()
        io.run_lengths = np.zeros(0, dtype=np.int64)
        if misses.size:
            with tracer.span("read") as sp:
                _, io = self.reader.read(misses, fetch_payload=False)
                if self.cfg.emulate_read_latency:
                    time.sleep(io.seconds)
                sp.set(n_misses=int(misses.size), extents=int(io.n_ops),
                       modeled_s=io.seconds, measured_s=io.measured_seconds)
        return miss_mask, io

    def predict_read_seconds(self, union: np.ndarray) -> float:
        """Modeled flash seconds serving `union` would cost RIGHT NOW, without
        serving it: peek the cache for residency (no stat/frequency bumps),
        then price the would-be miss read at the reader's current collapse
        threshold on the calibrated UFSDevice. Pure — cache, adaptive
        threshold, and history are untouched — so the server's SLO-aware
        admission gate can cost a candidate step per free slot per layer
        without perturbing the state it predicts."""
        union = np.asarray(union, dtype=np.int64)
        if union.size == 0:
            return 0.0
        resident = self.cache.peek_mask(union)
        misses = union[~resident]
        if misses.size == 0:
            return 0.0
        return self.reader.predict_seconds(misses)

    def _admit_and_record(self, n_activated: int, n_misses: int,
                          misses: np.ndarray, io: IOStats,
                          run_lengths: np.ndarray) -> TokenStats:
        """Complete-phase primitive: admit this step's missed neurons into the
        DRAM cache and record the merged TokenStats."""
        ts = TokenStats(n_activated=n_activated,
                        n_hits=n_activated - n_misses, n_misses=n_misses,
                        io=io, run_lengths=run_lengths)
        if misses.size:
            with get_tracer().span("admit", n_misses=int(misses.size)):
                self.cache.admit(misses, self.placement.physical_of(misses))
        self.history.append(ts)
        return ts

    def _serve_union(self, union: np.ndarray) -> tuple[TokenStats, np.ndarray]:
        """Probe + read + admit for one sorted-unique activated set; returns
        (merged TokenStats, miss mask over `union`)."""
        miss_mask, io = self._probe_and_read(union)
        ts = self._admit_and_record(int(union.size),
                                    int(np.count_nonzero(miss_mask)),
                                    union[miss_mask], io, io.run_lengths)
        return ts, miss_mask

    def step(self, activated_ids: np.ndarray,
             fetch_payload: bool = True) -> tuple[Optional[np.ndarray], TokenStats]:
        """Serve one token's activated-neuron set; returns (bundle data, stats).

        Returned bundles are in `activated_ids` order (cache hits are served
        from DRAM at zero I/O cost; the payload is identical either way).
        With `fetch_payload=False` the caller gathers the payload itself
        (e.g. into a reused staging buffer via `NeuronStore.fetch_into`).
        """
        ids = np.unique(np.asarray(activated_ids, dtype=np.int64))
        ts, _ = self._serve_union(ids)
        # payload for *all* activated neurons (hits came from DRAM)
        data = self.store.fetch(ids) if fetch_payload else None
        return data, ts

    # ------------------------------------------------------------------
    def step_batch(self, ids_per_request: Sequence[np.ndarray]) -> BatchStepResult:
        """Serve one decode step for a whole batch of requests.

        Activated sets are merged across requests, the cache is probed once
        per unique neuron, and all misses go out as ONE collapsed extent read
        — a neuron wanted by several requests is read (and billed to the
        device) once. `history` records the merged step, so `summary()`
        reflects real device activity; per-request attribution (hits, misses,
        proportional share of the read time) is one searchsorted + bincount
        over the concatenated id sets.
        """
        id_sets = [np.unique(np.asarray(ids, dtype=np.int64))
                   for ids in ids_per_request]
        all_ids = (np.concatenate(id_sets) if id_sets
                   else np.zeros((0,), dtype=np.int64))
        union = np.unique(all_ids)
        merged, miss_mask = self._serve_union(union)
        # per-request attribution: locate every requested id in the union,
        # look up its hit/miss status, and histogram by request
        sizes = np.array([s.size for s in id_sets], dtype=np.int64)
        req_of = np.repeat(np.arange(len(id_sets)), sizes)
        is_miss = (miss_mask[np.searchsorted(union, all_ids)] if all_ids.size
                   else np.zeros(0, dtype=bool))
        miss_counts = np.bincount(req_of, weights=is_miss,
                                  minlength=len(id_sets)).astype(np.int64)
        data = self.store.fetch(union)
        return self._attributed_result(union, data, merged, sizes, miss_counts)

    def step_masks(self, masks: np.ndarray,
                   fetch_payload: bool = True) -> BatchStepResult:
        """`step_batch` straight from the [B, n_neurons] bool mask matrix.

        The union and the per-request miss counts come from column/row
        reductions of the mask matrix — the decode inner loop never
        materialises per-request id lists. With `fetch_payload=False` the
        caller gathers payloads itself (e.g. into a reused staging buffer
        via `NeuronStore.fetch_into`) and `result.data` is None.

        Implemented as `complete_step(begin_step_masks(masks))` — the fused
        step and the split-phase pipeline share every probe/read/admit line,
        so the two are stats-identical by construction.
        """
        return self.complete_step(self.begin_step_masks(masks, fetch_payload))

    # -- split-phase (asynchronous prefetch) ---------------------------
    def begin_step_masks(self, masks: np.ndarray,
                         fetch_payload: bool = True) -> PendingStep:
        """Begin one batched step from (possibly speculative) masks: probe the
        cache and issue the single collapsed read for all misses. Safe to run
        on a background worker — admission, history, and attribution are
        deferred to `complete_step` on the serving thread. Each engine serves
        one FFN block, so a worker running layer k+1's begin phase never
        shares mutable state with layer k's complete phase.
        """
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        union = np.flatnonzero(masks.any(axis=0))
        miss_mask, io = self._probe_and_read(union)
        data = self.store.fetch(union) if fetch_payload else None
        return PendingStep(masks=masks, union=union, miss_mask=miss_mask,
                           io=io, data=data)

    def complete_step(self, pending: PendingStep,
                      true_masks: Optional[np.ndarray] = None) -> BatchStepResult:
        """Finish a split-phase step, reconciling speculation against truth.

        With `true_masks=None` (or equal to the speculated masks) this is
        exactly the tail of the fused `step_masks`. Otherwise, truly activated
        neurons the speculation missed are probed and served by a synchronous
        top-up read — NEVER skipped — and the merged stats cover everything
        the device actually did (both reads, both probes). Admission happens
        once over all missed neurons, exactly like a fused step over the same
        set. Per-request attribution bills the combined read time by each
        request's share of truly-requested misses, so `req_io_seconds` sums
        exactly to `merged.io.seconds`; speculative over-reads that no request
        wanted are split evenly (they are the speculation's cost, not any one
        request's).
        """
        spec_miss = pending.union[pending.miss_mask]
        io, run_lengths = pending.io, pending.io.run_lengths
        n_spec_hits = int(pending.union.size) - int(spec_miss.size)
        if true_masks is None:
            masks = pending.masks
            extra = topup_miss = np.zeros(0, dtype=np.int64)
            n_extra_hits = 0
        else:
            masks = np.atleast_2d(np.asarray(true_masks, dtype=bool))
            true_union = np.flatnonzero(masks.any(axis=0))
            extra = np.setdiff1d(true_union, pending.union, assume_unique=True)
            topup_miss = np.zeros(0, dtype=np.int64)
            n_extra_hits = 0
            if extra.size:                       # lookahead under-prediction
                hit2 = self.cache.lookup_mask(extra)
                topup_miss = extra[~hit2]
                n_extra_hits = int(np.count_nonzero(hit2))
                if topup_miss.size:              # synchronous top-up read
                    with get_tracer().span("topup") as sp:
                        _, io2 = self.reader.read(topup_miss,
                                                  fetch_payload=False)
                        if self.cfg.emulate_read_latency:
                            time.sleep(io2.seconds)
                        sp.set(n_topup=int(topup_miss.size),
                               extents=int(io2.n_ops), modeled_s=io2.seconds,
                               measured_s=io2.measured_seconds)
                    io = dataclasses.replace(io)  # don't mutate the pending copy
                    io.add(io2)
                    run_lengths = np.concatenate([run_lengths, io2.run_lengths])
        all_miss = (np.concatenate([spec_miss, topup_miss]) if topup_miss.size
                    else spec_miss)
        served = int(pending.union.size) + int(extra.size)
        merged = self._admit_and_record(
            served, served - n_spec_hits - n_extra_hits, all_miss, io,
            run_lengths)
        sizes = masks.sum(axis=1, dtype=np.int64)
        # per-request misses: each request's truly-activated neurons that the
        # device had to read this step (speculated or topped up)
        if all_miss.size:
            miss_cols = np.sort(all_miss)
            miss_counts = masks[:, miss_cols].sum(axis=1, dtype=np.int64)
        else:
            miss_counts = np.zeros(masks.shape[0], dtype=np.int64)
        ids = (np.sort(np.concatenate([pending.union, extra])) if extra.size
               else pending.union)
        # keep the documented data contract ([len(ids), w] in ids order) when
        # the begin phase fetched a payload that top-ups have since widened
        data = (self.store.fetch(ids) if pending.data is not None and extra.size
                else pending.data)
        res = self._attributed_result(ids, data, merged, sizes, miss_counts)
        res.topup_ids = extra
        res.n_speculated = int(pending.union.size)
        return res

    def _attributed_result(self, union: np.ndarray, data: Optional[np.ndarray],
                           merged: TokenStats, sizes: np.ndarray,
                           miss_counts: np.ndarray) -> BatchStepResult:
        total_missed = int(miss_counts.sum())
        if total_missed:
            shares = miss_counts / total_missed
        elif merged.io.seconds > 0:
            # pure over-speculation: bytes were read but no request asked for
            # them — split the read time evenly so attribution still sums
            # exactly to the merged read
            shares = np.full(len(miss_counts), 1.0 / max(len(miss_counts), 1))
        else:
            shares = np.zeros(len(miss_counts))
        return BatchStepResult(
            ids=union, data=data, merged=merged,
            req_n_activated=sizes,
            req_n_misses=miss_counts,
            req_io_seconds=merged.io.seconds * shares,
            req_bytes_useful=(miss_counts * self.store.bundle_bytes
                              * self.store.reads_per_bundle),
        )

    # ------------------------------------------------------------------
    def run_trace(self, masks: Sequence[np.ndarray]) -> List[TokenStats]:
        """Serve a [T, n] activation-mask trace; returns per-token stats."""
        out = []
        for mask in np.atleast_2d(np.asarray(masks)):
            ids = np.nonzero(mask)[0]
            _, ts = self.step(ids)
            out.append(ts)
        return out

    # -- aggregate metrics (paper's reporting) --------------------------
    def summary(self) -> dict:
        io_s = sum(t.io.seconds for t in self.history)
        ops = sum(t.io.n_ops for t in self.history)
        useful = sum(t.io.bytes_useful for t in self.history)
        read = sum(t.io.bytes_read for t in self.history)
        n_tok = max(len(self.history), 1)
        runs = (np.concatenate([np.asarray(t.run_lengths) for t in self.history])
                if self.history else np.zeros(0, dtype=np.int64))
        return dict(
            tokens=len(self.history),
            io_seconds_per_token=io_s / n_tok,
            iops=ops / io_s if io_s else 0.0,
            ops_per_token=ops / n_tok,
            effective_bandwidth=useful / io_s if io_s else 0.0,
            raw_bandwidth=read / io_s if io_s else 0.0,
            waste_ratio=(1.0 - useful / read) if read else 0.0,
            cache_hit_rate=self.cache.stats.hit_rate,
            mean_run_length=float(np.mean(runs)) if runs.size else 0.0,
            max_run_length=int(np.max(runs)) if runs.size else 0,
        )

    def reset_stats(self) -> None:
        self.history.clear()
