"""OffloadEngine — the paper's online serving pipeline for one FFN block.

Per token: predict activated neurons -> probe DRAM cache -> plan reads over the
flash layout (with access collapse) -> simulated-UFS read -> admit into cache
(linking-aligned) -> compute the sparse FFN from the bundles actually read.

The engine is deliberately deterministic and fully instrumented: every paper
figure (latency, IOPS, effective bandwidth, run lengths, cache behaviour) is
derived from `TokenStats` streams produced here.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cache import LinkingAlignedCache
from repro.core.collapse import runs_from_positions
from repro.core.placement import PlacementResult, identity_placement
from repro.core.storage import IOStats, ManagedReader, NeuronStore, UFSDevice


@dataclasses.dataclass
class TokenStats:
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io: IOStats = dataclasses.field(default_factory=IOStats)
    run_lengths: List[int] = dataclasses.field(default_factory=list)

    @property
    def io_seconds(self) -> float:
        return self.io.seconds


@dataclasses.dataclass
class EngineConfig:
    cache_ratio: float = 0.1          # fraction of neurons resident in DRAM
    collapse: bool = True             # paper §5.1
    linking_aligned_cache: bool = True  # paper §5.2
    reads_per_bundle: int = 1         # 1 = bundled (LLMFlash/RIPPLE); n_mats = llama.cpp
    initial_collapse_threshold: int = 4
    segment_min_len: int = 4
    segment_admit_p: float = 0.25


class OffloadEngine:
    """Flash-offloaded sparse-FFN serving for one FFN block."""

    def __init__(
        self,
        bundles: np.ndarray,                       # [n_neurons, bundle_width]
        placement: Optional[PlacementResult] = None,
        device: Optional[UFSDevice] = None,
        config: Optional[EngineConfig] = None,
        bundle_bytes: Optional[int] = None,
    ) -> None:
        self.cfg = config or EngineConfig()
        n = bundles.shape[0]
        self.placement = placement or identity_placement(n)
        self.store = NeuronStore(
            bundles, self.placement, device or UFSDevice(),
            reads_per_bundle=self.cfg.reads_per_bundle,
            bundle_bytes=bundle_bytes,
        )
        self.reader = ManagedReader(
            self.store,
            adaptive=self.cfg.collapse,
            initial_threshold=self.cfg.initial_collapse_threshold,
        )
        self.cache = LinkingAlignedCache(
            capacity=int(self.cfg.cache_ratio * n),
            segment_min_len=self.cfg.segment_min_len,
            segment_admit_p=self.cfg.segment_admit_p,
            linking_aligned=self.cfg.linking_aligned_cache,
        )
        self.history: List[TokenStats] = []

    # ------------------------------------------------------------------
    def step(self, activated_ids: np.ndarray) -> tuple[np.ndarray, TokenStats]:
        """Serve one token's activated-neuron set; returns (bundle data, stats).

        Returned bundles are in `activated_ids` order (cache hits are served
        from DRAM at zero I/O cost; the payload is identical either way).
        """
        ids = np.unique(np.asarray(activated_ids, dtype=np.int64))
        ts = TokenStats(n_activated=int(ids.size))
        hits, misses = self.cache.lookup(ids)
        ts.n_hits, ts.n_misses = int(hits.size), int(misses.size)
        if misses.size:
            _, io = self.reader.read(misses)
            ts.io = io
            phys = self.placement.physical_of(misses)
            ts.run_lengths = [l for _, l in runs_from_positions(phys)]
            self.cache.admit(misses, phys)
        # payload for *all* activated neurons (hits came from DRAM)
        data = self.store._phys_data[self.placement.physical_of(ids)]
        self.history.append(ts)
        return data, ts

    # ------------------------------------------------------------------
    def run_trace(self, masks: Sequence[np.ndarray]) -> List[TokenStats]:
        """Serve a [T, n] activation-mask trace; returns per-token stats."""
        out = []
        for mask in np.atleast_2d(np.asarray(masks)):
            ids = np.nonzero(mask)[0]
            _, ts = self.step(ids)
            out.append(ts)
        return out

    # -- aggregate metrics (paper's reporting) --------------------------
    def summary(self) -> dict:
        io_s = sum(t.io.seconds for t in self.history)
        ops = sum(t.io.n_ops for t in self.history)
        useful = sum(t.io.bytes_useful for t in self.history)
        read = sum(t.io.bytes_read for t in self.history)
        n_tok = max(len(self.history), 1)
        runs = [l for t in self.history for l in t.run_lengths]
        return dict(
            tokens=len(self.history),
            io_seconds_per_token=io_s / n_tok,
            iops=ops / io_s if io_s else 0.0,
            ops_per_token=ops / n_tok,
            effective_bandwidth=useful / io_s if io_s else 0.0,
            raw_bandwidth=read / io_s if io_s else 0.0,
            waste_ratio=(1.0 - useful / read) if read else 0.0,
            cache_hit_rate=self.cache.stats.hit_rate,
            mean_run_length=float(np.mean(runs)) if runs else 0.0,
            max_run_length=int(np.max(runs)) if runs else 0,
        )

    def reset_stats(self) -> None:
        self.history.clear()
