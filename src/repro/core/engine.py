"""OffloadEngine — the paper's online serving pipeline for one FFN block.

Per token: predict activated neurons -> probe DRAM cache -> plan reads over the
flash layout (with access collapse) -> simulated-UFS read -> admit into cache
(linking-aligned) -> compute the sparse FFN from the bundles actually read.

Two serving granularities:
  * `step(ids)`       — one activated set (one token / one request);
  * `step_batch(ids_per_request)` — one decode *batch*: the activated sets of
    all requests are merged, the cache is probed once, and all misses are
    served by a single collapsed extent read (shared neurons are read once —
    the batching win). Per-request hit/miss/I/O attribution comes back as
    `RequestStats` so the serving engine can bill each request.

The engine is deliberately deterministic and fully instrumented: every paper
figure (latency, IOPS, effective bandwidth, run lengths, cache behaviour) is
derived from `TokenStats` streams produced here.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cache import LinkingAlignedCache
from repro.core.collapse import runs_from_positions
from repro.core.placement import PlacementResult
from repro.core.storage import IOStats, ManagedReader, NeuronStore, UFSDevice


@dataclasses.dataclass
class TokenStats:
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io: IOStats = dataclasses.field(default_factory=IOStats)
    run_lengths: List[int] = dataclasses.field(default_factory=list)

    @property
    def io_seconds(self) -> float:
        return self.io.seconds


@dataclasses.dataclass
class RequestStats:
    """Per-request attribution of one batched engine step.

    The device performs ONE merged read; each request is billed a share of
    the read TIME proportional to the misses it asked for, so `io_seconds`
    always sums to exactly the merged read. A neuron missed by several
    requests splits its time cost among them — that split IS the batching
    saving, vs. each request paying for its own read in the unbatched loop.
    `bytes_useful` is different on purpose: it counts the bytes a request
    asked to have read (its own missed bundles), so summing it across
    requests double-counts shared neurons — compare it against
    `merged.io.bytes_useful` to measure exactly that sharing."""
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io_seconds: float = 0.0
    bytes_useful: int = 0


@dataclasses.dataclass
class BatchStepResult:
    """Result of one `step_batch`: merged payload + stats at both granularities."""
    ids: np.ndarray                     # union of activated ids, sorted unique
    data: np.ndarray                    # [len(ids), bundle_width] payloads
    merged: TokenStats                  # what the device actually did (1 read)
    per_request: List[RequestStats]     # attribution, len == n requests

    def rows_for(self, request_ids: np.ndarray) -> np.ndarray:
        """Row indices into `data` for one request's activated ids."""
        return np.searchsorted(self.ids, np.unique(np.asarray(request_ids,
                                                              dtype=np.int64)))


@dataclasses.dataclass
class EngineConfig:
    cache_ratio: float = 0.1          # fraction of neurons resident in DRAM
    collapse: bool = True             # paper §5.1
    linking_aligned_cache: bool = True  # paper §5.2
    reads_per_bundle: int = 1         # 1 = bundled (LLMFlash/RIPPLE); n_mats = llama.cpp
    initial_collapse_threshold: int = 4
    segment_min_len: int = 4
    segment_admit_p: float = 0.25


class OffloadEngine:
    """Flash-offloaded sparse-FFN serving for one FFN block."""

    def __init__(
        self,
        bundles: Optional[np.ndarray] = None,      # [n_neurons, bundle_width]
        placement: Optional[PlacementResult] = None,
        device: Optional[UFSDevice] = None,
        config: Optional[EngineConfig] = None,
        bundle_bytes: Optional[int] = None,
        *,
        store: Optional[NeuronStore] = None,
    ) -> None:
        """Either pass raw `bundles` (+ optional placement/device, defaulted by
        `NeuronStore` — the single constructor path) or a prebuilt `store`.
        The engine never re-defaults placement/device itself: `self.placement`
        and the device model are always the store's."""
        if store is None:
            self.cfg = config or EngineConfig()
            if bundles is None:
                raise ValueError("OffloadEngine needs `bundles` or `store`")
            store = NeuronStore(
                bundles, placement, device,
                reads_per_bundle=self.cfg.reads_per_bundle,
                bundle_bytes=bundle_bytes,
            )
        else:
            if any(a is not None for a in (bundles, placement, device, bundle_bytes)):
                raise ValueError(
                    "pass either a prebuilt `store` or raw bundles/placement/"
                    "device/bundle_bytes, not both — the store already fixes them")
            if config is None:   # adopt the store's layout cost model
                self.cfg = dataclasses.replace(
                    EngineConfig(), reads_per_bundle=store.reads_per_bundle)
            elif config.reads_per_bundle != store.reads_per_bundle:
                raise ValueError(
                    f"config.reads_per_bundle={config.reads_per_bundle} "
                    f"conflicts with store.reads_per_bundle={store.reads_per_bundle}")
            else:
                self.cfg = config
        self.store = store
        self.placement = store.placement
        self.reader = ManagedReader(
            self.store,
            adaptive=self.cfg.collapse,
            initial_threshold=self.cfg.initial_collapse_threshold,
        )
        self.cache = LinkingAlignedCache(
            capacity=int(self.cfg.cache_ratio * store.n_neurons),
            segment_min_len=self.cfg.segment_min_len,
            segment_admit_p=self.cfg.segment_admit_p,
            linking_aligned=self.cfg.linking_aligned_cache,
        )
        self.history: List[TokenStats] = []

    @classmethod
    def from_store(cls, store: NeuronStore,
                   config: Optional[EngineConfig] = None) -> "OffloadEngine":
        return cls(store=store, config=config)

    # ------------------------------------------------------------------
    def step(self, activated_ids: np.ndarray) -> tuple[np.ndarray, TokenStats]:
        """Serve one token's activated-neuron set; returns (bundle data, stats).

        Returned bundles are in `activated_ids` order (cache hits are served
        from DRAM at zero I/O cost; the payload is identical either way).
        """
        ids = np.unique(np.asarray(activated_ids, dtype=np.int64))
        ts = TokenStats(n_activated=int(ids.size))
        hits, misses = self.cache.lookup(ids)
        ts.n_hits, ts.n_misses = int(hits.size), int(misses.size)
        if misses.size:
            _, io = self.reader.read(misses)
            ts.io = io
            phys = self.placement.physical_of(misses)
            ts.run_lengths = [l for _, l in runs_from_positions(phys)]
            self.cache.admit(misses, phys)
        # payload for *all* activated neurons (hits came from DRAM)
        data = self.store.fetch(ids)
        self.history.append(ts)
        return data, ts

    # ------------------------------------------------------------------
    def step_batch(self, ids_per_request: Sequence[np.ndarray]) -> BatchStepResult:
        """Serve one decode step for a whole batch of requests.

        Activated sets are merged across requests, the cache is probed once
        per unique neuron, and all misses go out as ONE collapsed extent read
        — a neuron wanted by several requests is read (and billed to the
        device) once. `history` records the merged step, so `summary()`
        reflects real device activity; per-request attribution (hits, misses,
        proportional share of the read time) rides along in the result.
        """
        id_sets = [np.unique(np.asarray(ids, dtype=np.int64))
                   for ids in ids_per_request]
        union = (np.unique(np.concatenate(id_sets)) if id_sets
                 else np.zeros((0,), dtype=np.int64))
        merged = TokenStats(n_activated=int(union.size))
        hits, misses = self.cache.lookup(union)
        merged.n_hits, merged.n_misses = int(hits.size), int(misses.size)
        if misses.size:
            _, io = self.reader.read(misses)
            merged.io = io
            phys = self.placement.physical_of(misses)
            merged.run_lengths = [l for _, l in runs_from_positions(phys)]
            self.cache.admit(misses, phys)
        data = self.store.fetch(union)
        self.history.append(merged)

        miss_counts = [int(np.isin(ids, misses, assume_unique=True).sum())
                       for ids in id_sets]
        total_requested_misses = sum(miss_counts)
        per_request = []
        for ids, n_miss in zip(id_sets, miss_counts):
            share = (n_miss / total_requested_misses
                     if total_requested_misses else 0.0)
            per_request.append(RequestStats(
                n_activated=int(ids.size),
                n_hits=int(ids.size) - n_miss,
                n_misses=n_miss,
                io_seconds=merged.io.seconds * share,
                bytes_useful=n_miss * self.store.bundle_bytes
                             * self.store.reads_per_bundle,
            ))
        return BatchStepResult(ids=union, data=data, merged=merged,
                               per_request=per_request)

    # ------------------------------------------------------------------
    def run_trace(self, masks: Sequence[np.ndarray]) -> List[TokenStats]:
        """Serve a [T, n] activation-mask trace; returns per-token stats."""
        out = []
        for mask in np.atleast_2d(np.asarray(masks)):
            ids = np.nonzero(mask)[0]
            _, ts = self.step(ids)
            out.append(ts)
        return out

    # -- aggregate metrics (paper's reporting) --------------------------
    def summary(self) -> dict:
        io_s = sum(t.io.seconds for t in self.history)
        ops = sum(t.io.n_ops for t in self.history)
        useful = sum(t.io.bytes_useful for t in self.history)
        read = sum(t.io.bytes_read for t in self.history)
        n_tok = max(len(self.history), 1)
        runs = [l for t in self.history for l in t.run_lengths]
        return dict(
            tokens=len(self.history),
            io_seconds_per_token=io_s / n_tok,
            iops=ops / io_s if io_s else 0.0,
            ops_per_token=ops / n_tok,
            effective_bandwidth=useful / io_s if io_s else 0.0,
            raw_bandwidth=read / io_s if io_s else 0.0,
            waste_ratio=(1.0 - useful / read) if read else 0.0,
            cache_hit_rate=self.cache.stats.hit_rate,
            mean_run_length=float(np.mean(runs)) if runs else 0.0,
            max_run_length=int(np.max(runs)) if runs else 0,
        )

    def reset_stats(self) -> None:
        self.history.clear()
