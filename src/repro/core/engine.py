"""OffloadEngine — the paper's online serving pipeline for one FFN block.

Per token: predict activated neurons -> probe DRAM cache -> plan reads over the
flash layout (with access collapse) -> simulated-UFS read -> admit into cache
(linking-aligned) -> compute the sparse FFN from the bundles actually read.

Three serving granularities:
  * `step(ids)`       — one activated set (one token / one request);
  * `step_batch(ids_per_request)` — one decode *batch* from per-request id
    arrays: the activated sets of all requests are merged, the cache is
    probed once, and all misses are served by a single collapsed extent read
    (shared neurons are read once — the batching win);
  * `step_masks(masks)` — same batched step but straight from the [B, n]
    boolean activation-mask matrix the predictor produces. This is the
    serving hot path: the union, the per-request attribution
    (searchsorted/bincount-style), and the run statistics are all computed
    with array ops — no per-request or per-neuron Python iteration.

Per-request attribution comes back columnar in `BatchStepResult`
(`req_io_seconds` etc.); `per_request` materialises the `RequestStats` view
on demand for reporting code.

The engine is deliberately deterministic and fully instrumented: every paper
figure (latency, IOPS, effective bandwidth, run lengths, cache behaviour) is
derived from `TokenStats` streams produced here.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cache import make_linking_aligned_cache
from repro.core.placement import PlacementResult
from repro.core.storage import IOStats, ManagedReader, NeuronStore, UFSDevice


@dataclasses.dataclass
class TokenStats:
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io: IOStats = dataclasses.field(default_factory=IOStats)
    run_lengths: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))

    @property
    def io_seconds(self) -> float:
        return self.io.seconds


@dataclasses.dataclass
class RequestStats:
    """Per-request attribution of one batched engine step.

    The device performs ONE merged read; each request is billed a share of
    the read TIME proportional to the misses it asked for, so `io_seconds`
    always sums to exactly the merged read. A neuron missed by several
    requests splits its time cost among them — that split IS the batching
    saving, vs. each request paying for its own read in the unbatched loop.
    `bytes_useful` is different on purpose: it counts the bytes a request
    asked to have read (its own missed bundles), so summing it across
    requests double-counts shared neurons — compare it against
    `merged.io.bytes_useful` to measure exactly that sharing."""
    n_activated: int = 0
    n_hits: int = 0
    n_misses: int = 0
    io_seconds: float = 0.0
    bytes_useful: int = 0


@dataclasses.dataclass
class BatchStepResult:
    """Result of one batched step: merged payload + stats at both granularities.

    Per-request attribution is stored columnar (one array per field, row =
    request) so the serving engine can consume it without constructing
    per-request Python objects; `per_request` builds the object view lazily.
    """
    ids: np.ndarray                     # union of activated ids, sorted unique
    data: Optional[np.ndarray]          # [len(ids), bundle_width] payloads
    merged: TokenStats                  # what the device actually did (1 read)
    req_n_activated: np.ndarray         # [R] int
    req_n_misses: np.ndarray            # [R] int
    req_io_seconds: np.ndarray          # [R] float, sums to merged.io.seconds
    req_bytes_useful: np.ndarray        # [R] int

    @property
    def per_request(self) -> List[RequestStats]:
        return [RequestStats(
            n_activated=int(a), n_hits=int(a) - int(m), n_misses=int(m),
            io_seconds=float(s), bytes_useful=int(b))
            for a, m, s, b in zip(self.req_n_activated, self.req_n_misses,
                                  self.req_io_seconds, self.req_bytes_useful)]

    def rows_for(self, request_ids: np.ndarray) -> np.ndarray:
        """Row indices into `data` for one request's activated ids."""
        return np.searchsorted(self.ids, np.unique(np.asarray(request_ids,
                                                              dtype=np.int64)))


@dataclasses.dataclass
class EngineConfig:
    cache_ratio: float = 0.1          # fraction of neurons resident in DRAM
    collapse: bool = True             # paper §5.1
    linking_aligned_cache: bool = True  # paper §5.2
    reads_per_bundle: int = 1         # 1 = bundled (LLMFlash/RIPPLE); n_mats = llama.cpp
    # None anchors the adaptive collapse threshold at the device break-even
    # gap; an explicit value overrides the anchor (clamped to its band)
    initial_collapse_threshold: Optional[int] = None
    segment_min_len: int = 4
    segment_admit_p: float = 0.25
    cache_impl: str = "array"         # "array" (vectorized) | "dict" (reference)


class OffloadEngine:
    """Flash-offloaded sparse-FFN serving for one FFN block."""

    def __init__(
        self,
        bundles: Optional[np.ndarray] = None,      # [n_neurons, bundle_width]
        placement: Optional[PlacementResult] = None,
        device: Optional[UFSDevice] = None,
        config: Optional[EngineConfig] = None,
        bundle_bytes: Optional[int] = None,
        *,
        store: Optional[NeuronStore] = None,
    ) -> None:
        """Either pass raw `bundles` (+ optional placement/device, defaulted by
        `NeuronStore` — the single constructor path) or a prebuilt `store`.
        The engine never re-defaults placement/device itself: `self.placement`
        and the device model are always the store's."""
        if store is None:
            self.cfg = config or EngineConfig()
            if bundles is None:
                raise ValueError("OffloadEngine needs `bundles` or `store`")
            store = NeuronStore(
                bundles, placement, device,
                reads_per_bundle=self.cfg.reads_per_bundle,
                bundle_bytes=bundle_bytes,
            )
        else:
            if any(a is not None for a in (bundles, placement, device, bundle_bytes)):
                raise ValueError(
                    "pass either a prebuilt `store` or raw bundles/placement/"
                    "device/bundle_bytes, not both — the store already fixes them")
            if config is None:   # adopt the store's layout cost model
                self.cfg = dataclasses.replace(
                    EngineConfig(), reads_per_bundle=store.reads_per_bundle)
            elif config.reads_per_bundle != store.reads_per_bundle:
                raise ValueError(
                    f"config.reads_per_bundle={config.reads_per_bundle} "
                    f"conflicts with store.reads_per_bundle={store.reads_per_bundle}")
            else:
                self.cfg = config
        self.store = store
        self.placement = store.placement
        self.reader = ManagedReader(
            self.store,
            adaptive=self.cfg.collapse,
            initial_threshold=self.cfg.initial_collapse_threshold,
        )
        self.cache = make_linking_aligned_cache(
            capacity=int(self.cfg.cache_ratio * store.n_neurons),
            n_keys=store.n_neurons,
            segment_min_len=self.cfg.segment_min_len,
            segment_admit_p=self.cfg.segment_admit_p,
            linking_aligned=self.cfg.linking_aligned_cache,
            impl=self.cfg.cache_impl,
        )
        self.history: List[TokenStats] = []

    @classmethod
    def from_store(cls, store: NeuronStore,
                   config: Optional[EngineConfig] = None) -> "OffloadEngine":
        return cls(store=store, config=config)

    # ------------------------------------------------------------------
    def _serve_union(self, union: np.ndarray) -> tuple[TokenStats, np.ndarray]:
        """Probe + read + admit for one sorted-unique activated set; returns
        (merged TokenStats, miss mask over `union`)."""
        ts = TokenStats(n_activated=int(union.size))
        hit_mask = self.cache.lookup_mask(union)
        n_hits = int(np.count_nonzero(hit_mask))
        ts.n_hits, ts.n_misses = n_hits, int(union.size) - n_hits
        miss_mask = ~hit_mask
        misses = union[miss_mask]
        if misses.size:
            _, io = self.reader.read(misses)
            ts.io = io
            ts.run_lengths = io.run_lengths
            self.cache.admit(misses, self.placement.physical_of(misses))
        self.history.append(ts)
        return ts, miss_mask

    def step(self, activated_ids: np.ndarray) -> tuple[np.ndarray, TokenStats]:
        """Serve one token's activated-neuron set; returns (bundle data, stats).

        Returned bundles are in `activated_ids` order (cache hits are served
        from DRAM at zero I/O cost; the payload is identical either way).
        """
        ids = np.unique(np.asarray(activated_ids, dtype=np.int64))
        ts, _ = self._serve_union(ids)
        # payload for *all* activated neurons (hits came from DRAM)
        data = self.store.fetch(ids)
        return data, ts

    # ------------------------------------------------------------------
    def step_batch(self, ids_per_request: Sequence[np.ndarray]) -> BatchStepResult:
        """Serve one decode step for a whole batch of requests.

        Activated sets are merged across requests, the cache is probed once
        per unique neuron, and all misses go out as ONE collapsed extent read
        — a neuron wanted by several requests is read (and billed to the
        device) once. `history` records the merged step, so `summary()`
        reflects real device activity; per-request attribution (hits, misses,
        proportional share of the read time) is one searchsorted + bincount
        over the concatenated id sets.
        """
        id_sets = [np.unique(np.asarray(ids, dtype=np.int64))
                   for ids in ids_per_request]
        all_ids = (np.concatenate(id_sets) if id_sets
                   else np.zeros((0,), dtype=np.int64))
        union = np.unique(all_ids)
        merged, miss_mask = self._serve_union(union)
        # per-request attribution: locate every requested id in the union,
        # look up its hit/miss status, and histogram by request
        sizes = np.array([s.size for s in id_sets], dtype=np.int64)
        req_of = np.repeat(np.arange(len(id_sets)), sizes)
        is_miss = (miss_mask[np.searchsorted(union, all_ids)] if all_ids.size
                   else np.zeros(0, dtype=bool))
        miss_counts = np.bincount(req_of, weights=is_miss,
                                  minlength=len(id_sets)).astype(np.int64)
        data = self.store.fetch(union)
        return self._attributed_result(union, data, merged, sizes, miss_counts)

    def step_masks(self, masks: np.ndarray,
                   fetch_payload: bool = True) -> BatchStepResult:
        """`step_batch` straight from the [B, n_neurons] bool mask matrix.

        The union and the per-request miss counts come from column/row
        reductions of the mask matrix — the decode inner loop never
        materialises per-request id lists. With `fetch_payload=False` the
        caller gathers payloads itself (e.g. into a reused staging buffer
        via `NeuronStore.fetch_into`) and `result.data` is None.
        """
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        union = np.flatnonzero(masks.any(axis=0))
        merged, miss_mask = self._serve_union(union)
        miss_counts = masks[:, union[miss_mask]].sum(axis=1, dtype=np.int64)
        sizes = masks.sum(axis=1, dtype=np.int64)
        data = self.store.fetch(union) if fetch_payload else None
        return self._attributed_result(union, data, merged, sizes, miss_counts)

    def _attributed_result(self, union: np.ndarray, data: Optional[np.ndarray],
                           merged: TokenStats, sizes: np.ndarray,
                           miss_counts: np.ndarray) -> BatchStepResult:
        total_missed = int(miss_counts.sum())
        shares = (miss_counts / total_missed) if total_missed else \
            np.zeros(len(miss_counts))
        return BatchStepResult(
            ids=union, data=data, merged=merged,
            req_n_activated=sizes,
            req_n_misses=miss_counts,
            req_io_seconds=merged.io.seconds * shares,
            req_bytes_useful=(miss_counts * self.store.bundle_bytes
                              * self.store.reads_per_bundle),
        )

    # ------------------------------------------------------------------
    def run_trace(self, masks: Sequence[np.ndarray]) -> List[TokenStats]:
        """Serve a [T, n] activation-mask trace; returns per-token stats."""
        out = []
        for mask in np.atleast_2d(np.asarray(masks)):
            ids = np.nonzero(mask)[0]
            _, ts = self.step(ids)
            out.append(ts)
        return out

    # -- aggregate metrics (paper's reporting) --------------------------
    def summary(self) -> dict:
        io_s = sum(t.io.seconds for t in self.history)
        ops = sum(t.io.n_ops for t in self.history)
        useful = sum(t.io.bytes_useful for t in self.history)
        read = sum(t.io.bytes_read for t in self.history)
        n_tok = max(len(self.history), 1)
        runs = (np.concatenate([np.asarray(t.run_lengths) for t in self.history])
                if self.history else np.zeros(0, dtype=np.int64))
        return dict(
            tokens=len(self.history),
            io_seconds_per_token=io_s / n_tok,
            iops=ops / io_s if io_s else 0.0,
            ops_per_token=ops / n_tok,
            effective_bandwidth=useful / io_s if io_s else 0.0,
            raw_bandwidth=read / io_s if io_s else 0.0,
            waste_ratio=(1.0 - useful / read) if read else 0.0,
            cache_hit_rate=self.cache.stats.hit_rate,
            mean_run_length=float(np.mean(runs)) if runs.size else 0.0,
            max_run_length=int(np.max(runs)) if runs.size else 0,
        )

    def reset_stats(self) -> None:
        self.history.clear()
