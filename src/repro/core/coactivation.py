"""Neuron co-activation statistics (paper §4.1, Eq. 1-2).

Records activation frequencies f(n_i) and co-activation frequencies f(n_i, n_j)
from FFN activation-mask traces, and exposes the probabilities P(i), P(ij) and the
distance dist(i, j) = 1 - P(ij) (Eq. 3) used by the placement search.

Neuron *bundles* (the paper's row-column bundling unit: the gate/up rows + down
column activated by the same intermediate value) are the unit of accounting — one
"neuron" here is one bundle.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class CoActivationStats:
    """Accumulates the adjacency (co-activation count) matrix for one FFN block.

    Memory: the dense pair-count matrix is float32 [n, n]; for the largest model
    in the paper (n=43008) this is ~7.4 GB, so large-n users should accumulate
    per-layer (layers are independent, as the paper parallelises over layers).
    """

    n_neurons: int

    def __post_init__(self) -> None:
        self.counts = np.zeros(self.n_neurons, dtype=np.int64)
        self.pair_counts = np.zeros((self.n_neurons, self.n_neurons), dtype=np.float32)
        self.n_tokens = 0

    def update(self, masks: np.ndarray) -> None:
        """masks: [T, n] bool/0-1 activation mask for T tokens."""
        masks = np.asarray(masks)
        if masks.ndim == 1:
            masks = masks[None]
        if masks.shape[-1] != self.n_neurons:
            raise ValueError(f"mask width {masks.shape[-1]} != n_neurons {self.n_neurons}")
        m = masks.astype(np.float32)
        self.counts += masks.astype(np.int64).sum(axis=0)
        # A += M^T M — co-activation outer-product accumulation. This is the
        # offline hot spot; kernels/coact.py provides the Pallas-TPU version.
        self.pair_counts += m.T @ m
        self.n_tokens += masks.shape[0]

    # -- probabilities (Eq. 1, 2) -------------------------------------------
    def p_single(self) -> np.ndarray:
        total = self.counts.sum()
        if total == 0:
            return np.zeros(self.n_neurons)
        return self.counts / total

    def p_pair(self) -> np.ndarray:
        total = self.pair_counts.sum()
        if total == 0:
            return np.zeros_like(self.pair_counts)
        return self.pair_counts / total

    # -- distances (Eq. 3) ---------------------------------------------------
    def distance_matrix(self) -> np.ndarray:
        """dist(i, j) = 1 - P(ij); diagonal is +inf (no self edges)."""
        d = 1.0 - self.p_pair()
        np.fill_diagonal(d, np.inf)
        return d

    def activation_rate(self) -> np.ndarray:
        """Per-neuron empirical activation probability (per token)."""
        if self.n_tokens == 0:
            return np.zeros(self.n_neurons)
        return self.counts / self.n_tokens

    def merge(self, other: "CoActivationStats",
              inplace: bool = False) -> "CoActivationStats":
        """Combine two accumulators. `inplace=True` folds `other` into `self`
        (and returns self) without allocating a third [n, n] pair matrix —
        what the shard-streaming path uses to keep one running matrix."""
        if other.n_neurons != self.n_neurons:
            raise ValueError("cannot merge stats of different widths")
        if inplace:
            self.counts += other.counts
            self.pair_counts += other.pair_counts
            self.n_tokens += other.n_tokens
            return self
        out = CoActivationStats(self.n_neurons)
        out.counts = self.counts + other.counts
        out.pair_counts = self.pair_counts + other.pair_counts
        out.n_tokens = self.n_tokens + other.n_tokens
        return out


def stats_from_masks(masks: np.ndarray) -> CoActivationStats:
    s = CoActivationStats(masks.shape[-1])
    s.update(masks)
    return s


def stats_from_mask_shards(shards: Iterable[np.ndarray],
                           n_neurons: Optional[int] = None) -> CoActivationStats:
    """`stats_from_masks` over a shard iterator (traces larger than RAM).

    Each shard is accumulated into its own `CoActivationStats` and folded in
    via `CoActivationStats.merge(inplace=True)`, so only one shard's masks,
    its [n, n] pair matrix, and the single running pair matrix are resident
    at a time — the entry point the offline packer uses with
    `repro.core.trace.iter_trace_shards`. An empty iterator needs
    `n_neurons` to size the (zero) stats.
    """
    out: Optional[CoActivationStats] = None
    for masks in shards:
        s = stats_from_masks(np.asarray(masks))
        out = s if out is None else out.merge(s, inplace=True)
    if out is None:
        if n_neurons is None:
            raise ValueError("empty shard iterator and no n_neurons given")
        out = CoActivationStats(n_neurons)
    return out


def expected_io_ops(masks: Iterable[np.ndarray], placement: np.ndarray) -> float:
    """Average number of contiguous read runs per token under a placement.

    This is the objective the Hamiltonian-path search minimises (Eq. 4-5): each
    maximal run of activated neurons that is contiguous in the *physical* layout
    costs one I/O op.
    """
    inv = np.empty_like(placement)
    inv[placement] = np.arange(len(placement))
    total_runs = 0
    n_tok = 0
    for mask_block in masks:
        mask_block = np.atleast_2d(np.asarray(mask_block))
        for mask in mask_block:
            ids = np.nonzero(mask)[0]
            if len(ids) == 0:
                continue
            phys = np.sort(inv[ids])
            runs = 1 + int(np.sum(np.diff(phys) > 1))
            total_runs += runs
            n_tok += 1
    return total_runs / max(n_tok, 1)
