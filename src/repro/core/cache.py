"""DRAM neuron cache: S3-FIFO base policy + linking-aligned admission (paper §5.2).

The paper integrates the S3-FIFO cache (Yang et al., SOSP'23) into all baselines
and adds, for RIPPLE, an *admission* layer that distinguishes

  * sporadic neurons — activated with few contiguous neighbours: cached normally;
  * continuous segments — runs of >= `segment_min_len` contiguous (in flash
    layout) activated neurons: admitted with lower probability `segment_admit_p`,
    because caching fragments of a segment punches holes in contiguous flash
    runs (hurting continuity) while whole segments are cheap to re-read anyway.

Only admission changes; eviction/promotion remain S3-FIFO ("we only control the
caching admitting policy, yet leave the other unchanged").
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.utils import stable_uniform


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    admitted: int = 0
    rejected: int = 0
    evicted: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class S3FIFOCache:
    """S3-FIFO: small FIFO (probation), main FIFO, ghost queue of evicted keys.

    Keys are (layer, neuron) tuples or plain ints; capacity in entries.
    """

    def __init__(self, capacity: int, small_ratio: float = 0.1, ghost_ratio: float = 0.9) -> None:
        self.capacity = max(capacity, 0)
        self.small_cap = max(1, int(self.capacity * small_ratio)) if self.capacity else 0
        self.main_cap = self.capacity - self.small_cap
        self.small: "OrderedDict[object, int]" = OrderedDict()   # key -> freq
        self.main: "OrderedDict[object, int]" = OrderedDict()
        self.ghost: "OrderedDict[object, None]" = OrderedDict()
        self.ghost_cap = max(1, int(self.capacity * ghost_ratio)) if self.capacity else 0
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.small or key in self.main

    def __len__(self) -> int:
        return len(self.small) + len(self.main)

    def access(self, key: object) -> bool:
        """Lookup; bumps frequency on hit. Returns hit?"""
        if key in self.small:
            self.small[key] = min(self.small[key] + 1, 3)
            self.stats.hits += 1
            return True
        if key in self.main:
            self.main[key] = min(self.main[key] + 1, 3)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self:
            return
        self.stats.admitted += 1
        if key in self.ghost:
            del self.ghost[key]
            self.main[key] = 0
            self._evict_main()
        else:
            self.small[key] = 0
            self._evict_small()

    def _evict_small(self) -> None:
        while len(self.small) > self.small_cap:
            key, freq = self.small.popitem(last=False)
            if freq > 0:                       # seen again while on probation
                self.main[key] = 0
                self._evict_main()
            else:
                self._ghost_insert(key)
                self.stats.evicted += 1

    def _evict_main(self) -> None:
        while len(self.main) > self.main_cap:
            key, freq = self.main.popitem(last=False)
            if freq > 0:
                self.main[key] = freq - 1       # reinsert at tail, decremented
            else:
                self._ghost_insert(key)
                self.stats.evicted += 1

    def _ghost_insert(self, key: object) -> None:
        self.ghost[key] = None
        while len(self.ghost) > self.ghost_cap:
            self.ghost.popitem(last=False)


class LRUCache:
    """Classic LRU — a weaker baseline than S3-FIFO (paper cites S3-FIFO as
    the strong cache it integrates into all systems; LRU is here for the
    cache-policy ablation benchmark)."""

    def __init__(self, capacity: int) -> None:
        self.capacity = max(capacity, 0)
        self.data: "OrderedDict[object, None]" = OrderedDict()
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.data

    def __len__(self) -> int:
        return len(self.data)

    def access(self, key: object) -> bool:
        if key in self.data:
            self.data.move_to_end(key)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self.data:
            return
        self.stats.admitted += 1
        self.data[key] = None
        while len(self.data) > self.capacity:
            self.data.popitem(last=False)
            self.stats.evicted += 1


class FIFOCache:
    """Plain FIFO — the weakest baseline."""

    def __init__(self, capacity: int) -> None:
        self.capacity = max(capacity, 0)
        self.queue: deque = deque()
        self.members: Set[object] = set()
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.members

    def __len__(self) -> int:
        return len(self.members)

    def access(self, key: object) -> bool:
        if key in self.members:
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self.members:
            return
        self.stats.admitted += 1
        self.queue.append(key)
        self.members.add(key)
        while len(self.queue) > self.capacity:
            self.members.discard(self.queue.popleft())
            self.stats.evicted += 1


class LinkingAlignedCache:
    """S3-FIFO + the paper's linking-aligned admission policy.

    `lookup(ids)` splits activated neuron ids into cache hits and misses;
    `admit(ids, physical_positions)` classifies misses into sporadic neurons vs
    continuous segments and admits segment members with probability
    `segment_admit_p` (deterministic pseudo-random so runs are reproducible).
    """

    def __init__(
        self,
        capacity: int,
        segment_min_len: int = 4,
        segment_admit_p: float = 0.25,
        linking_aligned: bool = True,
        salt: int = 0,
    ) -> None:
        self.cache = S3FIFOCache(capacity)
        self.segment_min_len = segment_min_len
        self.segment_admit_p = segment_admit_p
        self.linking_aligned = linking_aligned
        self.salt = salt
        self._tick = 0

    @property
    def stats(self) -> CacheStats:
        return self.cache.stats

    def lookup(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, dtype=np.int64)
        hit_mask = np.fromiter((self.cache.access(int(i)) for i in ids), dtype=bool, count=len(ids))
        return ids[hit_mask], ids[~hit_mask]

    def classify(self, miss_ids: np.ndarray, physical: np.ndarray) -> Tuple[Set[int], Set[int]]:
        """Split miss ids into (sporadic, segment_members) by run length in flash."""
        order = np.argsort(physical)
        phys_sorted = physical[order]
        ids_sorted = np.asarray(miss_ids, dtype=np.int64)[order]
        sporadic: Set[int] = set()
        segment: Set[int] = set()
        run: List[int] = []

        def flush(run_ids: List[int]) -> None:
            target = segment if len(run_ids) >= self.segment_min_len else sporadic
            target.update(run_ids)

        for k in range(len(ids_sorted)):
            if run and phys_sorted[k] != phys_sorted[k - 1] + 1:
                flush(run)
                run = []
            run.append(int(ids_sorted[k]))
        if run:
            flush(run)
        return sporadic, segment

    def admit(self, miss_ids: np.ndarray, physical: np.ndarray) -> None:
        miss_ids = np.asarray(miss_ids, dtype=np.int64)
        if miss_ids.size == 0:
            return
        self._tick += 1
        if not self.linking_aligned:
            for i in miss_ids:
                self.cache.insert(int(i))
            return
        sporadic, segment = self.classify(miss_ids, np.asarray(physical, dtype=np.int64))
        for i in sporadic:
            self.cache.insert(i)
        for i in segment:
            if stable_uniform(self.salt, self._tick, i) < self.segment_admit_p:
                self.cache.insert(i)
            else:
                self.cache.stats.rejected += 1

    def resident_ids(self) -> np.ndarray:
        keys = list(self.cache.small.keys()) + list(self.cache.main.keys())
        return np.asarray(sorted(int(k) for k in keys), dtype=np.int64)
