"""DRAM neuron cache: S3-FIFO base policy + linking-aligned admission (paper §5.2).

The paper integrates the S3-FIFO cache (Yang et al., SOSP'23) into all baselines
and adds, for RIPPLE, an *admission* layer that distinguishes

  * sporadic neurons — activated with few contiguous neighbours: cached normally;
  * continuous segments — runs of >= `segment_min_len` contiguous (in flash
    layout) activated neurons: admitted with lower probability `segment_admit_p`,
    because caching fragments of a segment punches holes in contiguous flash
    runs (hurting continuity) while whole segments are cheap to re-read anyway.

Only admission changes; eviction/promotion remain S3-FIFO ("we only control the
caching admitting policy, yet leave the other unchanged").

Two implementations of the same policy live here:

  * `LinkingAlignedCache` — the reference oracle: OrderedDict queues, one
    Python iteration per neuron. Easy to audit against the paper, but the
    per-neuron loop dominates the online stage's host time at realistic
    activated-set sizes (thousands of neurons per decode step per layer).
  * `ArrayLinkingAlignedCache` — the array-native hot-path implementation:
    residency/frequency arrays + numpy FIFO queues. `lookup` is one
    fancy-index probe, classification reuses the vectorized run-break logic
    from `collapse`, admission sampling is a single `stable_uniform_array`
    call, and queue maintenance runs as bulk array ops on the (overwhelmingly
    common) no-recycle path, falling back to an exact sequential replay of the
    reference algorithm whenever a batch could hit an order-dependent corner
    (CLOCK recycling in the main queue, ghost overflow racing a ghost hit).
    It is decision-for-decision identical to the reference — same hits,
    misses, admissions, rejections, evictions, and ghost promotions, in the
    same order (tests/test_cache_equivalence.py proves it on random traces).

Admission order is deterministic in both: misses are classified in physical
(flash-layout) order, sporadic neurons are inserted first, then the sampled
segment members — so the two implementations can be compared decision for
decision and reruns are reproducible.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.collapse import run_bounds_from_sorted
from repro.utils import stable_uniform, stable_uniform_array


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    admitted: int = 0
    rejected: int = 0
    evicted: int = 0
    ghost_promotions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class LoopCounters:
    """Per-neuron Python-loop iteration counters.

    The reference implementation bills every per-neuron Python iteration here;
    the array-native implementation must keep all three at zero (its only
    non-vectorized work is the rare exact-replay fallback, counted
    separately per *batch*, and amortized queue maintenance). The CI perf
    smoke asserts the hot-path counters stay zero.
    """
    probe: int = 0        # per-neuron cache-probe iterations (lookup)
    classify: int = 0     # per-neuron run-classification iterations
    sample: int = 0       # per-neuron admission-sampling iterations
    fallback_batches: int = 0   # admit batches replayed sequentially (exactness)
    fallback_inserts: int = 0   # inserts executed inside those replays

    @property
    def per_neuron_total(self) -> int:
        return self.probe + self.classify + self.sample


class S3FIFOCache:
    """S3-FIFO: small FIFO (probation), main FIFO, ghost queue of evicted keys.

    Keys are (layer, neuron) tuples or plain ints; capacity in entries.
    """

    def __init__(self, capacity: int, small_ratio: float = 0.1, ghost_ratio: float = 0.9) -> None:
        self.capacity = max(capacity, 0)
        self.small_cap = max(1, int(self.capacity * small_ratio)) if self.capacity else 0
        self.main_cap = self.capacity - self.small_cap
        self.small: "OrderedDict[object, int]" = OrderedDict()   # key -> freq
        self.main: "OrderedDict[object, int]" = OrderedDict()
        self.ghost: "OrderedDict[object, None]" = OrderedDict()
        self.ghost_cap = max(1, int(self.capacity * ghost_ratio)) if self.capacity else 0
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.small or key in self.main

    def __len__(self) -> int:
        return len(self.small) + len(self.main)

    def access(self, key: object) -> bool:
        """Lookup; bumps frequency on hit. Returns hit?"""
        if key in self.small:
            self.small[key] = min(self.small[key] + 1, 3)
            self.stats.hits += 1
            return True
        if key in self.main:
            self.main[key] = min(self.main[key] + 1, 3)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self:
            return
        self.stats.admitted += 1
        if key in self.ghost:
            del self.ghost[key]
            self.main[key] = 0
            self.stats.ghost_promotions += 1
            self._evict_main()
        else:
            self.small[key] = 0
            self._evict_small()

    def _evict_small(self) -> None:
        while len(self.small) > self.small_cap:
            key, freq = self.small.popitem(last=False)
            if freq > 0:                       # seen again while on probation
                self.main[key] = 0
                self._evict_main()
            else:
                self._ghost_insert(key)
                self.stats.evicted += 1

    def _evict_main(self) -> None:
        while len(self.main) > self.main_cap:
            key, freq = self.main.popitem(last=False)
            if freq > 0:
                self.main[key] = freq - 1       # reinsert at tail, decremented
            else:
                self._ghost_insert(key)
                self.stats.evicted += 1

    def _ghost_insert(self, key: object) -> None:
        self.ghost[key] = None
        while len(self.ghost) > self.ghost_cap:
            self.ghost.popitem(last=False)

    def queues(self) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]], List[int]]:
        """(small [(key, freq)], main [(key, freq)], ghost [key]) in FIFO order
        — the full decision state, for equivalence checks against the
        array-native implementation."""
        small = [(int(k), int(f)) for k, f in self.small.items()]
        main = [(int(k), int(f)) for k, f in self.main.items()]
        return small, main, [int(k) for k in self.ghost.keys()]


class LRUCache:
    """Classic LRU — a weaker baseline than S3-FIFO (paper cites S3-FIFO as
    the strong cache it integrates into all systems; LRU is here for the
    cache-policy ablation benchmark)."""

    def __init__(self, capacity: int) -> None:
        self.capacity = max(capacity, 0)
        self.data: "OrderedDict[object, None]" = OrderedDict()
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.data

    def __len__(self) -> int:
        return len(self.data)

    def access(self, key: object) -> bool:
        if key in self.data:
            self.data.move_to_end(key)
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self.data:
            return
        self.stats.admitted += 1
        self.data[key] = None
        while len(self.data) > self.capacity:
            self.data.popitem(last=False)
            self.stats.evicted += 1


class FIFOCache:
    """Plain FIFO — the weakest baseline."""

    def __init__(self, capacity: int) -> None:
        self.capacity = max(capacity, 0)
        self.queue: deque = deque()
        self.members: Set[object] = set()
        self.stats = CacheStats()

    def __contains__(self, key: object) -> bool:
        return key in self.members

    def __len__(self) -> int:
        return len(self.members)

    def access(self, key: object) -> bool:
        if key in self.members:
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        return False

    def insert(self, key: object) -> None:
        if self.capacity == 0 or key in self.members:
            return
        self.stats.admitted += 1
        self.queue.append(key)
        self.members.add(key)
        while len(self.queue) > self.capacity:
            self.members.discard(self.queue.popleft())
            self.stats.evicted += 1


class LinkingAlignedCache:
    """Reference S3-FIFO + linking-aligned admission (per-neuron Python loops).

    `lookup(ids)` splits activated neuron ids into cache hits and misses;
    `admit(ids, physical_positions)` classifies misses into sporadic neurons vs
    continuous segments and admits segment members with probability
    `segment_admit_p` (deterministic pseudo-random so runs are reproducible).

    Kept as the decision oracle for `ArrayLinkingAlignedCache`; the serving
    engine uses the array-native implementation by default.
    """

    def __init__(
        self,
        capacity: int,
        segment_min_len: int = 4,
        segment_admit_p: float = 0.25,
        linking_aligned: bool = True,
        salt: int = 0,
    ) -> None:
        self.cache = S3FIFOCache(capacity)
        self.segment_min_len = segment_min_len
        self.segment_admit_p = segment_admit_p
        self.linking_aligned = linking_aligned
        self.salt = salt
        self._tick = 0
        self.loop_counters = LoopCounters()

    @property
    def stats(self) -> CacheStats:
        return self.cache.stats

    def lookup_mask(self, ids: np.ndarray) -> np.ndarray:
        """Boolean hit mask over `ids` (in input order); bumps hit frequencies."""
        ids = np.asarray(ids, dtype=np.int64)
        self.loop_counters.probe += int(ids.size)
        return np.fromiter((self.cache.access(int(i)) for i in ids),
                           dtype=bool, count=len(ids))

    def peek_mask(self, ids: np.ndarray) -> np.ndarray:
        """Side-effect-free residency probe: same mask as `lookup_mask` would
        return, but no hit/miss counters, frequencies, or queue state move.
        The admission predictor (serving/server.py) uses this to cost a step
        without perturbing the cache it is predicting."""
        ids = np.asarray(ids, dtype=np.int64)
        return np.fromiter((int(i) in self.cache for i in ids),
                           dtype=bool, count=len(ids))

    def lookup(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, dtype=np.int64)
        hit_mask = self.lookup_mask(ids)
        return ids[hit_mask], ids[~hit_mask]

    def _classify_ordered(self, miss_ids: np.ndarray,
                          physical: np.ndarray) -> Tuple[List[int], List[int]]:
        """(sporadic, segment_members) as lists in physical-layout order."""
        order = np.argsort(physical)
        phys_sorted = physical[order]
        ids_sorted = np.asarray(miss_ids, dtype=np.int64)[order]
        sporadic: List[int] = []
        segment: List[int] = []
        run: List[int] = []

        def flush(run_ids: List[int]) -> None:
            target = segment if len(run_ids) >= self.segment_min_len else sporadic
            target.extend(run_ids)

        for k in range(len(ids_sorted)):
            self.loop_counters.classify += 1
            if run and phys_sorted[k] != phys_sorted[k - 1] + 1:
                flush(run)
                run = []
            run.append(int(ids_sorted[k]))
        if run:
            flush(run)
        return sporadic, segment

    def classify(self, miss_ids: np.ndarray, physical: np.ndarray) -> Tuple[Set[int], Set[int]]:
        """Split miss ids into (sporadic, segment_members) by run length in flash."""
        sporadic, segment = self._classify_ordered(miss_ids, physical)
        return set(sporadic), set(segment)

    def admit(self, miss_ids: np.ndarray, physical: np.ndarray) -> None:
        miss_ids = np.asarray(miss_ids, dtype=np.int64)
        if miss_ids.size == 0:
            return
        self._tick += 1
        if not self.linking_aligned:
            for i in miss_ids:
                self.cache.insert(int(i))
            return
        sporadic, segment = self._classify_ordered(
            miss_ids, np.asarray(physical, dtype=np.int64))
        for i in sporadic:
            self.cache.insert(i)
        for i in segment:
            self.loop_counters.sample += 1
            if stable_uniform(self.salt, self._tick, i) < self.segment_admit_p:
                self.cache.insert(i)
            else:
                self.cache.stats.rejected += 1

    def resident_ids(self) -> np.ndarray:
        keys = list(self.cache.small.keys()) + list(self.cache.main.keys())
        return np.asarray(sorted(int(k) for k in keys), dtype=np.int64)


# ---------------------------------------------------------------------------
# Array-native implementation
# ---------------------------------------------------------------------------

def _merge_sorted(a_keys: np.ndarray, a_pos: np.ndarray,
                  b_keys: np.ndarray, b_pos: np.ndarray,
                  b_after_ties: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """Merge two (keys, sort-position) streams, each already sorted by
    position, into one — O(n) via two searchsorted calls instead of an
    argsort. `b_after_ties` places b-entries after equal-position a-entries.
    Returns (merged_keys, merged_positions)."""
    na, nb = int(a_pos.size), int(b_pos.size)
    if na == 0:
        return b_keys, b_pos
    if nb == 0:
        return a_keys, a_pos
    side_a, side_b = ("left", "right") if b_after_ties else ("right", "left")
    ia = np.arange(na) + np.searchsorted(b_pos, a_pos, side=side_a)
    ib = np.arange(nb) + np.searchsorted(a_pos, b_pos, side=side_b)
    keys = np.empty(na + nb, dtype=a_keys.dtype)
    pos = np.empty(na + nb, dtype=a_pos.dtype)
    keys[ia], keys[ib] = a_keys, b_keys
    pos[ia], pos[ib] = a_pos, b_pos
    return keys, pos


class ArrayS3FIFOCache:
    """S3-FIFO over dense numpy state for an integer key space [0, n_keys).

    State:
      * `where`   int8[n_keys]  — 0 absent, 1 in small FIFO, 2 in main FIFO
      * `freq`    int64[n_keys] — S3-FIFO access frequency (valid while resident)
      * `in_ghost` bool[n_keys] — ghost-queue membership bitmap
      * `_small_q`/`_main_q`/`_ghost_q` — FIFO orders as plain int64 arrays
        (head first), rebuilt by slicing/concatenation once per insert batch.

    `access_batch` is a single fancy-index probe; `insert_batch` applies a
    whole admission batch with bulk array ops and is exact for arbitrary
    interleavings: main-queue CLOCK recycling is simulated by the chunked
    `_drain_main`, and ghost-overflow races against same-batch ghost hits
    are resolved up-front by `_refine_ghost_decisions`. Only inputs the
    admit path never produces (duplicate or already-resident keys) are
    replayed through the reference `S3FIFOCache` (bitwise-identical by
    construction) — counted in `loop_counters.fallback_*`.
    """

    def __init__(self, capacity: int, n_keys: int,
                 small_ratio: float = 0.1, ghost_ratio: float = 0.9) -> None:
        self.capacity = max(capacity, 0)
        self.n_keys = int(n_keys)
        self.small_cap = max(1, int(self.capacity * small_ratio)) if self.capacity else 0
        self.main_cap = self.capacity - self.small_cap
        self.ghost_cap = max(1, int(self.capacity * ghost_ratio)) if self.capacity else 0
        self.stats = CacheStats()
        self.where = np.zeros(self.n_keys, dtype=np.int8)
        self.freq = np.zeros(self.n_keys, dtype=np.int64)
        self.in_ghost = np.zeros(self.n_keys, dtype=bool)
        self._small_q = np.zeros(0, dtype=np.int64)
        self._main_q = np.zeros(0, dtype=np.int64)
        self._ghost_q = np.zeros(0, dtype=np.int64)
        self._ghost_rank = np.zeros(self.n_keys, dtype=np.int64)
        self.bulk_batches = 0
        self.fallback_batches = 0
        self.fallback_inserts = 0

    def __len__(self) -> int:
        return int(self._small_q.size + self._main_q.size)

    def __contains__(self, key: int) -> bool:
        return bool(self.where[int(key)] > 0)

    # -- probe --------------------------------------------------------------
    def access_batch(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized `access` over an id batch; returns the hit mask.

        Decision-identical to calling the reference `access` per id in order:
        residency cannot change mid-batch (no inserts here), so the hit mask
        is the residency bitmap, and hit frequencies rise by the number of
        occurrences, saturating at 3.
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros(0, dtype=bool)
        hit = self.where[ids] > 0
        n_hits = int(np.count_nonzero(hit))
        self.stats.hits += n_hits
        self.stats.misses += int(ids.size) - n_hits
        hit_ids = ids[hit]
        if hit_ids.size:
            if hit_ids.size == 1 or np.all(np.diff(hit_ids) > 0):  # unique fast path
                self.freq[hit_ids] = np.minimum(self.freq[hit_ids] + 1, 3)
            else:
                uniq, counts = np.unique(hit_ids, return_counts=True)
                self.freq[uniq] = np.minimum(self.freq[uniq] + counts, 3)
        return hit

    # -- insert -------------------------------------------------------------
    def insert_batch(self, keys: np.ndarray, assume_unique: bool = False) -> None:
        """Insert `keys` as if `insert` were called per key in order.

        A batch is planned from the ghost-membership decisions as of batch
        start. A provisional decision is wrong only when ghost overflow pops
        a batch key's entry earlier in the same batch than that key's own
        insertion; `_refine_ghost_decisions` resolves exactly those before
        planning, so the plan is exact in one pass.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if self.capacity == 0 or keys.size == 0:
            return
        # the bulk path assumes distinct, non-resident keys (the admit path
        # guarantees this: keys are this step's misses); anything else is an
        # order-dependent corner -> exact sequential replay
        if np.any(self.where[keys] > 0) or (
                not assume_unique and np.unique(keys).size != keys.size):
            return self._insert_batch_seq(keys)
        d = self.in_ghost[keys]                      # provisional ghost decisions
        if np.any(d):
            d = self._refine_ghost_decisions(keys, d)
        self._commit_bulk(keys, d, self._plan_bulk(keys, d))

    def _refine_ghost_decisions(self, keys: np.ndarray,
                                d0: np.ndarray) -> np.ndarray:
        """Resolve the ghost-decision fixed point in one ordered scan.

        A provisional decision is wrong only when ghost overflow pops the
        key's entry earlier in the same batch. Every queue quantity the
        overflow depends on reduces to a COUNT that is a function of (step,
        kept-ghost-hits-so-far): with `f` fresh inserts, the small queue pops
        max(0, f - slack) entries — always the leading slice of (old small ++
        fresh keys), whose promote/ghost split depends on the old entries'
        frequencies only (batch keys enter with freq 0) and is precomputed as
        one cumsum. Main evictions are one per over-cap append regardless of
        CLOCK recycling, so they're a running count too. The scan walks the
        ghost-hit candidates in step order (runs of fresh steps in between
        advance in O(1)), maintaining the ghost pop count and the kept
        entries' rank order; each candidate is kept or flipped exactly as the
        sequential process would. Cost: O(candidates log candidates) plus one
        cumsum — no per-neuron work.
        """
        cand = np.flatnonzero(d0)
        nc = int(cand.size)
        ranks = self._ghost_rank[keys[cand]]
        Ls, Lm = int(self._small_q.size), int(self._main_q.size)
        slack_s = self.small_cap - Ls
        main_over = Lm - self.main_cap
        base = int(self._ghost_q.size) - self.ghost_cap
        # promotions among the first x small pops, for any x (pops beyond the
        # old small queue hit batch keys, which enter with freq 0)
        cs = np.concatenate([np.zeros(1, dtype=np.int64),
                             np.cumsum(self.freq[self._small_q] > 0),
                             np.full(int(keys.size), 0, dtype=np.int64)])
        if self._small_q.size:
            cs[Ls + 1:] = cs[Ls]

        # Ghost pop pressure right after step j's append is
        #   base + small_ghosts(j, kept) + main_evictions(j, kept) - kept,
        # nondecreasing in j at fixed kept, so each candidate needs exactly
        # one evaluation: the endpoint of the run right before it (step - 1),
        # folded into a running max. Decisions must resolve strictly in step
        # order (a later flip can raise pop pressure past the binding max of
        # an earlier candidate but not vice versa), so the scan is scalar;
        # the expensive rank bookkeeping is hoisted out: deleted_ahead under
        # the all-kept assumption is one vectorized triangular count, and the
        # loop only corrects it by the (few) actually-flipped ranks.
        from bisect import bisect_left, insort

        cand_l, ranks_l = cand.tolist(), ranks.tolist()
        cs_l = cs.tolist()
        pops_g, kept = 0, 0
        kept_ranks: List[int] = []
        flip_steps: List[int] = []
        for i, step in enumerate(cand_l):
            if step:
                pops_s = step - kept - slack_s    # small pops through step-1
                if pops_s < 0:
                    pops_s = 0
                promos = cs_l[pops_s]
                ev = main_over + kept + promos    # main evictions
                if ev < 0:
                    ev = 0
                p = base + (pops_s - promos) + ev - kept
                if p > pops_g:
                    pops_g = p
            r = ranks_l[i]
            # effective rank: kept deletions ahead with smaller rank move the
            # entry toward the head
            if r - bisect_left(kept_ranks, r) < pops_g:
                flip_steps.append(step)           # entry already popped
            else:                                 # true ghost hit
                insort(kept_ranks, r)
                kept += 1
        d = d0.copy()
        if flip_steps:
            d[flip_steps] = False                 # -> plain fresh inserts
        return d

    def _plan_bulk(self, keys: np.ndarray, d: np.ndarray) -> dict:
        fresh = keys[~d]
        step_of_fresh = np.flatnonzero(~d)
        step_of_hit = np.flatnonzero(d)

        # -- small FIFO: one pop per over-cap append, pops never recycle into
        # small, so popped == leading slice of (old queue ++ fresh appends)
        S0, Ls, nF = self._small_q, int(self._small_q.size), int(fresh.size)
        n_pop_s = max(0, Ls + nF - self.small_cap)
        small_seq = np.concatenate([S0, fresh]) if nF else S0
        popped = small_seq[:n_pop_s]
        popped_f = self.freq[popped].copy()
        if n_pop_s > Ls:
            popped_f[Ls:] = 0                        # batch keys enter with freq 0
        promote = popped_f > 0
        new_small = small_seq[n_pop_s:]
        # the t-th pop fires at the (t + slack)-th fresh insert
        pop_steps = step_of_fresh[max(0, self.small_cap - Ls):][:n_pop_s]
        promoted = popped[promote]
        small_ghosted = popped[~promote]
        small_ghost_steps = pop_steps[~promote]

        # -- main FIFO appends: ghost hits + small promotions, in step order
        app_keys, app_steps = _merge_sorted(keys[d], step_of_hit,
                                            promoted, pop_steps[promote])

        # -- main FIFO CLOCK drain (exact, chunked — see _drain_main)
        M0, Lm, nA = self._main_q, int(self._main_q.size), int(app_keys.size)
        slack_m = self.main_cap - Lm
        n_evict_m = max(0, Lm + nA - self.main_cap)
        if n_evict_m:
            main_evicted, new_main, recycled = self._drain_main(
                M0, app_keys, n_evict_m, slack_m)
            # the i-th main eviction fires at the (i + main_slack)-th append
            main_ghost_steps = app_steps[slack_m:][:n_evict_m]
        else:
            new_main = np.concatenate([M0, app_keys]) if nA else M0
            main_evicted = new_main[:0]
            recycled = new_main[:0]
            main_ghost_steps = app_steps[:0]

        # -- ghost queue: <=1 append and <=1 deletion per step; deletion
        # precedes the append within a step; overflow pops after each append.
        # The schedule is exact because `_refine_ghost_decisions` already
        # resolved every decision a mid-batch overflow pop could flip.
        g_app, g_steps = _merge_sorted(small_ghosted, small_ghost_steps,
                                       main_evicted, main_ghost_steps)
        n_del, n_app = int(step_of_hit.size), int(g_app.size)
        if n_app:
            # live count right after each event, ignoring pops; the cumulative
            # pop count after any prefix is the running max of (live - cap).
            # Deletions sort before the same step's append (del, then insert).
            ev_delta, _ = _merge_sorted(
                np.full(n_del, -1, dtype=np.int64), step_of_hit,
                np.ones(n_app, dtype=np.int64), g_steps)
            is_append = ev_delta == 1
            live = int(self._ghost_q.size) + np.cumsum(ev_delta)
            pops_run = np.maximum.accumulate(
                np.where(is_append, live - self.ghost_cap, 0))
            n_pop = max(0, int(pops_run[-1]))
        else:
            n_pop = 0
        return dict(fresh=fresh, app_keys=app_keys, new_small=new_small,
                    new_main=new_main, small_ghosted=small_ghosted,
                    main_evicted=main_evicted, recycled=recycled,
                    g_app=g_app, n_pop=n_pop,
                    n_ghost_hits=int(step_of_hit.size))

    def _drain_main(self, M0: np.ndarray, app_keys: np.ndarray, n_evict: int,
                    slack: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Exact CLOCK drain of the main FIFO for one append batch.

        Pops consume arrivals in time order; a popped entry with freq > 0 is
        decremented and recycled to the tail, re-arriving right after the
        append that triggered the in-progress eviction (#slack+e); each
        eviction ends at the next freq-0 pop. Simulated in vectorized chunks:
        a chunk pops up to the first recycle's re-arrival position (all pops
        before it are final regardless of interleaving), then its recycles
        merge back into the pending stream by arrival time. Every chunk
        completes at least one eviction and recycling strictly decreases
        frequency, so a handful of chunks covers any batch (typically one).

        Returns (evicted keys in eviction order, new queue in FIFO order,
        recycled keys — one occurrence per frequency decrement).
        """
        Lm, nA = int(M0.size), int(app_keys.size)
        SUB = np.int64(1) << np.int64(32)
        # arrival-time keys: bucket = append number (0 for old entries),
        # sub-priority orders same-bucket arrivals (append, then recycles)
        pending = np.concatenate([M0, app_keys])
        ptime = np.concatenate([np.arange(Lm, dtype=np.int64),
                                np.arange(1, nA + 1, dtype=np.int64) * SUB])
        pfz = np.concatenate([self.freq[M0], np.zeros(nA, dtype=np.int64)])
        evicted_parts: List[np.ndarray] = []
        recycled_parts: List[np.ndarray] = []
        done, rc = 0, 1
        while done < n_evict:
            zpos = np.flatnonzero(pfz == 0)
            need = n_evict - done
            P = int(zpos[need - 1]) + 1 if zpos.size >= need else int(pending.size)
            nz_first = np.argmax(pfz[:P] > 0) if P else 0
            if P and pfz[nz_first] > 0:
                # a recycle exists: its re-arrival bounds the final prefix
                e_first = done + int(np.count_nonzero(pfz[:nz_first] == 0)) + 1
                t_first = np.int64(slack + e_first) * SUB + np.int64(rc)
                P = min(P, int(np.searchsorted(ptime, t_first)))
            chunk, chunk_f = pending[:P], pfz[:P]
            ev_mask = chunk_f == 0
            evicted_parts.append(chunk[ev_mask])
            nz = np.flatnonzero(~ev_mask)
            done_before = done
            done += int(np.count_nonzero(ev_mask))
            if nz.size:
                recs = chunk[nz]
                recycled_parts.append(recs)
                czero = np.cumsum(ev_mask)
                e_idx = done_before + czero[nz] + 1   # in-progress eviction ids
                rec_time = ((slack + e_idx) * SUB
                            + (rc + np.arange(nz.size, dtype=np.int64)))
                rc += int(nz.size)
                na, nb = int(pending.size) - P, int(nz.size)
                ia = np.arange(na) + np.searchsorted(rec_time, ptime[P:])
                ib = np.arange(nb) + np.searchsorted(ptime[P:], rec_time)
                merged = np.empty(na + nb, dtype=np.int64)
                merged_t = np.empty(na + nb, dtype=np.int64)
                merged_f = np.empty(na + nb, dtype=np.int64)
                merged[ia], merged[ib] = pending[P:], recs
                merged_t[ia], merged_t[ib] = ptime[P:], rec_time
                merged_f[ia], merged_f[ib] = pfz[P:], chunk_f[nz] - 1
                pending, ptime, pfz = merged, merged_t, merged_f
            else:
                pending, ptime, pfz = pending[P:], ptime[P:], pfz[P:]
        evicted = (np.concatenate(evicted_parts) if evicted_parts
                   else pending[:0])
        recycled = (np.concatenate(recycled_parts) if recycled_parts
                    else pending[:0])
        return evicted, pending, recycled

    def _commit_bulk(self, keys: np.ndarray, d: np.ndarray, plan: dict) -> None:
        self.in_ghost[keys[d]] = False               # ghost hits leave the queue
        old_live = self._ghost_q[self.in_ghost[self._ghost_q]] \
            if plan["n_ghost_hits"] else self._ghost_q
        ghost_seq = np.concatenate([old_live, plan["g_app"]])
        n_pop = plan["n_pop"]
        new_ghost = ghost_seq[n_pop:]
        # popped first, survivors last: a flipped key re-ghosted in the same
        # batch appears twice in ghost_seq (popped old entry + new append) and
        # must end up live in the bitmap
        if n_pop:
            self.in_ghost[ghost_seq[:n_pop]] = False
        self.in_ghost[new_ghost] = True

        self.where[plan["fresh"]] = 1
        self.where[plan["app_keys"]] = 2
        self.where[plan["small_ghosted"]] = 0
        self.where[plan["main_evicted"]] = 0
        self.freq[keys] = 0
        self.freq[plan["app_keys"]] = 0
        if plan["recycled"].size:
            # a key may be recycled more than once across drain chunks
            np.subtract.at(self.freq, plan["recycled"], 1)
        self._small_q = plan["new_small"]
        self._main_q = plan["new_main"]
        self._ghost_q = new_ghost
        self._ghost_rank[new_ghost] = np.arange(new_ghost.size)
        self.stats.admitted += int(keys.size)
        self.stats.ghost_promotions += plan["n_ghost_hits"]
        self.stats.evicted += (int(plan["small_ghosted"].size)
                               + int(plan["main_evicted"].size))
        self.bulk_batches += 1

    def _insert_batch_seq(self, keys: np.ndarray) -> None:
        """Exact order-dependent corner: replay through the reference S3-FIFO
        (shares this cache's stats object) and rebuild the array state."""
        self.fallback_batches += 1
        self.fallback_inserts += int(keys.size)
        ref = S3FIFOCache.__new__(S3FIFOCache)
        ref.capacity, ref.small_cap = self.capacity, self.small_cap
        ref.main_cap, ref.ghost_cap = self.main_cap, self.ghost_cap
        ref.small = OrderedDict((int(k), int(self.freq[k])) for k in self._small_q)
        ref.main = OrderedDict((int(k), int(self.freq[k])) for k in self._main_q)
        ref.ghost = OrderedDict((int(k), None) for k in self._ghost_q)
        ref.stats = self.stats
        for k in keys.tolist():
            ref.insert(k)
        self._load_from_reference(ref)

    def _load_from_reference(self, ref: S3FIFOCache) -> None:
        self.where[self._small_q] = 0
        self.where[self._main_q] = 0
        self.in_ghost[self._ghost_q] = False
        self._small_q = np.fromiter(ref.small.keys(), np.int64, len(ref.small))
        self._main_q = np.fromiter(ref.main.keys(), np.int64, len(ref.main))
        self._ghost_q = np.fromiter(ref.ghost.keys(), np.int64, len(ref.ghost))
        self.where[self._small_q] = 1
        self.where[self._main_q] = 2
        self.freq[self._small_q] = np.fromiter(ref.small.values(), np.int64,
                                               len(ref.small))
        self.freq[self._main_q] = np.fromiter(ref.main.values(), np.int64,
                                              len(ref.main))
        self.in_ghost[self._ghost_q] = True
        self._ghost_rank[self._ghost_q] = np.arange(self._ghost_q.size)

    # -- debug / equivalence views ------------------------------------------
    def queues(self) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]], List[int]]:
        """(small [(key, freq)], main [(key, freq)], ghost [key]) in FIFO order."""
        small = [(int(k), int(self.freq[k])) for k in self._small_q]
        main = [(int(k), int(self.freq[k])) for k in self._main_q]
        return small, main, [int(k) for k in self._ghost_q]


class ArrayLinkingAlignedCache:
    """Array-native S3-FIFO + linking-aligned admission (the hot-path default).

    Same policy and same decisions as `LinkingAlignedCache`, with the three
    per-neuron hot loops vectorized end-to-end:

      * probe      — one fancy-index over the residency bitmap;
      * classify   — run breaks via `collapse.run_bounds_from_sorted`;
      * sampling   — one `stable_uniform_array` call over segment members,
                     keyed on the same (salt, tick, id) triples as the
                     reference so admission decisions match bit for bit.

    Requires the key space size (`n_keys` = neurons in the FFN block) so
    residency can live in dense arrays.
    """

    def __init__(
        self,
        capacity: int,
        n_keys: int,
        segment_min_len: int = 4,
        segment_admit_p: float = 0.25,
        linking_aligned: bool = True,
        salt: int = 0,
    ) -> None:
        self.cache = ArrayS3FIFOCache(capacity, n_keys)
        self.segment_min_len = segment_min_len
        self.segment_admit_p = segment_admit_p
        self.linking_aligned = linking_aligned
        self.salt = salt
        self._tick = 0

    @property
    def stats(self) -> CacheStats:
        return self.cache.stats

    @property
    def loop_counters(self) -> LoopCounters:
        """Hot per-neuron loop counters stay zero by construction; only the
        exact-replay fallback (per admit batch, not per neuron) is counted."""
        return LoopCounters(probe=0, classify=0, sample=0,
                            fallback_batches=self.cache.fallback_batches,
                            fallback_inserts=self.cache.fallback_inserts)

    def lookup_mask(self, ids: np.ndarray) -> np.ndarray:
        return self.cache.access_batch(ids)

    def peek_mask(self, ids: np.ndarray) -> np.ndarray:
        """Side-effect-free residency probe (see `LinkingAlignedCache.peek_mask`):
        one fancy-index over the bitmap, no stats/frequency mutation."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros(0, dtype=bool)
        return self.cache.where[ids] > 0

    def lookup(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, dtype=np.int64)
        hit_mask = self.lookup_mask(ids)
        return ids[hit_mask], ids[~hit_mask]

    def _classify_arrays(self, miss_ids: np.ndarray,
                         physical: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(ids in physical order, segment-member mask) — fully vectorized."""
        miss_ids = np.asarray(miss_ids, dtype=np.int64)
        physical = np.asarray(physical, dtype=np.int64)
        order = np.argsort(physical)
        phys_sorted = physical[order]
        ids_sorted = miss_ids[order]
        starts, ends = run_bounds_from_sorted(phys_sorted)
        lengths = ends - starts + 1               # ids per run (positions unique)
        seg_mask = np.repeat(lengths >= self.segment_min_len, lengths)
        return ids_sorted, seg_mask

    def classify(self, miss_ids: np.ndarray, physical: np.ndarray) -> Tuple[Set[int], Set[int]]:
        ids_sorted, seg_mask = self._classify_arrays(miss_ids, physical)
        return set(ids_sorted[~seg_mask].tolist()), set(ids_sorted[seg_mask].tolist())

    def admit(self, miss_ids: np.ndarray, physical: np.ndarray) -> None:
        miss_ids = np.asarray(miss_ids, dtype=np.int64)
        if miss_ids.size == 0:
            return
        self._tick += 1
        if not self.linking_aligned:
            self.cache.insert_batch(miss_ids)
            return
        ids_sorted, seg_mask = self._classify_arrays(miss_ids, physical)
        segment = ids_sorted[seg_mask]
        if segment.size:
            u = stable_uniform_array(self.salt, self._tick, segment)
            keep = u < self.segment_admit_p
            self.stats.rejected += int(np.count_nonzero(~keep))
            admitted_segment = segment[keep]
        else:
            admitted_segment = segment
        # sporadic + sampled segment members are disjoint subsets of this
        # step's (unique) misses — skip the duplicate guard
        self.cache.insert_batch(
            np.concatenate([ids_sorted[~seg_mask], admitted_segment]),
            assume_unique=True)

    def resident_ids(self) -> np.ndarray:
        return np.flatnonzero(self.cache.where > 0).astype(np.int64)


def make_linking_aligned_cache(
    capacity: int,
    n_keys: int,
    segment_min_len: int = 4,
    segment_admit_p: float = 0.25,
    linking_aligned: bool = True,
    salt: int = 0,
    impl: str = "array",
):
    """Factory over the two decision-identical implementations."""
    if impl == "array":
        return ArrayLinkingAlignedCache(
            capacity, n_keys, segment_min_len=segment_min_len,
            segment_admit_p=segment_admit_p, linking_aligned=linking_aligned,
            salt=salt)
    if impl == "dict":
        return LinkingAlignedCache(
            capacity, segment_min_len=segment_min_len,
            segment_admit_p=segment_admit_p, linking_aligned=linking_aligned,
            salt=salt)
    raise ValueError(f"unknown cache impl {impl!r} (want 'array' or 'dict')")
