"""RIPPLE core: neuron co-activation linking for flash-offloaded LLM inference.

Offline stage: `coactivation` (pattern extraction) -> `placement` (greedy
Hamiltonian-path search). Online stage: `collapse` (access collapse),
`cache` (linking-aligned S3-FIFO), `storage` (UFS device model + neuron store),
`predictor` (activation prediction), `engine` (the batched serving pipeline),
`pipeline` (double-buffered I/O–compute overlap model).
"""
from repro.core.cache import (ArrayLinkingAlignedCache, ArrayS3FIFOCache,
                              CacheStats, FIFOCache, LRUCache,
                              LinkingAlignedCache, LoopCounters, S3FIFOCache,
                              make_linking_aligned_cache)
from repro.core.coactivation import (CoActivationStats, expected_io_ops,
                                     stats_from_mask_shards, stats_from_masks)
from repro.core.collapse import (AdaptiveThreshold, BottleneckDetector,
                                 collapse_extents, collapse_positions,
                                 run_bounds_from_sorted, runs_from_positions)
from repro.core.engine import (BatchStepResult, EngineConfig, OffloadEngine,
                               RequestStats, TokenStats)
from repro.core.expert_placement import (expected_reads_per_token,
                                         expert_coactivation,
                                         hierarchical_moe_placement,
                                         search_expert_placement,
                                         synthetic_routing)
from repro.core.pipeline import (IOScheduler, Stage, TokenTiming,
                                 overlapped_latency, serial_latency)
from repro.core.placement import (PlacementResult, frequency_placement,
                                  identity_placement, path_length, search_placement)
from repro.core.predictor import (PredictorConfig, PredictorParams, init_predictor,
                                  predict_mask, predictor_logits, recall_precision,
                                  train_predictor)
from repro.core.sparse_ffn import (FFNWeights, dense_ffn, ffn_pre_activation,
                                   make_bundles, sparse_ffn_from_bundles,
                                   sparse_ffn_gather)
from repro.core.storage import (UFS31, UFS40, IOStats, ManagedReader, NeuronStore,
                                UFSDevice)
from repro.core.trace import (ShardedTraceWriter, SyntheticTraceConfig,
                              iter_trace_shards, relu_activation_mask,
                              synthetic_masks, topk_activation_mask,
                              trace_model_activations)

__all__ = [k for k in dir() if not k.startswith("_")]
