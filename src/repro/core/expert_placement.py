"""Expert-level co-activation linking for MoE architectures (DESIGN §4).

For MoE layers the RIPPLE unit is the EXPERT: top-k routing co-activates k
experts per token, and experts routed together should be contiguous in flash
so one continuous read covers a token's expert set. This is the same
Hamiltonian-path machinery as neuron placement, applied to the E x E expert
co-routing graph, plus within-expert neuron linking using the tokens routed
to that expert.

Offline inputs come from router traces: `routing_stats(sel)` over [T, top_k]
expert-id selections.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.coactivation import CoActivationStats
from repro.core.placement import PlacementResult, search_placement


def routing_masks(sel: np.ndarray, n_experts: int) -> np.ndarray:
    """sel: [T, top_k] routed expert ids -> [T, E] 0/1 co-routing masks."""
    sel = np.asarray(sel)
    T = sel.shape[0]
    masks = np.zeros((T, n_experts), dtype=bool)
    masks[np.arange(T)[:, None], sel] = True
    return masks


def expert_coactivation(sel: np.ndarray, n_experts: int) -> CoActivationStats:
    stats = CoActivationStats(n_experts)
    stats.update(routing_masks(sel, n_experts))
    return stats


def search_expert_placement(sel: np.ndarray, n_experts: int) -> PlacementResult:
    """Expert flash order minimising expected reads per token (Eq. 4-5 at
    expert granularity). E is small — exact mode always."""
    stats = expert_coactivation(sel, n_experts)
    return search_placement(stats.distance_matrix(), mode="exact")


def expected_reads_per_token(sel: np.ndarray, n_experts: int,
                             placement: PlacementResult) -> float:
    """Mean number of contiguous extents covering each token's expert set."""
    sel = np.asarray(sel)
    inv = placement.inverse
    total = 0
    for row in sel:
        phys = np.sort(inv[np.unique(row)])
        total += 1 + int(np.sum(np.diff(phys) > 1))
    return total / max(len(sel), 1)


def within_expert_masks(
    token_masks: np.ndarray,       # [T, d_ff_expert] neuron activations
    sel: np.ndarray,               # [T, top_k] which experts each token used
    expert: int,
) -> np.ndarray:
    """Neuron activation masks restricted to tokens routed to `expert`."""
    routed = np.any(np.asarray(sel) == expert, axis=1)
    return np.asarray(token_masks)[routed]


def hierarchical_moe_placement(
    sel: np.ndarray,
    neuron_masks_per_expert: Optional[List[np.ndarray]],
    n_experts: int,
) -> Tuple[PlacementResult, List[Optional[PlacementResult]]]:
    """Two-level RIPPLE for MoE: expert order in flash + per-expert neuron
    order. Returns (expert placement, per-expert neuron placements)."""
    expert_pl = search_expert_placement(sel, n_experts)
    neuron_pls: List[Optional[PlacementResult]] = []
    for e in range(n_experts):
        if neuron_masks_per_expert is None or neuron_masks_per_expert[e] is None \
                or len(neuron_masks_per_expert[e]) == 0:
            neuron_pls.append(None)
            continue
        stats = CoActivationStats(neuron_masks_per_expert[e].shape[1])
        stats.update(neuron_masks_per_expert[e])
        neuron_pls.append(search_placement(stats.distance_matrix(), mode="auto"))
    return expert_pl, neuron_pls


def synthetic_routing(n_tokens: int, n_experts: int, top_k: int,
                      n_groups: int = 4, seed: int = 0,
                      group_p: float = 0.85) -> np.ndarray:
    """Synthetic co-routed selections: experts belong to affinity groups;
    a token draws most of its top-k from one group (mirrors the observation
    that domain/topic tokens co-route)."""
    rng = np.random.default_rng(seed)
    groups = [np.array([e for e in range(n_experts) if e % n_groups == g])
              for g in range(n_groups)]
    sel = np.zeros((n_tokens, top_k), dtype=np.int64)
    for t in range(n_tokens):
        g = rng.integers(n_groups)
        pool = groups[g]
        for k in range(top_k):
            if rng.random() < group_p and len(pool) > 0:
                sel[t, k] = rng.choice(pool)
            else:
                sel[t, k] = rng.integers(n_experts)
        # top-k entries must be distinct experts
        row = np.unique(sel[t])
        while len(row) < top_k:
            row = np.unique(np.concatenate([row, [rng.integers(n_experts)]]))
        sel[t] = row[:top_k]
    return sel
