"""Sparse FFN math — pure-jnp reference ops used by the offload engine.

A "neuron" n of an FFN block is the bundle {W_gate[n, :], W_up[n, :], W_down[:, n]}
(2-matrix models drop the gate). Activation sparsity: with ReLU, the FFN output
is exactly preserved when computing only over neurons whose intermediate is > 0.

These functions are the semantic oracles; kernels/sparse_ffn.py provides the
Pallas segment-gather version for the TPU target.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class FFNWeights(NamedTuple):
    w_up: jnp.ndarray            # [n_neurons, d_model]
    w_down: jnp.ndarray          # [n_neurons, d_model]  (stored row-major per neuron)
    w_gate: Optional[jnp.ndarray] = None   # [n_neurons, d_model] or None


def dense_ffn(x: jnp.ndarray, w: FFNWeights, activation: str = "relu") -> jnp.ndarray:
    """x: [..., d_model] -> [..., d_model]."""
    pre = x @ w.w_up.T
    act = _act(pre, activation)
    if w.w_gate is not None:
        act = act * (x @ w.w_gate.T)
    return act @ w.w_down


def ffn_pre_activation(x: jnp.ndarray, w: FFNWeights) -> jnp.ndarray:
    return x @ w.w_up.T


def _act(x: jnp.ndarray, name: str) -> jnp.ndarray:
    if name == "relu":
        return jax.nn.relu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


@partial(jax.jit, static_argnames=("activation",))
def sparse_ffn_gather(
    x: jnp.ndarray,
    w: FFNWeights,
    neuron_ids: jnp.ndarray,
    activation: str = "relu",
    valid_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """FFN over a static-size activated subset (padded with `valid_mask`).

    x: [B, d]; neuron_ids: [k] int32 (may contain padding); valid_mask: [k] bool.
    Exact when the true activated set is a subset of neuron_ids (ReLU zeroes
    the rest anyway); padding rows are masked to zero contribution.
    """
    up = w.w_up[neuron_ids]                      # [k, d]
    pre = x @ up.T                               # [B, k]
    act = _act(pre, activation)
    if w.w_gate is not None:
        act = act * (x @ w.w_gate[neuron_ids].T)
    if valid_mask is not None:
        act = act * valid_mask[None, :].astype(act.dtype)
    return act @ w.w_down[neuron_ids]            # [B, d]


def sparse_ffn_from_bundles(
    x: jnp.ndarray,
    bundles: jnp.ndarray,
    d_model: int,
    n_mats: int,
    activation: str = "relu",
    valid_mask: Optional[jnp.ndarray] = None,
    scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """FFN computed directly from flash bundle payloads (engine read path).

    bundles: [k, n_mats * d_model] rows as stored in flash —
    layout per neuron: [up | down] (n_mats=2) or [gate | up | down] (n_mats=3).
    scales: optional [k] f32 per-neuron symmetric dequant scales; when given,
    bundles may be raw int8 rows and are dequantized on-device (bitwise equal
    to `store.format.dequantize_int8`, which is q.astype(f32) * scale).
    """
    k = bundles.shape[0]
    if scales is not None:
        bundles = bundles.astype(jnp.float32) * scales[:, None]
    parts = bundles.reshape(k, n_mats, d_model)
    if n_mats == 3:
        w = FFNWeights(w_up=parts[:, 1], w_down=parts[:, 2], w_gate=parts[:, 0])
    else:
        w = FFNWeights(w_up=parts[:, 0], w_down=parts[:, 1], w_gate=None)
    pre = x @ w.w_up.T
    act = _act(pre, activation)
    if w.w_gate is not None:
        act = act * (x @ w.w_gate.T)
    if valid_mask is not None:
        act = act * valid_mask[None, :].astype(act.dtype)
    return act @ w.w_down


def make_bundles(w: FFNWeights) -> jnp.ndarray:
    """Pack FFN weights into per-neuron flash bundles [n, n_mats*d]."""
    cols = [w.w_gate, w.w_up, w.w_down] if w.w_gate is not None else [w.w_up, w.w_down]
    return jnp.concatenate(cols, axis=-1)
