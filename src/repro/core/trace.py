"""Activation-trace capture and synthetic co-activation workloads.

Two sources of FFN activation masks:
  * `trace_model_activations` — run a real model (models/) over a token stream
    and record per-layer FFN activation masks (ReLU > 0 or top-k magnitude).
  * `synthetic_masks` — a planted-cluster generator matching the paper's
    Figure-6 observation: neurons belong to co-activation groups; each token
    activates a few groups plus background noise. Used by unit tests and
    benchmarks so core results don't depend on model weights.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTraceConfig:
    n_neurons: int = 1024
    n_clusters: int = 32
    clusters_per_token: int = 3
    member_p: float = 0.9       # P(neuron fires | its cluster fires)
    noise_p: float = 0.01       # background activation probability
    zipf_alpha: float = 1.1     # cluster popularity skew
    seed: int = 0               # token sampling seed (the "dataset")
    structure_seed: Optional[int] = None   # cluster membership (the "model");
    #                                        defaults to `seed` if None
    popularity_seed: Optional[int] = None  # which clusters are popular
    #                                        (dataset-dependent); default fixed


def synthetic_masks(cfg: SyntheticTraceConfig, n_tokens: int) -> np.ndarray:
    """[n_tokens, n_neurons] bool planted-cluster activation masks.

    Cluster membership is a *random* partition of neuron ids (seeded by
    structure_seed — a MODEL property, per the paper's Fig. 15 finding that
    co-activation is model-intrinsic), so the identity (model-structure)
    layout scatters each cluster across the address space — exactly the
    misalignment the paper describes. Token sampling (seed) and cluster
    popularity (popularity_seed) are DATASET properties.
    """
    s_seed = cfg.structure_seed if cfg.structure_seed is not None else cfg.seed
    struct_rng = np.random.default_rng(s_seed)
    perm = struct_rng.permutation(cfg.n_neurons)
    cluster_of = np.empty(cfg.n_neurons, dtype=np.int64)
    for c in range(cfg.n_clusters):
        members = perm[c::cfg.n_clusters]
        cluster_of[members] = c
    # zipf-ish popularity over clusters; which clusters are hot is dataset-driven
    weights = 1.0 / np.arange(1, cfg.n_clusters + 1) ** cfg.zipf_alpha
    weights /= weights.sum()
    if cfg.popularity_seed is not None:
        pop_rng = np.random.default_rng(cfg.popularity_seed)
        weights = weights[pop_rng.permutation(cfg.n_clusters)]
    rng = np.random.default_rng(cfg.seed)
    masks = np.zeros((n_tokens, cfg.n_neurons), dtype=bool)
    for t in range(n_tokens):
        active_clusters = rng.choice(cfg.n_clusters, size=cfg.clusters_per_token, replace=False, p=weights)
        in_active = np.isin(cluster_of, active_clusters)
        fire = rng.random(cfg.n_neurons)
        masks[t] = (in_active & (fire < cfg.member_p)) | (fire < cfg.noise_p)
    return masks


def relu_activation_mask(pre_act: jnp.ndarray) -> jnp.ndarray:
    """ReLU-family sparsity: a neuron is activated iff its intermediate > 0."""
    return pre_act > 0


def topk_activation_mask(pre_act: jnp.ndarray, k: int) -> jnp.ndarray:
    """Magnitude top-k per token — used for non-ReLU (SiLU) models."""
    thresh = -jax.lax.top_k(-(-jnp.abs(pre_act)), k)[0][..., -1:]
    return jnp.abs(pre_act) >= thresh


def trace_model_activations(
    apply_fn: Callable[..., Dict[str, jnp.ndarray]],
    params,
    token_batches: List[np.ndarray],
    sparsity_topk: Optional[int] = None,
) -> List[np.ndarray]:
    """Run `apply_fn(params, tokens, capture_activations=True)` over batches.

    apply_fn must return a dict with key "ffn_pre_act": [L, B, T, N] (stacked
    scan layers). Returns per-layer [total_tokens, N] bool masks.
    """
    per_layer: List[List[np.ndarray]] = []
    for tokens in token_batches:
        out = apply_fn(params, jnp.asarray(tokens), capture_activations=True)
        pre = out["ffn_pre_act"]  # [L, B, T, N]
        if sparsity_topk is None:
            masks = np.asarray(relu_activation_mask(pre))
        else:
            masks = np.asarray(topk_activation_mask(pre, sparsity_topk))
        L = masks.shape[0]
        if not per_layer:
            per_layer = [[] for _ in range(L)]
        for l in range(L):
            per_layer[l].append(masks[l].reshape(-1, masks.shape[-1]))
    return [np.concatenate(chunks, axis=0) for chunks in per_layer]
