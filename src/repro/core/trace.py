"""Activation-trace capture and synthetic co-activation workloads.

Three sources of FFN activation masks:
  * `trace_model_activations` — run a real model (models/) over a token stream
    and record per-layer FFN activation masks (ReLU > 0 or top-k magnitude).
  * `ShardedTraceWriter` / `iter_trace_shards` — the same capture streamed to
    disk as per-layer `.npy` shards, so the offline packer can accumulate
    co-activation statistics over traces larger than RAM
    (`repro.core.coactivation.stats_from_mask_shards` merges per-shard stats).
  * `synthetic_masks` — a planted-cluster generator matching the paper's
    Figure-6 observation: neurons belong to co-activation groups; each token
    activates a few groups plus background noise. Used by unit tests and
    benchmarks so core results don't depend on model weights.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Callable, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTraceConfig:
    n_neurons: int = 1024
    n_clusters: int = 32
    clusters_per_token: int = 3
    member_p: float = 0.9       # P(neuron fires | its cluster fires)
    noise_p: float = 0.01       # background activation probability
    zipf_alpha: float = 1.1     # cluster popularity skew
    seed: int = 0               # token sampling seed (the "dataset")
    structure_seed: Optional[int] = None   # cluster membership (the "model");
    #                                        defaults to `seed` if None
    popularity_seed: Optional[int] = None  # which clusters are popular
    #                                        (dataset-dependent); default fixed


def synthetic_masks(cfg: SyntheticTraceConfig, n_tokens: int) -> np.ndarray:
    """[n_tokens, n_neurons] bool planted-cluster activation masks.

    Cluster membership is a *random* partition of neuron ids (seeded by
    structure_seed — a MODEL property, per the paper's Fig. 15 finding that
    co-activation is model-intrinsic), so the identity (model-structure)
    layout scatters each cluster across the address space — exactly the
    misalignment the paper describes. Token sampling (seed) and cluster
    popularity (popularity_seed) are DATASET properties.
    """
    s_seed = cfg.structure_seed if cfg.structure_seed is not None else cfg.seed
    struct_rng = np.random.default_rng(s_seed)
    perm = struct_rng.permutation(cfg.n_neurons)
    cluster_of = np.empty(cfg.n_neurons, dtype=np.int64)
    for c in range(cfg.n_clusters):
        members = perm[c::cfg.n_clusters]
        cluster_of[members] = c
    # zipf-ish popularity over clusters; which clusters are hot is dataset-driven
    weights = 1.0 / np.arange(1, cfg.n_clusters + 1) ** cfg.zipf_alpha
    weights /= weights.sum()
    if cfg.popularity_seed is not None:
        pop_rng = np.random.default_rng(cfg.popularity_seed)
        weights = weights[pop_rng.permutation(cfg.n_clusters)]
    rng = np.random.default_rng(cfg.seed)
    masks = np.zeros((n_tokens, cfg.n_neurons), dtype=bool)
    for t in range(n_tokens):
        active_clusters = rng.choice(cfg.n_clusters, size=cfg.clusters_per_token, replace=False, p=weights)
        in_active = np.isin(cluster_of, active_clusters)
        fire = rng.random(cfg.n_neurons)
        masks[t] = (in_active & (fire < cfg.member_p)) | (fire < cfg.noise_p)
    return masks


def relu_activation_mask(pre_act: jnp.ndarray) -> jnp.ndarray:
    """ReLU-family sparsity: a neuron is activated iff its intermediate > 0."""
    return pre_act > 0


def topk_activation_mask(pre_act: jnp.ndarray, k: int) -> jnp.ndarray:
    """Magnitude top-k per token — used for non-ReLU (SiLU) models."""
    thresh = -jax.lax.top_k(-(-jnp.abs(pre_act)), k)[0][..., -1:]
    return jnp.abs(pre_act) >= thresh


class ShardedTraceWriter:
    """Streaming activation-trace store: per-layer boolean mask shards.

    Each `append(layer, masks)` writes one `.npy` shard
    (``layer{l:03d}_shard{k:05d}.npy``, bool [T_k, n]) — nothing but the
    current batch's masks is ever held in memory, so the offline packer can
    trace arbitrarily long token streams. `finish()` writes a
    ``manifest.json`` recording the shard lists and token counts; readers go
    through `iter_trace_shards`, which prefers the manifest and falls back to
    a directory glob for unfinished traces.
    """

    MANIFEST = "manifest.json"

    def __init__(self, root: Union[str, os.PathLike], n_layers: int,
                 n_neurons: int) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n_layers = n_layers
        self.n_neurons = n_neurons
        self._shards: List[List[str]] = [[] for _ in range(n_layers)]
        self._tokens = [0] * n_layers

    def append(self, layer: int, masks: np.ndarray) -> pathlib.Path:
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        if masks.shape[-1] != self.n_neurons:
            raise ValueError(f"mask width {masks.shape[-1]} != n_neurons "
                             f"{self.n_neurons}")
        k = len(self._shards[layer])
        path = self.root / f"layer{layer:03d}_shard{k:05d}.npy"
        np.save(path, masks)
        self._shards[layer].append(path.name)
        self._tokens[layer] += masks.shape[0]
        return path

    def finish(self) -> dict:
        manifest = dict(n_layers=self.n_layers, n_neurons=self.n_neurons,
                        tokens_per_layer=self._tokens, shards=self._shards)
        (self.root / self.MANIFEST).write_text(json.dumps(manifest, indent=1))
        return manifest


def iter_trace_shards(root: Union[str, os.PathLike],
                      layer: int) -> Iterator[np.ndarray]:
    """Yield one layer's mask shards in write order, one array at a time."""
    root = pathlib.Path(root)
    manifest = root / ShardedTraceWriter.MANIFEST
    if manifest.exists():
        names = json.loads(manifest.read_text())["shards"][layer]
    else:
        names = sorted(p.name for p in root.glob(f"layer{layer:03d}_shard*.npy"))
    for name in names:
        yield np.load(root / name)


def trace_model_activations(
    apply_fn: Callable[..., Dict[str, jnp.ndarray]],
    params,
    token_batches: List[np.ndarray],
    sparsity_topk: Optional[int] = None,
) -> List[np.ndarray]:
    """Run `apply_fn(params, tokens, capture_activations=True)` over batches.

    apply_fn must return a dict with key "ffn_pre_act": [L, B, T, N] (stacked
    scan layers). Returns per-layer [total_tokens, N] bool masks.
    """
    per_layer: List[List[np.ndarray]] = []
    for tokens in token_batches:
        out = apply_fn(params, jnp.asarray(tokens), capture_activations=True)
        pre = out["ffn_pre_act"]  # [L, B, T, N]
        if sparsity_topk is None:
            masks = np.asarray(relu_activation_mask(pre))
        else:
            masks = np.asarray(topk_activation_mask(pre, sparsity_topk))
        L = masks.shape[0]
        if not per_layer:
            per_layer = [[] for _ in range(L)]
        for l in range(L):
            per_layer[l].append(masks[l].reshape(-1, masks.shape[-1]))
    return [np.concatenate(chunks, axis=0) for chunks in per_layer]
