"""Online IOPS-friendly access collapse (paper §5.1).

Given the physical positions of the neurons to read, produce the set of
contiguous read *extents*. Two nearby runs separated by a gap of <= threshold
unactivated neurons are merged into one read (the gap is read speculatively),
trading extra bytes for fewer I/O ops — a win while the device is IOPS-bound.

Runtime control (paper §5.1):
  * AdaptiveThreshold — raises/lowers the gap threshold based on achieved
    efficiency of past collapses.
  * BottleneckDetector — disables collapse once achieved bandwidth approaches
    the device maximum (bandwidth-bound regime: extra bytes no longer free).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

Extent = Tuple[int, int]  # (start_position, length) in physical neuron units


def run_bounds_from_sorted(positions: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(start_indices, end_indices) of maximal contiguous runs in an already
    sorted-unique position array. Index arrays point INTO `positions`; the
    whole computation is one diff + two concatenates (no per-element loop).
    Shared by the read planner and the cache's segment classifier."""
    if positions.size == 0:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty
    breaks = np.nonzero(np.diff(positions) > 1)[0]
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [positions.size - 1]])
    return starts, ends


def runs_from_positions(positions: np.ndarray) -> List[Extent]:
    """Maximal contiguous runs from physical positions (sorted + deduped here)."""
    positions = np.unique(np.asarray(positions, dtype=np.int64))
    starts, ends = run_bounds_from_sorted(positions)
    return [(int(positions[s]), int(positions[e] - positions[s] + 1))
            for s, e in zip(starts, ends)]


def collapse_extents(extents: Sequence[Extent], threshold: int) -> List[Extent]:
    """Merge extents whose gap is <= threshold (gap neurons read speculatively)."""
    if not extents:
        return []
    out = [extents[0]]
    for start, length in extents[1:]:
        pstart, plength = out[-1]
        gap = start - (pstart + plength)
        if gap <= threshold:
            out[-1] = (pstart, start + length - pstart)
        else:
            out.append((start, length))
    return out


def collapse_positions(positions: np.ndarray, threshold: int) -> List[Extent]:
    return collapse_extents(runs_from_positions(positions), threshold)


@dataclasses.dataclass
class CollapseStats:
    ops_before: int = 0
    ops_after: int = 0
    useful_neurons: int = 0
    read_neurons: int = 0

    @property
    def waste_ratio(self) -> float:
        return 0.0 if self.read_neurons == 0 else 1.0 - self.useful_neurons / self.read_neurons


class AdaptiveThreshold:
    """Gap threshold anchored at the device break-even point.

    Merging a gap of g bundles is profitable iff the speculative bytes cost
    less than one I/O op:  g * bundle_bytes / B_max  <  1 / IOPS_max, i.e.

        g*  =  B_max / (IOPS_max * bundle_bytes)        (the break-even gap)

    The threshold starts at g* and adapts multiplicatively within
    [g*/2, 2 g*] from the measured op-vs-byte cost balance — the dynamic
    adjustment of paper §5.1, with the anchor keeping it from running away
    on heavily scattered layouts (where balancing alone over-merges).
    """

    def __init__(self, initial: Optional[int] = None, lo: int = 0, hi: int = 256,
                 break_even: Optional[float] = None) -> None:
        if break_even is not None:
            lo = max(int(break_even // 2), 0)
            hi = max(int(break_even * 2), 1)
        if initial is None:
            initial = max(int(break_even), 0) if break_even is not None else 4
        # an explicit initial wins over the break-even anchor, but stays inside
        # the adaptation band so one update() can't jump it across the range
        self.threshold = min(max(int(initial), lo), hi)
        self.lo, self.hi = lo, hi

    def update(self, op_cost: float, byte_cost: float) -> int:
        if op_cost > 1.25 * byte_cost:
            self.threshold = min(self.hi, max(1, self.threshold * 2))
        elif byte_cost > 1.25 * op_cost:
            self.threshold = max(self.lo, self.threshold // 2)
        return self.threshold


class BottleneckDetector:
    """Periodically checks whether achieved bandwidth saturates the device.

    When utilisation >= `saturation` the storage is bandwidth-bound and collapse
    is disabled (paper: "the system defaults to the original read strategy").
    """

    def __init__(self, device_bandwidth: float, saturation: float = 0.9, period: int = 16) -> None:
        self.device_bandwidth = device_bandwidth
        self.saturation = saturation
        self.period = period
        self._bytes = 0.0
        self._time = 0.0
        self._calls = 0
        self.collapse_enabled = True

    def record(self, nbytes: float, seconds: float) -> None:
        self._bytes += nbytes
        self._time += seconds
        self._calls += 1
        if self._calls % self.period == 0:
            achieved = self._bytes / max(self._time, 1e-12)
            self.collapse_enabled = achieved < self.saturation * self.device_bandwidth
            self._bytes = self._time = 0.0
