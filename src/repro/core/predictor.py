"""Per-layer activation predictor (paper Fig. 3 step 1; à la Deja Vu / LLMFlash).

A small bottleneck MLP maps the pre-FFN hidden state to per-neuron activation
logits; neurons with sigmoid(logit) > threshold are predicted active. Trained
in JAX with Adam on (hidden_state, activation_mask) pairs from traces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterator, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PredictorParams(NamedTuple):
    w1: jnp.ndarray   # [d_model, d_hidden]
    b1: jnp.ndarray   # [d_hidden]
    w2: jnp.ndarray   # [d_hidden, n_neurons]
    b2: jnp.ndarray   # [n_neurons]


@dataclasses.dataclass
class PredictorConfig:
    d_model: int
    n_neurons: int
    d_hidden: int = 128
    threshold: float = 0.5
    lr: float = 1e-3
    pos_weight: float = 2.0   # recall matters more: a missed neuron corrupts output


def init_predictor(cfg: PredictorConfig, key: jax.Array) -> PredictorParams:
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(cfg.d_model)
    s2 = 1.0 / np.sqrt(cfg.d_hidden)
    return PredictorParams(
        w1=jax.random.normal(k1, (cfg.d_model, cfg.d_hidden), jnp.float32) * s1,
        b1=jnp.zeros(cfg.d_hidden),
        w2=jax.random.normal(k2, (cfg.d_hidden, cfg.n_neurons), jnp.float32) * s2,
        b2=jnp.zeros(cfg.n_neurons),
    )


def predictor_logits(params: PredictorParams, h: jnp.ndarray) -> jnp.ndarray:
    z = jax.nn.relu(h @ params.w1 + params.b1)
    return z @ params.w2 + params.b2


def predict_mask(params: PredictorParams, h: jnp.ndarray, threshold: float = 0.5) -> jnp.ndarray:
    return jax.nn.sigmoid(predictor_logits(params, h)) > threshold


@partial(jax.jit, static_argnames=("pos_weight",))
def _loss(params: PredictorParams, h, y, pos_weight: float = 2.0):
    logits = predictor_logits(params, h)
    y = y.astype(jnp.float32)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    w = jnp.where(y > 0, pos_weight, 1.0)
    return jnp.mean(per * w)


@partial(jax.jit, static_argnames=("lr", "pos_weight"))
def _adam_step(params: PredictorParams, mu, nu, step, h, y, lr: float,
               pos_weight: float):
    loss, grads = jax.value_and_grad(_loss)(params, h, y, pos_weight)
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, nu, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, mu, nu)
    return new, mu, nu, step, loss


def train_predictor(
    cfg: PredictorConfig,
    hiddens: np.ndarray,
    masks: np.ndarray,
    epochs: int = 5,
    batch_size: int = 256,
    seed: int = 0,
) -> Tuple[PredictorParams, float]:
    """Fit on [T, d_model] hiddens / [T, n] masks with Adam."""
    params = init_predictor(cfg, jax.random.PRNGKey(seed))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    mu, nu = zeros, zeros
    step = jnp.zeros((), jnp.float32)
    rng = np.random.default_rng(seed)
    n = hiddens.shape[0]
    loss = float("nan")
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n, batch_size):
            idx = order[s : s + batch_size]
            params, mu, nu, step, loss = _adam_step(
                params, mu, nu, step, jnp.asarray(hiddens[idx]),
                jnp.asarray(masks[idx]), cfg.lr, cfg.pos_weight)
    return params, float(loss)


def recall_precision(params: PredictorParams, hiddens: np.ndarray, masks: np.ndarray,
                     threshold: float = 0.5) -> Tuple[float, float]:
    pred = np.asarray(predict_mask(params, jnp.asarray(hiddens), threshold))
    truth = np.asarray(masks, dtype=bool)
    tp = float(np.sum(pred & truth))
    recall = tp / max(float(truth.sum()), 1.0)
    precision = tp / max(float(pred.sum()), 1.0)
    return recall, precision
