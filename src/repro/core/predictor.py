"""Per-layer activation predictor (paper Fig. 3 step 1; à la Deja Vu / LLMFlash).

A small bottleneck MLP maps the pre-FFN hidden state to per-neuron activation
logits; neurons with sigmoid(logit) > threshold are predicted active. Trained
in JAX with Adam on (hidden_state, activation_mask) pairs from traces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterator, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PredictorParams(NamedTuple):
    w1: jnp.ndarray   # [d_model, d_hidden]
    b1: jnp.ndarray   # [d_hidden]
    w2: jnp.ndarray   # [d_hidden, n_neurons]
    b2: jnp.ndarray   # [n_neurons]


@dataclasses.dataclass
class PredictorConfig:
    d_model: int
    n_neurons: int
    d_hidden: int = 128
    threshold: float = 0.5
    lr: float = 1e-3
    pos_weight: float = 2.0   # recall matters more: a missed neuron corrupts output


def init_predictor(cfg: PredictorConfig, key: jax.Array) -> PredictorParams:
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(cfg.d_model)
    s2 = 1.0 / np.sqrt(cfg.d_hidden)
    return PredictorParams(
        w1=jax.random.normal(k1, (cfg.d_model, cfg.d_hidden), jnp.float32) * s1,
        b1=jnp.zeros(cfg.d_hidden),
        w2=jax.random.normal(k2, (cfg.d_hidden, cfg.n_neurons), jnp.float32) * s2,
        b2=jnp.zeros(cfg.n_neurons),
    )


def predictor_logits(params: PredictorParams, h: jnp.ndarray) -> jnp.ndarray:
    z = jax.nn.relu(h @ params.w1 + params.b1)
    return z @ params.w2 + params.b2


def predict_mask(params: PredictorParams, h: jnp.ndarray, threshold: float = 0.5) -> jnp.ndarray:
    return jax.nn.sigmoid(predictor_logits(params, h)) > threshold


def predict_mask_np(params_np: Tuple[np.ndarray, ...], h: np.ndarray,
                    threshold: float = 0.5) -> np.ndarray:
    """Pure-numpy predictor inference for the serving thread's lookahead: the
    prefetch pipeline needs the speculative mask on HOST (to hand to the I/O
    worker) without a jax dispatch competing with the decode computation.
    `params_np` is the PredictorParams tuple as numpy arrays (see
    `as_numpy_params`); sigmoid(logit) > t is evaluated as logit > logit(t).
    """
    w1, b1, w2, b2 = params_np
    z = np.maximum(h @ w1 + b1, 0.0)
    logits = z @ w2 + b2
    cut = np.log(threshold / (1.0 - threshold))
    return logits > cut


def as_numpy_params(params: PredictorParams) -> Tuple[np.ndarray, ...]:
    """Host-side copies of predictor params for `predict_mask_np`."""
    return tuple(np.asarray(p) for p in params)


@partial(jax.jit, static_argnames=("pos_weight",))
def _loss(params: PredictorParams, h, y, pos_weight: float = 2.0):
    logits = predictor_logits(params, h)
    y = y.astype(jnp.float32)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    w = jnp.where(y > 0, pos_weight, 1.0)
    return jnp.mean(per * w)


@partial(jax.jit, static_argnames=("lr", "pos_weight"))
def _adam_step(params: PredictorParams, mu, nu, step, h, y, lr: float,
               pos_weight: float):
    loss, grads = jax.value_and_grad(_loss)(params, h, y, pos_weight)
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, nu, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    new = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, mu, nu)
    return new, mu, nu, step, loss


def train_predictor(
    cfg: PredictorConfig,
    hiddens: np.ndarray,
    masks: np.ndarray,
    epochs: int = 5,
    batch_size: int = 256,
    seed: int = 0,
) -> Tuple[PredictorParams, float]:
    """Fit on [T, d_model] hiddens / [T, n] masks with Adam."""
    params = init_predictor(cfg, jax.random.PRNGKey(seed))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    mu, nu = zeros, zeros
    step = jnp.zeros((), jnp.float32)
    rng = np.random.default_rng(seed)
    n = hiddens.shape[0]
    loss = float("nan")
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n, batch_size):
            idx = order[s : s + batch_size]
            params, mu, nu, step, loss = _adam_step(
                params, mu, nu, step, jnp.asarray(hiddens[idx]),
                jnp.asarray(masks[idx]), cfg.lr, cfg.pos_weight)
    return params, float(loss)


def train_lookahead_predictors(
    hiddens_per_layer: np.ndarray,      # [L, T, d_model] pre-FFN hidden states
    masks_per_layer: np.ndarray,        # [L, T, n_neurons] activation masks
    d_hidden: int = 64,
    threshold: float = 0.35,
    pos_weight: float = 4.0,
    epochs: int = 4,
    lr: float = 1e-3,
    seed: int = 0,
) -> list:
    """Cross-layer lookahead predictors for the asynchronous prefetch pipeline.

    Predictor k maps layer k's pre-FFN hidden state to layer k+1's activation
    mask — exactly the signal available one layer EARLY, so a background I/O
    worker can probe the cache and read flash for layer k+1 while the device
    still computes layer k's FFN. Returns L-1 `PredictorParams` (entry k
    predicts layer k+1 from layer k).

    Tuned to over-predict (low threshold, recall-weighted loss): a neuron the
    lookahead misses costs a synchronous top-up read on the serving thread,
    while an over-predicted neuron only inflates the prefetch read that is
    hidden behind compute anyway.
    """
    hiddens = np.asarray(hiddens_per_layer)
    masks = np.asarray(masks_per_layer)
    L = hiddens.shape[0]
    params = []
    for k in range(L - 1):
        cfg = PredictorConfig(
            d_model=hiddens.shape[-1], n_neurons=masks.shape[-1],
            d_hidden=d_hidden, threshold=threshold, lr=lr,
            pos_weight=pos_weight)
        p, _ = train_predictor(cfg, hiddens[k], masks[k + 1],
                               epochs=epochs, seed=seed + k)
        params.append(p)
    return params


def recall_precision(params: PredictorParams, hiddens: np.ndarray, masks: np.ndarray,
                     threshold: float = 0.5) -> Tuple[float, float]:
    pred = np.asarray(predict_mask(params, jnp.asarray(hiddens), threshold))
    truth = np.asarray(masks, dtype=bool)
    tp = float(np.sum(pred & truth))
    recall = tp / max(float(truth.sum()), 1.0)
    precision = tp / max(float(pred.sum()), 1.0)
    return recall, precision
