"""Named counters, gauges, and log-bucketed histograms with snapshot/delta.

The registry is additive infrastructure: existing stat objects
(``ServerStats``, ``IOScheduler.summary()``, ...) *register into* it via
gauge callables rather than being replaced, so nothing about the legacy
reporting surface changes.

- :class:`Counter` — monotonically increasing, lock-guarded ``inc``.
- :class:`Gauge` — last-set value, or a zero-arg callable evaluated at
  read time (``set_fn``), which is how live objects expose their state.
- :class:`Histogram` — base-2 log-bucketed (bucket key is the binary
  exponent from ``math.frexp``), tracking count/sum/min/max. Cheap enough
  for per-step observation.

``MetricsRegistry.snapshot()`` returns a plain-dict view;
``MetricsRegistry.delta(prev)`` subtracts counter values and histogram
counts, while gauges always report their current reading.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_metrics",
    "set_metrics",
]


class Counter:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        self._fn = None
        self._value = value

    def set_fn(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._value


class Histogram:
    """Base-2 log-bucketed histogram of non-negative observations.

    Bucket ``e`` holds values ``v`` with ``2**(e-1) <= v < 2**e``
    (``math.frexp(v)[1] == e``); zero/negative values land in the
    sentinel bucket ``"zero"``.
    """

    __slots__ = ("name", "count", "sum", "min", "max", "buckets", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: Dict[Any, int] = {}
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        key: Any = "zero" if value <= 0 else math.frexp(value)[1]
        with self._lock:
            self.count += 1
            self.sum += value
            if value < self.min:
                self.min = value
            if value > self.max:
                self.max = value
            self.buckets[key] = self.buckets.get(key, 0) + 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": dict(self.buckets),
            }


class MetricsRegistry:
    """Create-or-get registry of named metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def register_gauge(self, name: str, fn: Callable[[], float]) -> Gauge:
        """Register (or re-point) a gauge backed by a live callable."""
        g = self.gauge(name)
        g.set_fn(fn)
        return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name)
            return h

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        gauge_values = {}
        for name, g in gauges.items():
            try:
                gauge_values[name] = g.value
            except Exception:
                # A gauge callable may outlive the object it reads from.
                gauge_values[name] = None
        return {
            "counters": {name: c.value for name, c in counters.items()},
            "gauges": gauge_values,
            "histograms": {name: h.snapshot() for name, h in hists.items()},
        }

    def delta(self, prev: dict, cur: Optional[dict] = None) -> dict:
        """Difference of two snapshots: counters/histogram counts subtract,
        gauges report the current reading."""
        if cur is None:
            cur = self.snapshot()
        d_counters = {
            name: value - prev.get("counters", {}).get(name, 0)
            for name, value in cur["counters"].items()
        }
        d_hists = {}
        for name, h in cur["histograms"].items():
            p = prev.get("histograms", {}).get(name, {})
            p_buckets = p.get("buckets", {})
            d_hists[name] = {
                "count": h["count"] - p.get("count", 0),
                "sum": h["sum"] - p.get("sum", 0.0),
                "buckets": {
                    k: v - p_buckets.get(k, 0)
                    for k, v in h["buckets"].items()
                    if v - p_buckets.get(k, 0)
                },
            }
        return {"counters": d_counters, "gauges": dict(cur["gauges"]), "histograms": d_hists}


_METRICS = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    """The process-global metrics registry."""
    return _METRICS


def set_metrics(registry: MetricsRegistry) -> MetricsRegistry:
    """Install a registry globally (tests use this); returns the previous one."""
    global _METRICS
    prev = _METRICS
    _METRICS = registry
    return prev
