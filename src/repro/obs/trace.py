"""Low-overhead tracing with Chrome trace-event / Perfetto JSON export.

Design goals (ISSUE 10):

- **Thread-safe without hot-path locks.** Each thread records into its own
  fixed-capacity ring buffer; the only lock guards ring *creation* and
  export-time iteration.
- **Zero work when disabled.** The module-level tracer defaults to a shared
  :class:`NullTracer` whose ``span``/``instant``/``counter`` methods do
  nothing and return a shared no-op context manager, so call sites never
  branch on "is tracing on?".
- **Perfetto-compatible export.** ``export()`` emits Chrome trace-event JSON
  (``ph:"X"`` complete spans, ``ph:"i"`` instants, ``ph:"C"`` counter
  tracks, ``ph:"M"`` thread-name metadata) that loads directly in
  https://ui.perfetto.dev or ``chrome://tracing``.

Timestamps come from ``time.perf_counter`` (monotonic), rebased to the
tracer's construction time and expressed in microseconds, which is the unit
the trace-event format expects.

Virtual tracks: ``complete(..., track="req 7")`` and
``instant(..., track=...)`` place events on a named synthetic thread lane
instead of the calling thread's lane.  The server uses this to give every
request its own row of per-token decode spans.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "enable_tracing",
    "disable_tracing",
]

# Synthetic tids for named virtual tracks start here so they never collide
# with real thread idents in practice (and collisions would only merge lanes
# in the viewer, never corrupt data).
_TRACK_TID_BASE = 1_000_000

# Event tuple layout: (ts_us, dur_us_or_None, ph, name, tid, args_or_None)
_Event = Tuple[float, Optional[float], str, str, int, Optional[dict]]


class _Ring:
    """Fixed-capacity single-writer ring buffer of trace events."""

    __slots__ = ("cap", "buf", "idx", "total")

    def __init__(self, cap: int):
        self.cap = int(cap)
        self.buf: List[Optional[_Event]] = [None] * self.cap
        self.idx = 0
        self.total = 0

    def append(self, ev: _Event) -> None:
        self.buf[self.idx] = ev
        self.idx += 1
        if self.idx == self.cap:
            self.idx = 0
        self.total += 1

    def events(self) -> List[_Event]:
        if self.total <= self.cap:
            return [e for e in self.buf[: self.total] if e is not None]
        # Oldest event sits at idx (the next overwrite target).
        out = self.buf[self.idx :] + self.buf[: self.idx]
        return [e for e in out if e is not None]

    @property
    def dropped(self) -> int:
        return max(0, self.total - self.cap)


class _Span:
    """Context manager recording a ``ph:"X"`` complete event on exit.

    ``set(**kw)`` attaches late args (values only known mid-span, e.g. extent
    counts after a read returns).
    """

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **kw: Any) -> None:
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.now()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = self._tracer.now()
        self._tracer._emit(self._t0, t1 - self._t0, "X", self.name, self.args)
        return False


class Tracer:
    """Records spans/instants/counters into per-thread ring buffers."""

    enabled = True

    def __init__(
        self,
        capacity_per_thread: int = 65536,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if capacity_per_thread < 1:
            raise ValueError("capacity_per_thread must be >= 1")
        self.capacity_per_thread = int(capacity_per_thread)
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        # tid -> (ring, thread name at first event)
        self._rings: Dict[int, Tuple[_Ring, str]] = {}
        self._tracks: Dict[str, int] = {}
        self._local = threading.local()
        self.pid = os.getpid()

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Microseconds since tracer construction (monotonic)."""
        return (self._clock() - self._t0) * 1e6

    # -- recording -----------------------------------------------------------

    def _ring(self) -> _Ring:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            tid = threading.get_ident()
            ring = _Ring(self.capacity_per_thread)
            with self._lock:
                self._rings[tid] = (ring, threading.current_thread().name)
            self._local.ring = ring
            self._local.tid = tid
        return ring

    def _emit(
        self,
        ts_us: float,
        dur_us: Optional[float],
        ph: str,
        name: str,
        args: Optional[dict],
        tid: Optional[int] = None,
    ) -> None:
        ring = self._ring()
        if tid is None:
            tid = self._local.tid
        ring.append((ts_us, dur_us, ph, name, tid, args))

    def _track_tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(
                    track, _TRACK_TID_BASE + len(self._tracks)
                )
        return tid

    def span(self, name: str, **args: Any) -> _Span:
        """Context manager timing a block as a complete event."""
        return _Span(self, name, args or None)

    def complete(
        self,
        name: str,
        start_us: float,
        end_us: float,
        track: Optional[str] = None,
        **args: Any,
    ) -> None:
        """Record a retrospective span from explicit ``now()`` timestamps."""
        tid = self._track_tid(track) if track is not None else None
        self._emit(start_us, max(0.0, end_us - start_us), "X", name, args or None, tid=tid)

    def instant(self, name: str, track: Optional[str] = None, **args: Any) -> None:
        tid = self._track_tid(track) if track is not None else None
        self._emit(self.now(), None, "i", name, args or None, tid=tid)

    def counter(self, name: str, **values: float) -> None:
        """Record a point on a counter track (stacked area chart in Perfetto)."""
        self._emit(self.now(), None, "C", name, values)

    # -- introspection -------------------------------------------------------

    @property
    def n_events(self) -> int:
        with self._lock:
            return sum(r.total for r, _ in self._rings.values())

    @property
    def dropped(self) -> int:
        with self._lock:
            return sum(r.dropped for r, _ in self._rings.values())

    # -- export --------------------------------------------------------------

    def events(self) -> List[dict]:
        """All retained events as trace-event dicts, sorted by timestamp.

        Thread-name metadata (``ph:"M"``) rows come first so viewers label
        lanes.  Safe to call while other threads are still recording; events
        appended concurrently may or may not be included.
        """
        with self._lock:
            rings = list(self._rings.items())
            tracks = dict(self._tracks)
        out: List[dict] = []
        for tid, (_, tname) in rings:
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": self.pid,
                    "tid": tid,
                    "args": {"name": tname},
                }
            )
        for track, tid in tracks.items():
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": self.pid,
                    "tid": tid,
                    "args": {"name": track},
                }
            )
        body: List[dict] = []
        for tid, (ring, _) in rings:
            for ts_us, dur_us, ph, name, ev_tid, args in ring.events():
                ev: dict = {
                    "name": name,
                    "ph": ph,
                    "ts": ts_us,
                    "pid": self.pid,
                    "tid": ev_tid,
                }
                if ph == "X":
                    ev["dur"] = dur_us if dur_us is not None else 0.0
                elif ph == "i":
                    ev["s"] = "t"
                if args:
                    ev["args"] = args
                body.append(ev)
        body.sort(key=lambda e: e["ts"])
        return out + body

    def export(self, path: Optional[str] = None) -> List[dict]:
        """Export events; if ``path`` is given, write Perfetto-loadable JSON."""
        events = self.events()
        if path is not None:
            doc = {
                "traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs", "dropped_events": self.dropped},
            }
            with open(path, "w") as f:
                json.dump(doc, f)
        return events


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **kw: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: the default so instrumentation sites never branch."""

    enabled = False
    capacity_per_thread = 0
    pid = 0
    n_events = 0
    dropped = 0

    def now(self) -> float:
        return 0.0

    def span(self, name: str, **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def complete(self, *a: Any, **kw: Any) -> None:
        pass

    def instant(self, *a: Any, **kw: Any) -> None:
        pass

    def counter(self, *a: Any, **kw: Any) -> None:
        pass

    def events(self) -> List[dict]:
        return []

    def export(self, path: Optional[str] = None) -> List[dict]:
        return []


NULL_TRACER = NullTracer()

_TRACER: Any = NULL_TRACER


def get_tracer() -> Any:
    """The process-global tracer (a :class:`NullTracer` unless enabled)."""
    return _TRACER


def set_tracer(tracer: Optional[Any]) -> Any:
    """Install ``tracer`` globally (None → null tracer); returns the previous one."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER
    return prev


def enable_tracing(capacity_per_thread: int = 65536) -> Tracer:
    """Install and return a fresh recording :class:`Tracer`."""
    tracer = Tracer(capacity_per_thread=capacity_per_thread)
    set_tracer(tracer)
    return tracer


def disable_tracing() -> Any:
    """Restore the null tracer; returns the tracer that was active."""
    return set_tracer(NULL_TRACER)
