"""Per-request timeline view for SLO debugging.

``request_timeline(handle)`` folds a :class:`RequestHandle`'s lifecycle
stamps (queued/admitted/first-token/finished on the server's monotonic
clock) and per-token stamps into a phase breakdown, and — when a recording
tracer is active — attaches the trace spans tagged with the request's uid.

All phase times are SECONDS RELATIVE TO ``queued_at`` (the handle's clock),
independent of the tracer's microsecond clock; the attached spans keep the
tracer's own timebase so they can be cross-referenced with an exported
trace.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.trace import get_tracer

__all__ = ["request_timeline"]


def request_timeline(handle: Any, tracer: Optional[Any] = None) -> Dict[str, Any]:
    """Build a timeline dict for one request handle.

    Keys: ``uid``, ``finish_reason``, ``phases`` (name -> {start, end,
    seconds}, relative to queued_at), ``ttft``, ``tokens`` (per-token
    {t, gap}), ``itl`` (count/mean/max inter-token gap), ``slo``
    (resolved ttft/itl SLOs + whether each was met), and ``spans`` (trace
    events whose args carry this uid, empty when tracing is disabled).
    """
    tr = tracer if tracer is not None else get_tracer()
    q = handle.queued_at
    rel = lambda t: None if t is None else t - q  # noqa: E731

    phases: Dict[str, Dict[str, Optional[float]]] = {}

    def phase(name: str, start: Optional[float], end: Optional[float]) -> None:
        if start is None:
            return
        phases[name] = {
            "start": rel(start),
            "end": rel(end),
            "seconds": None if end is None else end - start,
        }

    phase("queued", q, handle.admitted_at if handle.admitted_at is not None
          else handle.finished_at)
    if handle.admitted_at is not None:
        phase("prefill", handle.admitted_at, handle.first_token_at)
    if handle.first_token_at is not None:
        phase("decode", handle.first_token_at, handle.finished_at)

    token_times: List[float] = list(handle.token_times)
    tokens = []
    gaps = []
    prev = None
    for t in token_times:
        gap = None if prev is None else t - prev
        if gap is not None:
            gaps.append(gap)
        tokens.append({"t": rel(t), "gap": gap})
        prev = t

    ttft = (handle.first_token_at - q
            if handle.first_token_at is not None else None)
    itl = {
        "count": len(gaps),
        "mean": sum(gaps) / len(gaps) if gaps else None,
        "max": max(gaps) if gaps else None,
    }
    slo = {
        "ttft_slo": handle.ttft_slo,
        "itl_slo": handle.itl_slo,
        "ttft_met": (None if handle.ttft_slo is None or ttft is None
                     else ttft <= handle.ttft_slo),
        "itl_met": (None if handle.itl_slo is None or not gaps
                    else max(gaps) <= handle.itl_slo),
    }

    uid = handle.uid
    spans = [ev for ev in tr.events()
             if ev.get("args", {}).get("uid") == uid]

    return {
        "uid": uid,
        "state": handle.state.value,
        "finish_reason": handle.finish_reason,
        "n_tokens": len(handle.tokens),
        "phases": phases,
        "ttft": ttft,
        "total": rel(handle.finished_at),
        "tokens": tokens,
        "itl": itl,
        "slo": slo,
        "io_seconds": handle.io_seconds,
        "prefill_seconds": handle.prefill_seconds,
        "decode_seconds": handle.decode_seconds,
        "spans": spans,
    }
