"""repro.obs — unified tracing + metrics for the serving stack (ISSUE 10).

Two cooperating pieces:

- :mod:`repro.obs.trace` — a :class:`Tracer` recording spans / instants /
  counter points into per-thread ring buffers, exported as Chrome
  trace-event JSON that loads in https://ui.perfetto.dev. Disabled by
  default via a no-op singleton, so instrumentation sites cost ~a no-op
  method call when tracing is off (gated <1% of step time by
  ``benchmarks/obs_overhead.py``; <5% enabled).
- :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges, and log-bucketed histograms with ``snapshot()``/``delta()``
  semantics. Existing stat objects register gauge callables into it.

Typical capture::

    from repro.obs import enable_tracing
    tracer = enable_tracing()
    ...   # run the server / engine
    tracer.export("trace.json")   # open in ui.perfetto.dev
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_metrics, set_metrics)
from repro.obs.timeline import request_timeline
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, disable_tracing,
                             enable_tracing, get_tracer, set_tracer)

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "enable_tracing",
    "disable_tracing",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_metrics",
    "set_metrics",
    "request_timeline",
]
