"""Distributed training driver.

Builds a mesh over the available devices, shards the train state with the
distributed/sharding.py rules, and runs the training loop under jit with
explicit in/out shardings — the same program the dry-run lowers for the
production mesh, executed for real on whatever devices exist (CPU here,
TPU pod on the target).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 50 --batch 8 --seq 128 [--model-axis 2] \
      [--checkpoint ckpt/state.npz] [--resume]
"""
import argparse
import os
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.data.pipeline import DataConfig, make_data_iter
from repro.distributed.sharding import batch_spec, named, param_specs
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, AdamWState, init_adamw
from repro.training.train import TrainState, make_train_step
from repro.utils import logger, pretty_bytes, tree_size_bytes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=0, help="override vocab (reduced runs)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    overrides = {}
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    model = build_model(cfg)
    mesh = make_host_mesh(model_axis=args.model_axis)
    logger.info("mesh: %s over %d devices", dict(mesh.shape), mesh.devices.size)

    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 2),
                          total_steps=args.steps)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    state = TrainState(params=params, opt=init_adamw(params, opt_cfg))
    logger.info("params: %s", pretty_bytes(tree_size_bytes(params)))

    start_step = 0
    if args.resume and args.checkpoint and os.path.exists(args.checkpoint):
        state, meta = load_checkpoint(args.checkpoint, state)
        start_step = int(meta.get("step", 0))
        logger.info("resumed from %s at step %d", args.checkpoint, start_step)

    pspecs = param_specs(jax.eval_shape(model.init_params,
                                        jax.random.PRNGKey(0)), mesh)
    sspecs = TrainState(params=pspecs,
                        opt=AdamWState(step=P(), mu=pspecs, nu=pspecs))
    bspec = {"tokens": batch_spec(mesh, args.batch, 2)}
    state = jax.device_put(state, named(sspecs, mesh))

    step_fn = make_train_step(model, opt_cfg, microbatches=args.microbatches)
    state_struct = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    metrics_shape = jax.eval_shape(
        step_fn, state_struct,
        {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32)})[1]
    mspecs = jax.tree_util.tree_map(lambda _: P(), metrics_shape)
    jitted = jax.jit(step_fn,
                     in_shardings=(named(sspecs, mesh), named(bspec, mesh)),
                     out_shardings=(named(sspecs, mesh), named(mspecs, mesh)),
                     donate_argnums=(0,))

    data = make_data_iter(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                     batch_size=args.batch, seed=args.seed))
    t0 = time.perf_counter()
    with mesh:
        for step in range(start_step, args.steps):
            batch = jax.device_put(next(data), named(bspec, mesh))
            state, metrics = jitted(state, batch)
            if step % max(args.steps // 20, 1) == 0 or step == args.steps - 1:
                logger.info("step %4d  loss=%.4f  grad_norm=%.3f  lr=%.2e",
                            step, float(metrics["loss"]),
                            float(metrics["grad_norm"]), float(metrics["lr"]))
            if (args.checkpoint and args.checkpoint_every
                    and (step + 1) % args.checkpoint_every == 0):
                save_checkpoint(args.checkpoint, jax.device_get(state),
                                {"step": step + 1, "arch": args.arch})
    dt = time.perf_counter() - t0
    tokens = (args.steps - start_step) * args.batch * args.seq
    logger.info("done: %.1fs, %.0f tokens/s", dt, tokens / dt)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, jax.device_get(state),
                        {"step": args.steps, "arch": args.arch})
        logger.info("final checkpoint: %s", args.checkpoint)


if __name__ == "__main__":
    main()
