"""Offline packing driver: run the paper's whole offline stage and write the
deployable NeuronPack artifact.

  PYTHONPATH=src python -m repro.launch.pack --arch qwen2-7b --reduced \
      --out model.npack [--calib-tokens 512] [--quantize int8] \
      [--no-placement] [--placement-mode auto|exact|topk] \
      [--d-model N] [--d-ff N] [--n-layers N]

The pack records the model's flash bundles in physical (linked-placement)
order plus the per-layer placement tables; serve it with
``repro.launch.serve --mode offload --pack model.npack`` built from the SAME
--arch/--seed/geometry flags (weights are deterministic from the seed, and
load-time validation rejects geometry mismatches).
"""
import argparse
import time

import jax

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.models import build_model
from repro.store.packer import build_pack
from repro.utils import add_verbosity_flag, configure_logging, get_logger

logger = get_logger("launch.pack")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--out", required=True, help="output NeuronPack path")
    ap.add_argument("--calib-tokens", type=int, default=512,
                    help="random calibration tokens to trace (streamed to "
                         "disk shards, so this can exceed RAM)")
    ap.add_argument("--calib-batch", type=int, default=8)
    ap.add_argument("--calib-seqlen", type=int, default=64)
    ap.add_argument("--quantize", choices=("none", "int8"), default="none",
                    help="int8 = per-neuron symmetric quantized bundles with "
                         "float32 scales")
    ap.add_argument("--no-placement", action="store_true",
                    help="identity layout (LLMFlash-style baseline pack)")
    ap.add_argument("--placement-mode", choices=("auto", "exact", "topk"),
                    default="auto")
    ap.add_argument("--pack-version", type=int, choices=(1, 2), default=2,
                    help="NeuronPack format version: 2 (default) adds the "
                         "header CRC + per-bundle CRC32 tables that "
                         "--verify-checksums serving checks; 1 writes the "
                         "legacy checksum-free layout")
    ap.add_argument("--shard-dir", default=None,
                    help="keep trace shards here (default: temp dir, deleted)")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    add_verbosity_flag(ap)
    args = ap.parse_args(argv)
    configure_logging(args.verbose)

    overrides = dict(vocab_size=args.vocab, activation="relu")
    for key in ("d_model", "d_ff", "n_layers"):
        val = getattr(args, key)
        if val is not None:
            overrides[key] = val
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    if cfg.family != "dense" or cfg.is_encdec:
        raise SystemExit("packing is implemented for dense decoder-only archs")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    t0 = time.perf_counter()
    report = build_pack(
        model, params, args.out,
        calib_tokens=args.calib_tokens, calib_batch=args.calib_batch,
        calib_seqlen=args.calib_seqlen, seed=args.seed,
        use_placement=not args.no_placement,
        placement_mode=args.placement_mode, quantize=args.quantize,
        shard_dir=args.shard_dir, pack_version=args.pack_version,
        meta=dict(arch=args.arch, seed=args.seed, vocab_size=cfg.vocab_size))
    logger.info(
        "packed %d layers x %d neurons x %d floats -> %s (%.1f MB, %s, "
        "%s layout) in %.1fs: traced %d tokens, placement search %.2fs",
        report.n_layers, report.n_neurons, report.bundle_width, report.path,
        report.file_bytes / 1e6,
        "int8" if report.quantized else "float32", report.placement_mode,
        time.perf_counter() - t0, report.tokens_traced, report.search_seconds)
    logger.info("serve it: PYTHONPATH=src python -m repro.launch.serve "
                "--arch %s --mode offload --pack %s --seed %d",
                args.arch, report.path, args.seed)


if __name__ == "__main__":
    main()
