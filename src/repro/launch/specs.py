"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

Follows the shannon/kernels pattern: weak-type-correct, shardable, zero device
allocation. For VLM the text length is seq_len - n_prefix_tokens so the total
decoder sequence matches the assigned shape; for audio the frames are the stub
frontend output and tokens run the full assigned seq_len on the decoder.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: InputShape, seq_len: int | None = None
                ) -> Dict[str, Any]:
    """Token/feature structs for a full-sequence pass (train or prefill)."""
    B = shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    if cfg.family == "vlm":
        return {
            "tokens": SDS((B, S - cfg.n_prefix_tokens), jnp.int32),
            "patch_feats": SDS((B, cfg.n_prefix_tokens, cfg.d_frontend), jnp.bfloat16),
        }
    if cfg.family == "audio":
        return {
            "tokens": SDS((B, S), jnp.int32),
            "frames": SDS((B, cfg.n_prefix_tokens, cfg.d_frontend), jnp.bfloat16),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_token_specs(shape: InputShape) -> Dict[str, Any]:
    return {
        "tokens": SDS((shape.global_batch, 1), jnp.int32),
        "position": SDS((), jnp.int32),
    }


def uses_swa_for(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k decode needs sub-quadratic memory: SWA ring for attention-
    dominated families; SSM/hybrid run natively (states / sparse attn layers)."""
    return shape.name == "long_500k" and cfg.family in ("dense", "vlm", "audio")


def cache_struct(cfg: ModelConfig, shape: InputShape, model) -> Any:
    swa = uses_swa_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: model.init_cache(B, S, swa=swa, dtype=jnp.bfloat16))
