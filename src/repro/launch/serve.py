"""Serving driver: slot-based continuous batching (InferenceServer), resident
or through the full RIPPLE offload runtime (predict -> batched engine step ->
sparse FFN from flash bundles, with double-buffered I/O-compute overlap).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --requests 8 --prompt-len 32 --new-tokens 16 \
      [--mode offload] [--slots 4] [--arrival-rate 2.0] [--burst 4] \
      [--queue-limit 16] [--ttft-slo 2.0] [--itl-slo 0.25] [--stream] \
      [--no-overlap] [--no-placement] [--kv-quant] \
      [--page-size 16 --num-pages 256 [--page-overcommit]]

`--slots N` fixes the decode-slot pool (default: one slot per request — the
one-shot batch). `--arrival-rate R` draws Poisson request arrivals at R req/s
(grouped `--burst` at a time for bursty traffic) and admits them mid-flight
as slots free up; `--stream` prints tokens as they are emitted. The overload
knobs `--queue-limit / --ttft-slo / --itl-slo` arm bounded-queue backpressure
and deadline retirement (finish_reason "rejected" / "timeout") — see the
README "Load testing & SLOs" section.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.core import EngineConfig, IOScheduler
from repro.models import build_model
from repro.obs import enable_tracing
from repro.serving.engine import Request, build_offload_runtime
from repro.serving.server import InferenceServer
from repro.utils import add_verbosity_flag, configure_logging, get_logger

logger = get_logger("launch.serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mode", choices=("resident", "offload"), default="resident",
                    help="offload = serve the decode FFNs from simulated flash")
    ap.add_argument("--offload", action="store_true",
                    help="deprecated alias for --mode offload")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode-slot pool size for continuous batching "
                         "(0 = one slot per request)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request arrivals per second; 0 = all "
                         "requests available at t=0")
    ap.add_argument("--burst", type=int, default=1,
                    help="arrival burst size: requests arrive in groups of "
                         "this many sharing one Poisson arrival instant "
                         "(inter-burst gap ~ Exp(burst/rate), so the mean "
                         "rate is unchanged); 1 = plain Poisson")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="bound the admission queue: a full queue sheds "
                         "lower-priority queued work or rejects the "
                         "newcomer (finish_reason='rejected'); 0 = unbounded")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="time-to-first-token deadline in seconds (monotonic "
                         "clock, submit -> first token); a queued request "
                         "that blows it is retired with "
                         "finish_reason='timeout'; 0 = none")
    ap.add_argument("--itl-slo", type=float, default=0.0,
                    help="inter-token latency deadline in seconds; an active "
                         "request whose gap between consecutive tokens "
                         "exceeds it is retired with finish_reason='timeout' "
                         "(partial tokens kept); also the budget for the "
                         "flash-I/O-aware admission gate in offload mode; "
                         "0 = none")
    ap.add_argument("--stream", action="store_true",
                    help="print each request's tokens as they are emitted")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable I/O-compute overlap in the offload scheduler")
    ap.add_argument("--prefetch", action="store_true",
                    help="EXECUTE the overlap: async layer-ahead prefetch "
                         "worker driven by trained cross-layer lookahead "
                         "predictors (mis-predictions topped up synchronously)")
    ap.add_argument("--no-placement", action="store_true",
                    help="identity flash layout (LLMFlash-style baseline)")
    ap.add_argument("--pack", default=None, metavar="PATH",
                    help="serve the decode FFNs from an on-disk NeuronPack "
                         "(built by repro.launch.pack with the same --arch/"
                         "--seed/geometry): REAL positional file reads per "
                         "collapsed extent. Mutually exclusive with the "
                         "synthetic in-memory flash (--no-placement)")
    ap.add_argument("--verify-checksums", action="store_true",
                    help="with --pack: verify every extent read against the "
                         "pack's per-bundle CRC32 table (format v2); a "
                         "detected corrupt read is re-read, not served")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: tokens per page (requires "
                         "--num-pages; 0 = contiguous per-slot caches). All "
                         "KV memory lives in one shared page arena; requests "
                         "map only the pages they fill, matched prompt "
                         "prefixes share pages copy-on-write, and admission "
                         "is gated by free pages instead of slot count")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged KV cache: total pages in the pool "
                         "(KV budget = num_pages * page_size positions)")
    ap.add_argument("--page-overcommit", action="store_true",
                    help="gate admission on the immediate prompt need only "
                         "(more concurrency; page pressure may preempt the "
                         "lowest-priority request, finish_reason='preempted') "
                         "instead of the strict worst-case reservation")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a Chrome trace-event / Perfetto timeline of "
                         "the whole run (server steps, engine reads, prefetch "
                         "worker, per-request lanes) and write it to PATH; "
                         "open it at https://ui.perfetto.dev")
    add_verbosity_flag(ap)
    args = ap.parse_args()
    configure_logging(args.verbose)
    tracer = enable_tracing() if args.trace_out else None
    if bool(args.page_size) != bool(args.num_pages):
        raise SystemExit("pass both --page-size and --num-pages, or neither")
    mode = "offload" if args.offload else args.mode
    if args.pack is not None:
        if mode != "offload":
            raise SystemExit("--pack requires --mode offload")
        if args.no_placement:
            raise SystemExit("--pack is mutually exclusive with "
                             "--no-placement: the layout is baked into the "
                             "pack (build an identity pack with "
                             "repro.launch.pack --no-placement)")

    overrides = dict(vocab_size=args.vocab, kv_quant=args.kv_quant)
    if mode == "offload":
        overrides["activation"] = "relu"   # ReLU sparsity (paper's setting)
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    offload = None
    scheduler = None
    if mode == "offload":
        if cfg.family != "dense" or cfg.is_encdec:
            raise SystemExit("--mode offload is implemented for dense decoder-only archs")
        t0 = time.perf_counter()
        if args.pack is not None:
            from repro.serving.engine import OffloadedFFNRuntime
            try:     # submit-time geometry validation against the model cfg
                offload = OffloadedFFNRuntime.from_pack(
                    cfg, args.pack, engine_cfg=EngineConfig(),
                    verify_checksums=args.verify_checksums)
            except ValueError as e:
                raise SystemExit(str(e))
            logger.info("offload runtime loaded from pack %s: %d layer "
                        "engines (real file extents) in %.2fs",
                        args.pack, offload.n_layers, time.perf_counter() - t0)
        else:
            offload = build_offload_runtime(
                model, params, rng=rng, engine_cfg=EngineConfig(),
                use_placement=not args.no_placement,
                train_lookahead=args.prefetch)
            logger.info("offload runtime calibrated: %d layer engines in %.2fs",
                        offload.n_layers, time.perf_counter() - t0)
        scheduler = IOScheduler(overlap=not args.no_overlap)

    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for i in range(args.requests)]
    if args.arrival_rate > 0:
        burst = max(args.burst, 1)
        n_bursts = -(-len(reqs) // burst)        # ceil
        burst_times = np.cumsum(
            rng.exponential(burst / args.arrival_rate, n_bursts))
        arrivals = np.repeat(burst_times, burst)[:len(reqs)]
    else:
        arrivals = np.zeros(len(reqs))

    on_token = None
    if args.stream:
        def on_token(uid: int, tok: int) -> None:
            logger.info("  [stream] req %d += token %d", uid, tok)

    server = InferenceServer(
        model, params, max_slots=args.slots or len(reqs),
        max_len=args.prompt_len + args.new_tokens + 8,
        mode=mode, offload=offload, scheduler=scheduler,
        prefetch=args.prefetch, seed=args.seed,
        queue_limit=args.queue_limit or None,
        ttft_slo_s=args.ttft_slo or None,
        itl_slo_s=args.itl_slo or None,
        page_size=args.page_size or None,
        num_pages=args.num_pages or None,
        page_overcommit=args.page_overcommit)
    handles = []
    t0 = time.perf_counter()
    try:
        i = 0
        while i < len(reqs) or server.has_work:
            now = time.perf_counter() - t0
            while i < len(reqs) and arrivals[i] <= now:
                handles.append(server.submit(reqs[i], on_token=on_token))
                i += 1
            if server.has_work:
                server.step()
            elif i < len(reqs):                 # idle until the next arrival
                time.sleep(min(arrivals[i] - now, 0.01))
    except KeyboardInterrupt:
        # graceful interrupt: retire every queued/in-flight request with
        # finish_reason="error" (partial tokens preserved), shut the
        # prefetch worker down cleanly, and fall through to the normal
        # result/stat flush instead of a traceback.
        n = server.abort("interrupted (KeyboardInterrupt)")
        logger.warning("interrupted: retired %d queued/in-flight requests; "
                       "flushing partial results", n)
    finally:
        server.close()
    wall = time.perf_counter() - t0
    results = [h.result for h in handles]
    n_tok = sum(len(r.tokens) for r in results)
    n_err = sum(r.finish_reason == "error" for r in results)
    logger.info("served %d requests, %d tokens in %.2fs (%.1f tok/s), "
                "slot occupancy %.0f%% over %d decode steps",
                len(results), n_tok, wall, n_tok / max(wall, 1e-9),
                server.stats.occupancy * 100, server.stats.decode_steps)
    if n_err:
        logger.warning("  %d request(s) finished with "
                       "finish_reason='error'", n_err)
    s = server.stats
    if s.rejected or s.shed or s.timeouts:
        logger.warning("overload: %d rejected, %d shed, %d deadline "
                       "timeouts (peak queue depth %d, %d I/O-gate "
                       "deferrals)", s.rejected, s.shed, s.timeouts,
                       s.peak_queue_depth, s.io_deferrals)
    for r in results[:3]:
        logger.info("  req %d: prefill %.0fms decode %.0fms io %.0fms "
                    "finish=%s -> %s...",
                    r.uid, r.prefill_seconds * 1e3, r.decode_seconds * 1e3,
                    r.io_seconds * 1e3, r.finish_reason, r.tokens[:6])

    pg = server.page_summary()
    if pg is not None:
        logger.info("paged KV: %d pages x %d tokens (%d KV positions, "
                    "quant=%s), peak occupancy %d pages; %d allocated / %d "
                    "freed over the run", pg["num_pages"], pg["page_size"],
                    pg["kv_positions"], pg["quantized"],
                    pg["peak_page_occupancy"], pg["pages_allocated"],
                    pg["pages_freed"])
        logger.info("  prefix sharing: %d hits, %d pages shared, %d CoW "
                    "copies, %d registry entries live (%d evicted); "
                    "pressure: %d page deferrals, %d preemptions",
                    pg["prefix_hits"], pg["pages_shared"], pg["cow_copies"],
                    pg["registry_entries"], pg["prefix_evictions"],
                    pg["page_deferrals"], pg["preemptions"])

    if mode == "offload":
        s = offload.io_summary()
        logger.info("offload I/O: %.2fms/token run_len=%.2f bw=%.0fMB/s hit=%.2f",
                    s["io_seconds_per_token"] * 1e3, s["mean_run_length"],
                    s["effective_bandwidth"] / 1e6, s["cache_hit_rate"])
        if s["retries"] or s["corrupt_extents"] or s["degraded_steps"] \
                or s["worker_restarts"]:
            logger.warning("fault tolerance engaged: %d retried reads, %d "
                           "corrupt extents caught, %d degraded steps, %d "
                           "worker restarts", s["retries"],
                           s["corrupt_extents"], s["degraded_steps"],
                           s["worker_restarts"])
        if "measured_file_seconds_per_token" in s:
            logger.info("pack file I/O MEASURED: %.3fms/token over %d real "
                        "extent reads (%.1f MB; page-cache-warm after the "
                        "first pass — see README caveat)",
                        s["measured_file_seconds_per_token"] * 1e3,
                        s["measured_extents_total"],
                        s["measured_bytes_total"] / 1e6)
        p = server.scheduler.summary()
        logger.info("pipeline (host-measured compute + modeled io): "
                    "serial %.2fms/token overlapped %.2fms/token "
                    "(%.1f%% hidden, overlap=%s)",
                    p["serial_seconds_per_token"] * 1e3,
                    p["overlapped_seconds_per_token"] * 1e3,
                    p["overlap_efficiency"] * 100, p["overlap_enabled"])
        if "measured_wall_seconds_per_token" in p:
            logger.info("prefetch MEASURED: wall %.2fms/token, io-worker busy "
                        "%.2fms, hidden %.2fms, exposed %.2fms (%.1f%% of "
                        "I/O host time off the critical path)",
                        p["measured_wall_seconds_per_token"] * 1e3,
                        p["measured_io_busy_seconds_per_token"] * 1e3,
                        p["measured_hidden_seconds_per_token"] * 1e3,
                        p["measured_exposed_seconds_per_token"] * 1e3,
                        p["measured_overlap_efficiency"] * 100)
    if offload is not None:
        offload.close()     # releases FileNeuronStore fds for --pack runs
    if tracer is not None:
        events = tracer.export(args.trace_out)
        logger.info("trace: %d events (%d dropped) -> %s; open it at "
                    "https://ui.perfetto.dev", len(events), tracer.dropped,
                    args.trace_out)


if __name__ == "__main__":
    main()
