"""Serving driver: batched prefill + decode, resident or through the full
RIPPLE offload runtime (predict -> batched engine step -> sparse FFN from
flash bundles, with double-buffered I/O-compute overlap accounting).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --requests 8 --prompt-len 32 --new-tokens 16 \
      [--mode offload] [--no-overlap] [--no-placement] [--kv-quant]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.core import EngineConfig, IOScheduler
from repro.models import build_model
from repro.serving.engine import (Request, ServingEngine,
                                  build_offload_runtime)
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mode", choices=("resident", "offload"), default="resident",
                    help="offload = serve the decode FFNs from simulated flash")
    ap.add_argument("--offload", action="store_true",
                    help="deprecated alias for --mode offload")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable I/O-compute overlap in the offload scheduler")
    ap.add_argument("--prefetch", action="store_true",
                    help="EXECUTE the overlap: async layer-ahead prefetch "
                         "worker driven by trained cross-layer lookahead "
                         "predictors (mis-predictions topped up synchronously)")
    ap.add_argument("--no-placement", action="store_true",
                    help="identity flash layout (LLMFlash-style baseline)")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    mode = "offload" if args.offload else args.mode

    overrides = dict(vocab_size=args.vocab, kv_quant=args.kv_quant)
    if mode == "offload":
        overrides["activation"] = "relu"   # ReLU sparsity (paper's setting)
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    offload = None
    scheduler = None
    if mode == "offload":
        if cfg.family != "dense" or cfg.is_encdec:
            raise SystemExit("--mode offload is implemented for dense decoder-only archs")
        t0 = time.perf_counter()
        offload = build_offload_runtime(
            model, params, rng=rng, engine_cfg=EngineConfig(),
            use_placement=not args.no_placement,
            train_lookahead=args.prefetch)
        scheduler = IOScheduler(overlap=not args.no_overlap)
        logger.info("offload runtime calibrated: %d layer engines in %.2fs",
                    offload.n_layers, time.perf_counter() - t0)

    engine = ServingEngine(model, params,
                           max_len=args.prompt_len + args.new_tokens + 8,
                           mode=mode, offload=offload, scheduler=scheduler,
                           prefetch=args.prefetch)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.serve(reqs, seed=args.seed)
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    logger.info("served %d requests, %d tokens in %.2fs (%.1f tok/s)",
                len(results), n_tok, wall, n_tok / wall)
    for r in results[:3]:
        logger.info("  req %d: prefill %.0fms decode %.0fms io %.0fms -> %s...",
                    r.uid, r.prefill_seconds * 1e3, r.decode_seconds * 1e3,
                    r.io_seconds * 1e3, r.tokens[:6])

    if mode == "offload":
        s = offload.io_summary()
        logger.info("offload I/O: %.2fms/token run_len=%.2f bw=%.0fMB/s hit=%.2f",
                    s["io_seconds_per_token"] * 1e3, s["mean_run_length"],
                    s["effective_bandwidth"] / 1e6, s["cache_hit_rate"])
        p = engine.scheduler.summary()
        logger.info("pipeline (host-measured compute + modeled io): "
                    "serial %.2fms/token overlapped %.2fms/token "
                    "(%.1f%% hidden, overlap=%s)",
                    p["serial_seconds_per_token"] * 1e3,
                    p["overlapped_seconds_per_token"] * 1e3,
                    p["overlap_efficiency"] * 100, p["overlap_enabled"])
        if "measured_wall_seconds_per_token" in p:
            logger.info("prefetch MEASURED: wall %.2fms/token, io-worker busy "
                        "%.2fms, hidden %.2fms, exposed %.2fms (%.1f%% of "
                        "I/O host time off the critical path)",
                        p["measured_wall_seconds_per_token"] * 1e3,
                        p["measured_io_busy_seconds_per_token"] * 1e3,
                        p["measured_hidden_seconds_per_token"] * 1e3,
                        p["measured_exposed_seconds_per_token"] * 1e3,
                        p["measured_overlap_efficiency"] * 100)


if __name__ == "__main__":
    main()
