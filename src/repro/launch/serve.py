"""Distributed serving driver: batched prefill + decode under jit shardings,
with optional RIPPLE offload accounting for the FFN weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --requests 8 --prompt-len 32 --new-tokens 16 [--offload] [--kv-quant]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_CONFIGS, get_config
from repro.core import (EngineConfig, identity_placement, search_placement,
                        stats_from_masks)
from repro.core.sparse_ffn import FFNWeights, make_bundles
from repro.models import build_model
from repro.serving.engine import OffloadedFFNRuntime, Request, ServingEngine
from repro.utils import logger


def _offload_runtime(cfg, model, params, rng):
    """Calibrate placements from a short trace and build the offload runtime."""
    if cfg.family != "dense" or cfg.is_encdec:
        raise SystemExit("--offload is implemented for dense decoder-only archs")
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32)
    out = model.forward(params, {"tokens": tokens}, capture_activations=True)
    L = out["ffn_pre_act"].shape[0]
    placements, bundles = [], []
    for l in range(L):
        masks = np.asarray(out["ffn_pre_act"][l] > 0).reshape(-1, cfg.d_ff)
        placements.append(search_placement(
            stats_from_masks(masks).distance_matrix(), mode="auto"))
        sub = params["stack"]["sub_0"]
        w = FFNWeights(w_up=sub["ffn"]["w_up"][l].T, w_down=sub["ffn"]["w_down"][l],
                       w_gate=(sub["ffn"]["w_gate"][l].T if "w_gate" in sub["ffn"]
                               else None))
        bundles.append(np.asarray(make_bundles(w)))
    return OffloadedFFNRuntime(cfg, bundles, placements), L


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--offload", action="store_true",
                    help="account FFN I/O through the RIPPLE flash engine")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    overrides = dict(vocab_size=args.vocab, kv_quant=args.kv_quant)
    if args.offload:
        overrides["activation"] = "relu"   # ReLU sparsity (paper's setting)
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    engine = ServingEngine(model, params,
                           max_len=args.prompt_len + args.new_tokens + 8)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.serve(reqs, seed=args.seed)
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    logger.info("served %d requests, %d tokens in %.2fs (%.1f tok/s)",
                len(results), n_tok, wall, n_tok / wall)
    for r in results[:3]:
        logger.info("  req %d: prefill %.0fms decode %.0fms -> %s...",
                    r.uid, r.prefill_seconds * 1e3, r.decode_seconds * 1e3,
                    r.tokens[:6])

    if args.offload:
        runtime, L = _offload_runtime(cfg, model, params, rng)
        h_stream = rng.standard_normal((n_tok, cfg.d_model)).astype(np.float32)
        sub = params["stack"]["sub_0"]
        for h in h_stream:
            for l in range(L):
                w_up = np.asarray(sub["ffn"]["w_up"][l]).T
                mask = (h[None] @ w_up.T) > 0
                runtime.ffn_apply(l, h[None], oracle_mask=mask)
        s = runtime.io_summary()
        logger.info("offload I/O: %.2fms/token run_len=%.2f bw=%.0fMB/s hit=%.2f",
                    s["io_seconds_per_token"] * 1e3, s["mean_run_length"],
                    s["effective_bandwidth"] / 1e6, s["cache_hit_rate"])


if __name__ == "__main__":
    main()
