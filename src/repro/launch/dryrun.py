import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each case this produces, with no device allocation beyond placeholders:
  * compiled.memory_analysis()  — proves the per-device working set fits
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective bytes parsed from the partitioned HLO text (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Results are saved under experiments/dryrun/ as JSON for benchmarks/roofline.py.
"""
import argparse
import json
import re
import time
from collections import defaultdict
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_CONFIGS, INPUT_SHAPES, get_config
from repro.distributed.sharding import (batch_spec, cache_specs, dp_axes,
                                        named, param_specs)
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, AdamWState, init_adamw
from repro.training.train import TrainState, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 2)
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (output-shape accounting)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:       # async pair: count the -start only
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


# per-arch gradient-accumulation defaults for train_4k (activation memory)
TRAIN_MICROBATCHES = defaultdict(lambda: 8)


def build_lowered(arch: str, shape_name: str, mesh,
                  microbatches: Optional[int] = None,
                  config_overrides: Optional[dict] = None,
                  options: Optional[dict] = None,
                  cfg=None):
    """Returns (lowered, meta) for one (arch, shape, mesh) case.

    options: perf-variant knobs — {"cache_shard_seq": bool,
    "replicate_below": int}. config_overrides: ModelConfig field overrides
    (e.g. flash_triangular=True, serve_sparse=True).
    """
    options = options or {}
    if cfg is None:
        cfg = get_config(arch, param_dtype="bfloat16", compute_dtype="bfloat16",
                         **(config_overrides or {}))
    model = build_model(cfg)
    shape = INPUT_SHAPES[shape_name]
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, mesh,
                         replicate_below=options.get("replicate_below", 0))
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)},
        "param_count": int(cfg.param_count()),
        "active_param_count": int(cfg.active_param_count()),
    }

    if shape.kind == "train":
        mb = microbatches or TRAIN_MICROBATCHES[arch]
        meta["microbatches"] = mb
        opt_cfg = AdamWConfig(moment_dtype="bfloat16")
        state_shape = jax.eval_shape(
            lambda p: TrainState(params=p, opt=init_adamw(p, opt_cfg)), params_shape)
        state_specs = TrainState(
            params=pspecs, opt=AdamWState(step=P(), mu=pspecs, nu=pspecs))
        batch = specs_lib.batch_specs(cfg, shape)
        batch_sh = {k: batch_spec(mesh, v.shape[0], len(v.shape)) for k, v in batch.items()}
        step = make_train_step(model, opt_cfg, microbatches=mb)
        metrics_shape = jax.eval_shape(step, state_shape, batch)[1]
        metrics_specs = jax.tree_util.tree_map(lambda _: P(), metrics_shape)
        jitted = jax.jit(
            step,
            in_shardings=(named(state_specs, mesh), named(batch_sh, mesh)),
            out_shardings=(named(state_specs, mesh), named(metrics_specs, mesh)),
            donate_argnums=(0,),      # train state updated in place
        )
        with mesh:
            lowered = jitted.lower(state_shape, batch)
        return lowered, meta

    if shape.kind == "prefill":
        batch = specs_lib.batch_specs(cfg, shape)
        batch_sh = {k: batch_spec(mesh, v.shape[0], len(v.shape)) for k, v in batch.items()}
        cache = specs_lib.cache_struct(cfg, shape, model)
        cspecs = cache_specs(cache, mesh, shape.global_batch,
                             shard_seq=options.get("cache_shard_seq", False),
                             no_model=options.get("cache_no_model", False))
        logits_spec = P(dp_axes(mesh) if shape.global_batch % mesh.shape["data"] == 0 else None,
                        None, "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)

        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_fn,
            in_shardings=(named(pspecs, mesh), named(batch_sh, mesh), named(cspecs, mesh)),
            out_shardings=(NamedSharding(mesh, logits_spec), named(cspecs, mesh)),
            donate_argnums=(2,),      # cache filled in place
        )
        with mesh:
            lowered = jitted.lower(params_shape, batch, cache)
        return lowered, meta

    # decode
    swa = specs_lib.uses_swa_for(cfg, shape)
    meta["swa"] = swa
    window = cfg.sliding_window if swa else 0
    toks = specs_lib.decode_token_specs(shape)
    cache = specs_lib.cache_struct(cfg, shape, model)
    cspecs = cache_specs(cache, mesh, shape.global_batch,
                         shard_seq=options.get("cache_shard_seq", False),
                         no_model=options.get("cache_no_model", False))
    tok_sh = batch_spec(mesh, shape.global_batch, 2)
    logits_spec = P(dp_axes(mesh) if shape.global_batch % mesh.shape["data"] == 0 else None,
                    None, "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)

    def serve_step(params, tokens, position, cache):
        return model.decode_step(params, tokens, position, cache, window=window)

    jitted = jax.jit(
        serve_step,
        in_shardings=(named(pspecs, mesh), NamedSharding(mesh, tok_sh),
                      NamedSharding(mesh, P()), named(cspecs, mesh)),
        out_shardings=(NamedSharding(mesh, logits_spec), named(cspecs, mesh)),
        donate_argnums=(3,),          # cache updated in place
    )
    with mesh:
        lowered = jitted.lower(params_shape, toks["tokens"], toks["position"], cache)
    return lowered, meta


def run_case(arch: str, shape_name: str, multi_pod: bool = False,
             microbatches: Optional[int] = None, save_dir: str = "experiments/dryrun",
             mesh=None, config_overrides: Optional[dict] = None,
             options: Optional[dict] = None, tag_suffix: str = "") -> Dict[str, Any]:
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    lowered, meta = build_lowered(arch, shape_name, mesh, microbatches,
                                  config_overrides=config_overrides, options=options)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = _memory_analysis_dict(compiled)
    cost = _cost_analysis_dict(compiled)
    coll = parse_collective_bytes(compiled.as_text())
    n_dev = int(mesh.devices.size)
    result = {
        **meta,
        "n_devices": n_dev,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": {k: v for k, v in cost.items()
                          if k in ("flops", "bytes accessed", "transcendentals",
                                   "optimal_seconds", "bytes accessed output")},
        "collective_bytes": coll,
    }
    print(f"[dryrun] {arch} x {shape_name} x {n_dev}dev: "
          f"flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e} "
          f"coll={coll.get('total', 0):.3e} "
          f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
          f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
    print("  memory_analysis:", mem)
    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}" + tag_suffix
        with open(os.path.join(save_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ASSIGNED_CONFIGS))
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) pairs")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--save-dir", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        archs = sorted(ASSIGNED_CONFIGS)
        shapes = list(INPUT_SHAPES)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        archs, shapes = [args.arch], [args.shape]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                run_case(arch, shape, multi_pod=args.multi_pod,
                         microbatches=args.microbatches, save_dir=args.save_dir,
                         mesh=mesh)
            except Exception as e:  # noqa: BLE001 — report every failing combo
                failures.append((arch, shape, repr(e)[:200]))
                print(f"[dryrun] FAIL {arch} x {shape}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all cases compiled OK")


if __name__ == "__main__":
    main()
