"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device state
(device count is locked at first jax init; launch/dryrun.py sets the 512-device
XLA flag before importing anything that calls into jax).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Single-host mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
