"""Offline NeuronPack builder — the paper's offline stage, end to end, into a
deployable artifact.

    trace FFN activations (streamed to disk shards)
      -> CoActivationStats per dense layer (shard-merged, bounded memory)
      -> greedy linked-placement search (Algorithm 1)
      -> serialize bundles in physical order (`repro.store.format.write_pack`)

The resulting file is everything the online stage needs to serve from flash:
`FileNeuronStore` opens it per layer, and `OffloadedFFNRuntime.from_pack`
wires it into the serving runtime. `launch/pack.py` is the CLI driver.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import List, Optional

import numpy as np

from repro.core.coactivation import stats_from_mask_shards
from repro.core.placement import (PlacementResult, identity_placement,
                                  search_placement)
from repro.core.trace import ShardedTraceWriter, iter_trace_shards
from repro.store.format import write_pack


def extract_dense_ffn_bundles(cfg, params) -> List[np.ndarray]:
    """Per dense-FFN layer, the [n_neurons, bundle_width] flash bundles in
    LOGICAL neuron order, enumerated in the same (group, sublayer) order as
    `ffn_pre_act` capture — the single source of truth shared by the packer
    and `build_offload_runtime`."""
    from repro.core.sparse_ffn import FFNWeights, make_bundles
    from repro.models import transformer

    P = transformer.stack_period(cfg)
    G = cfg.n_layers // P
    ffns = cfg.ffn_kinds()
    bundles = []
    for g in range(G):
        for j in range(P):
            if ffns[j] != "dense":
                continue
            ffn_p = params["stack"][f"sub_{j}"]["ffn"]
            w = FFNWeights(
                w_up=ffn_p["w_up"][g].T, w_down=ffn_p["w_down"][g],
                w_gate=(ffn_p["w_gate"][g].T if "w_gate" in ffn_p else None))
            bundles.append(np.asarray(make_bundles(w)))
    return bundles


def trace_to_shards(model, params, token_batches, writer: ShardedTraceWriter,
                    sparsity_topk: Optional[int] = None) -> int:
    """Run the model over token batches, appending each batch's per-layer
    activation masks straight to the shard writer (nothing accumulates in
    RAM). Returns the number of tokens traced."""
    import jax.numpy as jnp

    from repro.core.trace import relu_activation_mask, topk_activation_mask

    total = 0
    for tokens in token_batches:
        out = model.forward(params, {"tokens": jnp.asarray(tokens)},
                            capture_activations=True)
        pre = out["ffn_pre_act"]                   # [L, B, T, N]
        masks = np.asarray(relu_activation_mask(pre) if sparsity_topk is None
                           else topk_activation_mask(pre, sparsity_topk))
        for l in range(masks.shape[0]):
            writer.append(l, masks[l].reshape(-1, masks.shape[-1]))
        total += int(np.prod(np.asarray(tokens).shape))
    return total


@dataclasses.dataclass
class PackBuildReport:
    path: str
    n_layers: int
    n_neurons: int
    bundle_width: int
    quantized: bool
    file_bytes: int
    tokens_traced: int
    search_seconds: float              # summed over layers
    placement_mode: str
    build_seconds: float


def build_pack(
    model,
    params,
    out_path,
    *,
    calib_tokens: int = 512,
    calib_batch: int = 8,
    calib_seqlen: int = 64,
    seed: int = 0,
    use_placement: bool = True,
    placement_mode: str = "auto",
    quantize: str = "none",
    shard_dir=None,
    sparsity_topk: Optional[int] = None,
    meta: Optional[dict] = None,
    pack_version: Optional[int] = None,
) -> PackBuildReport:
    """The full offline stage for one model: calibration trace -> linked
    placement per dense layer -> NeuronPack on disk.

    The calibration stream is random tokens (the co-activation structure is
    model-intrinsic, paper Fig. 15); `shard_dir=None` stages trace shards in
    a temporary directory that is deleted after the stats pass.
    """
    cfg = model.cfg
    if cfg.family != "dense" or cfg.is_encdec:
        raise ValueError("NeuronPack packing covers dense decoder-only archs")
    t_start = time.perf_counter()
    bundles = extract_dense_ffn_bundles(cfg, params)
    rng = np.random.default_rng(seed)

    def batches():
        done = 0
        while done < calib_tokens:
            yield rng.integers(0, cfg.vocab_size,
                               (calib_batch, calib_seqlen)).astype(np.int32)
            done += calib_batch * calib_seqlen

    tmp = None
    if shard_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="npack-trace-")
        shard_dir = tmp.name
    try:
        writer = ShardedTraceWriter(shard_dir, n_layers=len(bundles),
                                    n_neurons=cfg.d_ff)
        traced = trace_to_shards(model, params, batches(), writer,
                                 sparsity_topk=sparsity_topk)
        writer.finish()
        placements: List[PlacementResult] = []
        for l in range(len(bundles)):
            if use_placement:
                stats = stats_from_mask_shards(iter_trace_shards(shard_dir, l),
                                               n_neurons=cfg.d_ff)
                placements.append(search_placement(stats.distance_matrix(),
                                                   mode=placement_mode))
            else:
                placements.append(identity_placement(cfg.d_ff))
    finally:
        if tmp is not None:
            tmp.cleanup()

    n_mats = 3 if cfg.activation == "silu" else 2
    pack_meta = dict(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_mats=n_mats,
        activation=cfg.activation, tokens_traced=traced,
        placement="linked" if use_placement else "identity",
    )
    pack_meta.update(meta or {})
    version_kw = {} if pack_version is None else {"version": pack_version}
    manifest = write_pack(out_path, bundles, placements,
                          quantize=quantize, meta=pack_meta, **version_kw)
    return PackBuildReport(
        path=manifest["path"], n_layers=len(bundles), n_neurons=cfg.d_ff,
        bundle_width=bundles[0].shape[1], quantized=manifest["quantized"],
        file_bytes=manifest["file_bytes"], tokens_traced=traced,
        search_seconds=sum(p.search_seconds for p in placements),
        placement_mode="linked" if use_placement else "identity",
        build_seconds=time.perf_counter() - t_start)
