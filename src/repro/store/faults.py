"""Deterministic fault injection for the flash-serving path.

The paper's premise is that decode latency is dominated by flash I/O on
IOPS-constrained smartphones — exactly the environment where reads are
flaky: UFS latency spikes under thermal throttling, transient EIO under
controller contention, torn sectors on worn flash. This module gives the
repo a reproducible way to make the storage layer misbehave so the
fault-tolerance machinery (retry + CRC verification in `FileNeuronStore`,
prefetch-worker supervision in `serving.engine`, per-request error
isolation in `serving.server`) can be tested and benchmarked under a
*seed-driven, exactly replayable* schedule.

Fault model — five kinds, keyed by (read_index, attempt):

  transient   the read attempt raises `TransientIOError` (errno EIO); the
              store's bounded-backoff retry loop is expected to absorb it.
  latency     the attempt completes but only after `delay_s` of extra wall
              time (a thermal-throttle spike; correctness-neutral).
  short_read  the first `pread` of the attempt returns a truncated chunk,
              forcing the store's short-read continuation loop to issue
              follow-up reads (exercises an otherwise OS-dependent branch).
  corrupt     the attempt's payload comes back with deterministically
              flipped bits. Invisible without checksums; with a v2 pack and
              `verify_checksums=True` the per-bundle CRC32 catches it and
              triggers a re-read.
  fatal       the attempt raises `FatalFault` — deliberately a
              `BaseException`, so the prefetch worker's per-job `Exception`
              handler cannot absorb it and the worker THREAD dies. This is
              the chaos suite's worker-death vector; the runtime's
              supervision (restart budget + synchronous fallback) is what
              keeps decode alive.

`read_index` counts logical extent reads per store (one per collapsed
extent, advancing once per read, NOT per retry attempt), so a schedule
addresses "the 7th extent read this store performs" regardless of timing,
threads, or how many retries earlier faults caused. `attempt` is the
retry ordinal within one logical read (0 = first try); an event with
`times=t` affects attempts 0..t-1, so `times=2` means "fail twice, then
succeed" — recoverable by any retry budget >= 2.

Two injection sites share the schedule vocabulary:

  * `FileNeuronStore(..., fault_plan=plan)` injects *below* the retry /
    verification layer — the recoverable path. A transient costs a retry,
    a corrupt extent costs a detection + re-read, and decode output is
    bit-identical to the clean run.
  * `FaultInjectingStore(inner, plan)` wraps ANY store (including the
    in-memory `NeuronStore`) at the `_serve_extents` boundary with NO
    retry layer in between — the unrecoverable path, used to prove that a
    failing request is isolated (`finish_reason="error"`) while the rest
    of the batch keeps decoding.

Every applied event is counted in `FaultPlan.injected`, which is the
ground truth the acceptance tests compare `IOStats.retries` /
`corrupt_extents` against.
"""
from __future__ import annotations

import dataclasses
import errno
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collapse import Extent
from repro.core.storage import IOStats, NeuronStore


class TransientIOError(OSError):
    """Injected retryable read failure (modeled as errno EIO)."""

    def __init__(self, message: str) -> None:
        super().__init__(errno.EIO, message)


class CorruptExtentError(IOError):
    """A CRC-verified extent read stayed corrupt through every re-read."""


class FatalFault(BaseException):
    """Injected *thread-killing* fault.

    Deliberately NOT an `Exception`: per-job exception handlers (the
    prefetch worker's) let it through, so raising it on the worker thread
    kills the thread — the realistic 'worker died mid-decode' failure the
    supervision machinery must survive.
    """


#: OSError errnos the retry loop treats as transient. Anything else
#: (ENOENT, EBADF, a genuine EOF short read...) propagates immediately —
#: retrying cannot fix a missing file.
RETRYABLE_ERRNOS = frozenset(
    {errno.EIO, errno.EAGAIN, errno.EINTR, errno.ETIMEDOUT})


def is_retryable(exc: BaseException) -> bool:
    """True for OSErrors a bounded retry can plausibly absorb."""
    return isinstance(exc, OSError) and exc.errno in RETRYABLE_ERRNOS


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient extent-read failures.

    `max_retries` counts RE-reads after the first attempt (so a read is
    tried at most `max_retries + 1` times). Backoff for the i-th retry is
    `backoff_s * backoff_mult**i`, capped at `max_backoff_s`; tests set
    `backoff_s=0` to retry instantly.
    """
    max_retries: int = 3
    backoff_s: float = 1e-3
    backoff_mult: float = 2.0
    max_backoff_s: float = 0.05

    def backoff(self, retry_index: int) -> float:
        if self.backoff_s <= 0:
            return 0.0
        return min(self.backoff_s * self.backoff_mult ** retry_index,
                   self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: `kind` applied to the first `times` attempts of
    logical read `read_index` (latency events carry `delay_s`)."""
    read_index: int
    kind: str                    # transient|latency|short_read|corrupt|fatal
    times: int = 1
    delay_s: float = 0.0

    KINDS = ("transient", "latency", "short_read", "corrupt", "fatal")

    def __post_init__(self) -> None:
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {self.KINDS})")


class FaultPlan:
    """A reproducible fault schedule plus ground-truth injection counters.

    Thread-safe: the prefetch worker and the serving thread may both drive
    reads against the same plan. `injected[kind]` counts events actually
    APPLIED (a planned event whose read index is never reached counts
    zero), which is what makes `retries == injected['transient'] +
    injected['corrupt']` an exact acceptance criterion rather than an
    upper bound.
    """

    def __init__(self, events: Sequence[FaultEvent] = (), seed: int = 0) -> None:
        self._events: Dict[int, List[FaultEvent]] = {}
        for ev in events:
            self._events.setdefault(ev.read_index, []).append(ev)
        self.seed = seed
        self.injected: Dict[str, int] = {k: 0 for k in FaultEvent.KINDS}
        self._lock = threading.Lock()

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_reads: int,
        *,
        transient_rate: float = 0.0,
        transient_times: int = 1,
        latency_rate: float = 0.0,
        delay_s: float = 2e-3,
        short_read_rate: float = 0.0,
        corrupt_rate: float = 0.0,
        fatal_reads: Sequence[int] = (),
    ) -> "FaultPlan":
        """Draw a schedule over the first `n_reads` logical reads: each read
        independently gets each fault kind at its rate (one uniform draw per
        (read, kind), fixed by `seed` — the same arguments always produce
        the same schedule). `fatal_reads` pins thread-killing faults at
        explicit read indices."""
        rng = np.random.default_rng(seed)
        draws = rng.random((max(int(n_reads), 0), 4))
        events: List[FaultEvent] = []
        for i in range(draws.shape[0]):
            if draws[i, 0] < transient_rate:
                events.append(FaultEvent(i, "transient", times=transient_times))
            if draws[i, 1] < latency_rate:
                events.append(FaultEvent(i, "latency", delay_s=delay_s))
            if draws[i, 2] < short_read_rate:
                events.append(FaultEvent(i, "short_read"))
            if draws[i, 3] < corrupt_rate:
                events.append(FaultEvent(i, "corrupt"))
        for i in fatal_reads:
            events.append(FaultEvent(int(i), "fatal"))
        return cls(events, seed=seed)

    def events_at(self, read_index: int) -> List[FaultEvent]:
        return self._events.get(read_index, [])

    @property
    def n_events(self) -> int:
        return sum(len(v) for v in self._events.values())

    def active(self, read_index: int, attempt: int) -> List[FaultEvent]:
        """Events applying to attempt `attempt` of read `read_index`,
        recorded into `injected` (call once per attempt — the caller then
        MUST apply every returned event)."""
        out = [ev for ev in self.events_at(read_index) if attempt < ev.times]
        if out:
            with self._lock:
                for ev in out:
                    self.injected[ev.kind] += 1
        return out

    def corrupt_payload(self, buf: bytearray, read_index: int) -> None:
        """Flip one bit at each of three deterministic positions of `buf`
        (keyed on (seed, read_index) so re-reads of a *transiently* corrupt
        extent see clean bytes, while tests can replay the exact damage)."""
        if not len(buf):
            return
        rng = np.random.default_rng((self.seed, read_index))
        for pos in rng.integers(0, len(buf), size=3):
            buf[int(pos)] ^= 1 << int(rng.integers(0, 8))


def seeded_layer_plans(seed: int, n_layers: int, n_reads: int,
                       **rates) -> List[FaultPlan]:
    """One independent seeded plan per layer store (layer l draws from
    `seed + l`), the shape `OffloadedFFNRuntime.from_pack(fault_plans=...)`
    expects."""
    return [FaultPlan.seeded(seed + l, n_reads, **rates)
            for l in range(n_layers)]


class FaultInjectingStore(NeuronStore):
    """Wrap ANY `NeuronStore` with a fault schedule at the extent-read
    boundary — with NO retry/verification layer in between, so every
    injected fault surfaces to the caller exactly as a failing device
    would. This is the unrecoverable-path harness: transients here
    propagate out of `read()` (isolation tests), fatals kill whichever
    thread issued the read (supervision tests).

    DRAM-side surfaces (`fetch` / `fetch_into` / scales) delegate
    untouched; only `_serve_extents` — the flash-read path — is faulted.
    Corruption applies to the returned payload when one is requested
    (`fetch_payload=True`); payload-free accounting reads have no bytes to
    damage, so corrupt events are only counted when they actually bite.
    """

    def __init__(self, inner: NeuronStore, plan: FaultPlan) -> None:
        # no super().__init__: every NeuronStore attribute mirrors `inner`
        # so engines built over the wrapper plan reads identically.
        self.inner = inner
        self.plan = plan
        self.n_neurons = inner.n_neurons
        self.bundle_width = inner.bundle_width
        self.placement = inner.placement
        self.device = inner.device
        self.reads_per_bundle = inner.reads_per_bundle
        self.bundle_bytes = inner.bundle_bytes
        self.quantized = inner.quantized
        self._read_index = 0
        self._index_lock = threading.Lock()

    # -- delegated payload surface ------------------------------------------
    @property
    def payload_dtype(self) -> np.dtype:
        return self.inner.payload_dtype

    @property
    def stored_dtype(self) -> np.dtype:
        return self.inner.stored_dtype

    def physical_payload(self, dequantize: bool = True) -> np.ndarray:
        return self.inner.physical_payload(dequantize)

    def physical_scales(self) -> Optional[np.ndarray]:
        return self.inner.physical_scales()

    def fetch(self, logical_ids: np.ndarray) -> np.ndarray:
        return self.inner.fetch(logical_ids)

    def fetch_into(self, logical_ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        return self.inner.fetch_into(logical_ids, out)

    def fetch_scales_into(self, logical_ids: np.ndarray,
                          out: np.ndarray) -> np.ndarray:
        return self.inner.fetch_scales_into(logical_ids, out)

    def close(self) -> None:
        self.inner.close()

    # -- faulted flash reads -------------------------------------------------
    def _next_index(self) -> int:
        with self._index_lock:
            i = self._read_index
            self._read_index += 1
            return i

    def _serve_extents(self, extents: List[Extent], phys: np.ndarray,
                       fetch_payload: bool,
                       stats: IOStats) -> Optional[np.ndarray]:
        corrupt_reads: List[int] = []
        for _ in extents:
            idx = self._next_index()
            for ev in self.plan.events_at(idx):
                if ev.kind == "corrupt":
                    if fetch_payload:      # counted below, where it bites
                        corrupt_reads.append(idx)
                    continue
                self.plan.active(idx, 0)   # count exactly what we apply
                if ev.kind == "latency":
                    time.sleep(ev.delay_s)
                elif ev.kind in ("transient", "short_read"):
                    raise TransientIOError(
                        f"injected {ev.kind} fault at read {idx} "
                        f"(no retry layer below this store)")
                elif ev.kind == "fatal":
                    raise FatalFault(f"injected fatal fault at read {idx}")
        data = self.inner._serve_extents(extents, phys, fetch_payload, stats)
        if data is not None and corrupt_reads:
            with self.plan._lock:
                for _ in corrupt_reads:
                    self.plan.injected["corrupt"] += 1
            raw = bytearray(np.ascontiguousarray(data).tobytes())
            for idx in corrupt_reads:
                self.plan.corrupt_payload(raw, idx)
            data = np.frombuffer(bytes(raw), dtype=data.dtype).reshape(
                data.shape)
        return data
