"""FileNeuronStore — the NeuronStore contract served from a NeuronPack file
with REAL positional reads.

Drop-in for `repro.core.storage.NeuronStore` everywhere the engine and the
serving runtime touch a store (`read` / `fetch` / `fetch_into` /
`plan_extents` / `physical_payload`), with two differences:

  * every collapsed extent the read planner produces becomes ONE real
    positional file read (`os.pread` on a raw fd; mmap slice fallback where
    pread is unavailable) against the pack's physical-order bundle region —
    the extent plan is no longer only an accounting fiction;
  * dual accounting: the calibrated `UFSDevice` model fields of `IOStats`
    are computed by exactly the same code path as the in-memory store (so
    every stats-identity test keeps meaning), while the new `measured_ops` /
    `measured_bytes` / `measured_seconds` fields record what the filesystem
    actually did.

DRAM-side access (`fetch` / `fetch_into` — cache hits and bytes the engine
just read) is served from a lazy mmap of the bundle region: the page cache
plays the role of DRAM residency, and the preceding extent `pread`s warm it,
which is the honest analogue of "the engine computes with the very bytes it
read". int8 packs serve rows dtype-faithfully: every payload surface routes
through one `payload_dtype`-aware accessor (`_as_payload` / `_gather_into`)
that passes raw int8 through untouched when the consumer asks for the stored
dtype (the fused segment kernel and dtype-faithful staging ring) and only
dequantizes (scales indexed in physical order) when the consumer actually
needs float32.
"""
from __future__ import annotations

import itertools
import os
import time
import zlib
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.core.collapse import Extent
from repro.core.storage import IOStats, NeuronStore, UFSDevice
from repro.obs import get_tracer
from repro.store.faults import (CorruptExtentError, FatalFault, FaultPlan,
                                RetryPolicy, TransientIOError, is_retryable)
from repro.store.format import NeuronPack, dequantize_int8

_HAS_PREAD = hasattr(os, "pread")


class _ChecksumMismatch(Exception):
    """Internal: an extent's payload failed per-bundle CRC verification.
    Converted to a retry (transient corruption: a re-read serves clean
    bytes) or, once the budget is exhausted, to `CorruptExtentError`."""


class FileNeuronStore(NeuronStore):
    """One layer of a NeuronPack served as a placement-aware neuron store."""

    def __init__(
        self,
        pack: Union[str, os.PathLike, NeuronPack],
        layer: int = 0,
        device: Optional[UFSDevice] = None,
        reads_per_bundle: int = 1,
        bundle_bytes: Optional[int] = None,
        use_pread: bool = True,
        *,
        retry: Optional[RetryPolicy] = None,
        verify_checksums: bool = False,
        fault_plan: Optional[FaultPlan] = None,
    ) -> None:
        """`retry` bounds how many times a transient extent-read failure
        (retryable OSError, or a CRC mismatch under `verify_checksums`) is
        re-read with exponential backoff before propagating.
        `verify_checksums=True` checks every extent's bundles against the
        pack's per-bundle CRC32 table after each read (v2 packs only) —
        a detected corrupt read costs one `IOStats.corrupt_extents` and a
        re-read, never silent corruption. `fault_plan` injects a
        deterministic fault schedule BELOW the retry layer (see
        `repro.store.faults`): the recoverable-chaos test hook."""
        # no super().__init__: the payload is the FILE, not a passed array.
        # Modeled accounting defaults to the pack's stored row bytes, so an
        # int8 pack is billed int8 bytes by the device model too.
        pack = NeuronPack.open(pack)
        if not 0 <= layer < pack.n_layers:
            raise ValueError(f"layer {layer} out of range for "
                             f"{pack.n_layers}-layer pack {pack.path}")
        self.pack = pack
        self.layer_index = layer
        self.n_neurons = pack.n_neurons
        self.bundle_width = pack.bundle_width
        self.placement = pack.placement(layer)
        self.device = device or UFSDevice()
        self.reads_per_bundle = reads_per_bundle
        self.quantized = pack.quantized
        self.bundle_bytes = (int(bundle_bytes) if bundle_bytes
                             else pack.row_bytes)
        self._row_bytes = pack.row_bytes          # real on-disk stride
        self._stored_dtype = pack.dtype
        self._bundles_at = pack.bundles_file_offset(layer)
        self._scales = pack.scales(layer)         # physical order, or None
        self._phys_data = pack.bundles_memmap(layer)   # raw-dtype page view
        self._fd = (os.open(pack.path, os.O_RDONLY)
                    if use_pread and _HAS_PREAD else None)
        self.retry = retry or RetryPolicy()
        self.verify_checksums = verify_checksums
        self.fault_plan = fault_plan
        self._read_counter = itertools.count()   # logical extent reads served
        self._row_crcs = None
        if verify_checksums:
            crcs = pack.row_crcs(layer)
            if crcs is None:
                raise ValueError(
                    f"{pack.path}: verify_checksums=True needs a v2 pack "
                    f"with per-bundle CRC tables (this pack is version "
                    f"{pack.version}); rebuild it with "
                    f"write_pack(..., version=2)")
            self._row_crcs = crcs

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return getattr(self, "_phys_data", None) is None

    def close(self) -> None:
        """Release the fd and the bundle-region memmap reference. Safe to
        call more than once; payload arrays already handed out keep their
        own reference to the mapping and stay valid."""
        if getattr(self, "_fd", None) is not None:
            os.close(self._fd)
            self._fd = None
        self._phys_data = None

    def __del__(self) -> None:  # fd hygiene; mmap closes with the array
        try:
            self.close()
        except Exception:       # noqa: BLE001 — interpreter teardown
            pass

    def __enter__(self) -> "FileNeuronStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- payload surface -----------------------------------------------------
    @property
    def payload_dtype(self) -> np.dtype:
        # the dtype `fetch` serves when the caller doesn't say otherwise;
        # kept float32 for quantized packs so legacy consumers that allocate
        # from payload_dtype keep receiving dequantized rows.
        return np.dtype(np.float32) if self.quantized else self._stored_dtype

    @property
    def stored_dtype(self) -> np.dtype:
        return self._stored_dtype

    def _as_payload(self, raw: np.ndarray, phys: Optional[np.ndarray],
                    dtype: np.dtype) -> np.ndarray:
        """Serve raw stored rows (gathered at physical positions `phys`;
        None = full physical order) at the consumer's dtype. Raw dtype passes
        through untouched; float32 out of an int8 pack dequantizes — the ONLY
        place this store turns quantized rows into floats."""
        dtype = np.dtype(dtype)
        if dtype == self._stored_dtype:
            return np.asarray(raw)
        if self.quantized and dtype == np.float32:
            scales = self._scales if phys is None else self._scales[phys]
            return dequantize_int8(np.asarray(raw), scales)
        raise ValueError(f"cannot serve {self._stored_dtype} payload as {dtype}")

    def _gather_into(self, phys: np.ndarray, out: np.ndarray) -> None:
        """`_as_payload` twin that fills a caller buffer (no allocation),
        dispatching on out.dtype: stored-dtype buffers take the raw rows
        (int8 stays int8 end-to-end), float32 buffers get the fused
        gather-dequant."""
        if out.dtype == self._stored_dtype:
            np.take(self._phys_data, phys, axis=0, out=out)
        elif self.quantized and out.dtype == np.float32:
            np.multiply(self._phys_data[phys].astype(np.float32),
                        self._scales[phys][:, None], out=out)
        else:
            raise ValueError(f"cannot serve {self._stored_dtype} payload "
                             f"into a {out.dtype} buffer")

    def physical_payload(self, dequantize: bool = True) -> np.ndarray:
        dtype = (np.float32 if self.quantized and dequantize
                 else self._stored_dtype)
        return self._as_payload(self._phys_data, None, dtype)

    def physical_scales(self) -> Optional[np.ndarray]:
        return self._scales

    def fetch(self, logical_ids: np.ndarray) -> np.ndarray:
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        if logical_ids.size == 0:
            return np.zeros((0, self.bundle_width), dtype=self.payload_dtype)
        phys = self.placement.physical_of(logical_ids)
        return self._as_payload(self._phys_data[phys], phys, self.payload_dtype)

    def fetch_into(self, logical_ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        k = logical_ids.size
        if k:
            phys = self.placement.physical_of(logical_ids)
            self._gather_into(phys, out[:k])
        return out

    def fetch_scales_into(self, logical_ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        logical_ids = np.asarray(logical_ids, dtype=np.int64)
        k = logical_ids.size
        if k:
            if self._scales is None:
                raise RuntimeError("store is not quantized: no scales to fetch")
            out[:k] = self._scales[self.placement.physical_of(logical_ids)]
        return out

    # -- real extent reads ---------------------------------------------------
    def _read_extent_attempt(self, start: int, length: int,
                             read_index: int, attempt: int) -> bytes:
        """One attempt at one positional read of `length` contiguous
        bundles, as raw bytes. The fault plan (when armed) injects its
        scheduled misbehaviour HERE — below the retry loop, at the point a
        real device would fail."""
        if self.closed:
            raise ValueError(f"store for layer {self.layer_index} of "
                             f"{self.pack.path} is closed")
        events = (self.fault_plan.active(read_index, attempt)
                  if self.fault_plan is not None else ())
        inject_short = inject_corrupt = False
        for ev in events:
            if ev.kind == "latency":
                time.sleep(ev.delay_s)
            elif ev.kind == "transient":
                raise TransientIOError(
                    f"injected transient read error (read {read_index}, "
                    f"attempt {attempt}) at extent {start}+{length} of "
                    f"{self.pack.path}")
            elif ev.kind == "fatal":
                raise FatalFault(f"injected fatal fault at read "
                                 f"{read_index} of {self.pack.path}")
            elif ev.kind == "short_read":
                inject_short = True
            elif ev.kind == "corrupt":
                inject_corrupt = True
        if self._fd is not None:
            want = length * self._row_bytes
            off = self._bundles_at + start * self._row_bytes
            chunks = []
            first = True
            while want:
                chunk = os.pread(self._fd, want, off)
                if first and inject_short and len(chunk) > 1:
                    # truncate the first chunk so the continuation loop has
                    # to issue follow-up preads for the remainder
                    chunk = chunk[:(len(chunk) + 1) // 2]
                first = False
                if not chunk:
                    raise IOError(f"short read at offset {off} of "
                                  f"{self.pack.path} (extent {start}"
                                  f"+{length})")
                chunks.append(chunk)
                off += len(chunk)
                want -= len(chunk)
            buf = chunks[0] if len(chunks) == 1 else b"".join(chunks)
        else:
            # mmap fallback: still a positional slice copy of the same bytes
            buf = self._phys_data[start:start + length].tobytes()
        if inject_corrupt:
            damaged = bytearray(buf)
            self.fault_plan.corrupt_payload(damaged, read_index)
            buf = bytes(damaged)
        return buf

    def _verify_extent(self, buf: bytes, start: int, length: int,
                       read_index: int) -> None:
        """Check every bundle of the extent against the pack's per-row
        CRC32 table (physical row p at table index p)."""
        rb = self._row_bytes
        crcs = self._row_crcs
        view = memoryview(buf)
        for i in range(length):
            if zlib.crc32(view[i * rb:(i + 1) * rb]) != int(crcs[start + i]):
                raise _ChecksumMismatch(
                    f"CRC mismatch at physical bundle {start + i} (extent "
                    f"{start}+{length}, read {read_index}) of "
                    f"{self.pack.path}")

    def _read_extent(self, start: int, length: int,
                     stats: Optional[IOStats] = None) -> np.ndarray:
        """One logical positional read of `length` physically-contiguous
        bundles: bounded-backoff retry for transient failures, optional
        per-bundle CRC verification with re-read on detected corruption.
        Retries and detections are recorded on `stats`; the logical read
        index advances once per call, never per attempt, so fault schedules
        address reads regardless of how many retries earlier faults cost.
        """
        read_index = next(self._read_counter)
        policy = self.retry
        attempt = 0
        tracer = get_tracer()
        with tracer.span("pread", start=int(start),
                         length=int(length)) as sp:
            while True:
                try:
                    buf = self._read_extent_attempt(start, length, read_index,
                                                    attempt)
                    if self._row_crcs is not None:
                        self._verify_extent(buf, start, length, read_index)
                    sp.set(attempts=attempt + 1)
                    return np.frombuffer(
                        buf, dtype=self._stored_dtype).reshape(
                            length, self.bundle_width)
                except (_ChecksumMismatch, OSError) as e:
                    corrupt = isinstance(e, _ChecksumMismatch)
                    if corrupt and stats is not None:
                        stats.corrupt_extents += 1
                    if corrupt:
                        tracer.instant("corrupt_extent", start=int(start),
                                       attempt=attempt)
                    if not corrupt and not is_retryable(e):
                        raise
                    if attempt >= policy.max_retries:
                        if corrupt:
                            raise CorruptExtentError(
                                f"{e} — still corrupt after "
                                f"{policy.max_retries} re-reads")
                        raise
                    if stats is not None:
                        stats.retries += 1
                    tracer.instant("read_retry", start=int(start),
                                   attempt=attempt)
                    delay = policy.backoff(attempt)
                    if delay > 0:
                        time.sleep(delay)
                    attempt += 1

    def _serve_extents(self, extents: List[Extent], phys: np.ndarray,
                       fetch_payload: bool,
                       stats: IOStats) -> Optional[np.ndarray]:
        """One REAL file read per collapsed extent (measured accounting),
        then gather the requested rows out of the extent blocks.

        The reads happen regardless of `fetch_payload`: the engine's
        probe/read path discards the payload (it re-gathers the full
        activated union into a staging buffer via `fetch_into`) but the flash
        traffic — and the page-cache warmth `fetch_into` then enjoys — is
        exactly these extent reads.
        """
        t0 = time.perf_counter()
        blocks = [self._read_extent(start, length, stats)
                  for start, length in extents]
        stats.measured_seconds = time.perf_counter() - t0
        stats.measured_ops = len(extents)
        stats.measured_bytes = sum(b.nbytes for b in blocks)
        if not fetch_payload:
            return None
        # locate each requested physical position inside its extent block
        ext_starts = np.array([s for s, _ in extents], dtype=np.int64)
        ext_lens = np.array([l for _, l in extents], dtype=np.int64)
        base = np.concatenate([[0], np.cumsum(ext_lens)[:-1]])
        which = np.searchsorted(ext_starts, phys, side="right") - 1
        rows = base[which] + (phys - ext_starts[which])
        flat = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
        return self._as_payload(flat[rows], phys, self.payload_dtype)


def open_layer_stores(
    pack: Union[str, os.PathLike, NeuronPack],
    device: Optional[UFSDevice] = None,
    reads_per_bundle: int = 1,
    *,
    retry: Optional[RetryPolicy] = None,
    verify_checksums: bool = False,
) -> Tuple[NeuronPack, List[FileNeuronStore]]:
    """All layers of a pack as FileNeuronStores sharing one parsed header."""
    pack = NeuronPack.open(pack)
    stores = [FileNeuronStore(pack, l, device=device,
                              reads_per_bundle=reads_per_bundle,
                              retry=retry, verify_checksums=verify_checksums)
              for l in range(pack.n_layers)]
    return pack, stores
