"""NeuronPack — the on-disk artifact the offline stage produces.

The paper's thesis is that WHERE neurons live in flash determines I/O
efficiency. Until this format existed, the repo's "flash" was a numpy array
and the physical layout an in-memory permutation: nothing was ever placed on
a storage medium. A NeuronPack serializes exactly that placement decision —
per-layer neuron bundles written to disk *in physical placement order*, so a
byte offset in the file IS a physical flash position and a collapsed extent
plan maps 1:1 to positional file reads (`repro.store.FileNeuronStore`).

Layout (little-endian, all regions 64-byte aligned)::

    [0:8)        magic  b"NPACK001"
    [8:16)       uint64 header-JSON byte length H
    [16:16+H)    header JSON (utf-8)
    [16+H:16+H+4) uint32 CRC32 of the header JSON          (version >= 2)
    --- data_start = align64(16 + H [+ 4]) ---
    per layer, in order:
      placement table  int64[n]       physical slot -> logical neuron id
      scales           float32[n]     per-neuron dequant scale (int8 packs)
      bundles          dtype[n, w]    payloads in PHYSICAL placement order
      bundle_crcs      uint32[n]      per-bundle CRC32       (version >= 2)

Format v2 (the default) adds integrity metadata: a CRC32 of the header
JSON (a torn header write is detected at open, not as a garbled offset
table), a per-layer whole-bundle-region CRC32 recorded in the header, and
a per-bundle CRC32 table — one checksum per physical row, what
`FileNeuronStore(verify_checksums=True)` checks after every extent read
so a corrupt flash read is detected and re-read instead of silently
corrupting decode. v1 packs (no checksums) remain fully readable; v2
packs are readable by this module only (the version gate below).
Malformed files of any kind raise `PackFormatError` naming the path and
what was expected vs found.

The header records per-layer offsets RELATIVE to data_start (so the header's
own length never feeds back into the offsets), the bundle geometry
(n_neurons, bundle_width, dtype), whether bundles are int8-quantized, the
placement search provenance (mode / edges / seconds), and a free-form `meta`
dict the packer fills with model geometry (d_model, n_mats, activation) that
load-time validation checks against the serving config.

Quantization is per-neuron symmetric int8: scale = max|row| / 127 (1.0 for
all-zero rows), row ≈ q * scale. Dequantization is deterministic, so two
readers of the same pack always serve bit-identical float32 payloads.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.placement import PlacementResult

MAGIC = b"NPACK001"
VERSION = 2                    # written by default
READABLE_VERSIONS = (1, 2)     # v1 packs (no checksums) stay readable
_ALIGN = 64


class PackFormatError(ValueError):
    """The file is not a readable NeuronPack (truncated, wrong magic,
    unsupported version, garbled or checksum-failing header). The message
    always names the path and what was expected vs actually found."""

_DTYPES = {"float32": np.float32, "float16": np.float16, "int8": np.int8}


def _align(n: int) -> int:
    return -(-n // _ALIGN) * _ALIGN


def quantize_int8(rows: np.ndarray) -> tuple:
    """Per-neuron symmetric int8: returns (q [n, w] int8, scales [n] float32).

    scale = max|row| / 127 (rows of zeros get scale 1.0 so dequantization is
    exact for them too); values round to nearest and clip to [-127, 127].
    """
    rows = np.asarray(rows, dtype=np.float32)
    peak = np.abs(rows).max(axis=1)
    scales = np.where(peak > 0, peak / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(rows / scales[:, None]), -127, 127).astype(np.int8)
    return q, scales


def dequantize_int8(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of `quantize_int8` row-wise: float32 q * scale."""
    return q.astype(np.float32) * np.asarray(scales, np.float32)[:, None]


def _row_crc32s(rows: np.ndarray) -> np.ndarray:
    """CRC32 of every row of a C-contiguous [n, w] array, as uint32[n]."""
    rows = np.ascontiguousarray(rows)
    rb = rows.shape[1] * rows.dtype.itemsize
    view = memoryview(rows).cast("B")
    return np.array([zlib.crc32(view[i * rb:(i + 1) * rb])
                     for i in range(rows.shape[0])], dtype="<u4")


@dataclasses.dataclass(frozen=True)
class PackLayer:
    """One layer's region table (offsets relative to the pack's data_start)."""
    index: int
    placement_offset: int
    scales_offset: Optional[int]       # None unless quantized
    bundles_offset: int
    bundles_nbytes: int
    placement_mode: str
    edges_used: int
    search_seconds: float
    crcs_offset: Optional[int] = None  # per-bundle CRC table (v2 packs)
    bundles_crc32: Optional[int] = None  # whole-region CRC32 (v2 packs)


class NeuronPack:
    """Read-side handle on a NeuronPack file: header + per-layer accessors.

    Bundle payloads are exposed two ways — `bundles_memmap(l)` (the lazy
    page-cache view `FileNeuronStore` fancy-indexes for DRAM-side fetches;
    packs larger than RAM stay larger than RAM) and the absolute byte offsets
    (`bundles_file_offset(l)`) the store's `pread` extent path uses.
    """

    def __init__(self, path: Union[str, os.PathLike]) -> None:
        self.path = os.fspath(path)
        try:
            size = os.path.getsize(self.path)
        except OSError as e:
            raise PackFormatError(f"{self.path}: cannot stat pack file ({e})")
        with open(self.path, "rb") as f:
            preamble = f.read(16)
            if len(preamble) < 16:
                raise PackFormatError(
                    f"{self.path}: file is {size} bytes — too short for a "
                    f"NeuronPack (need at least the 16-byte magic + "
                    f"header-length preamble)")
            magic = preamble[:8]
            if magic != MAGIC:
                raise PackFormatError(
                    f"{self.path}: not a NeuronPack (magic {magic!r}, "
                    f"expected {MAGIC!r})")
            (hlen,) = np.frombuffer(preamble[8:16], dtype="<u8")
            hlen = int(hlen)
            if 16 + hlen > size:
                raise PackFormatError(
                    f"{self.path}: header claims {hlen} bytes but only "
                    f"{size - 16} follow the preamble — truncated pack")
            blob = f.read(hlen)
            try:
                header = json.loads(blob.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise PackFormatError(
                    f"{self.path}: header JSON is unreadable ({e}) — "
                    f"corrupt file or not a NeuronPack")
            if not isinstance(header, dict):
                raise PackFormatError(
                    f"{self.path}: header is {type(header).__name__}, "
                    f"expected a JSON object")
            version = header.get("version")
            if version not in READABLE_VERSIONS:
                raise PackFormatError(
                    f"{self.path}: unsupported NeuronPack version {version!r}"
                    f" (reader supports {READABLE_VERSIONS})")
            crc_bytes = 0
            if version >= 2:
                crc_raw = f.read(4)
                if len(crc_raw) < 4:
                    raise PackFormatError(
                        f"{self.path}: truncated before the v2 header "
                        f"checksum (expected 4 CRC bytes after the "
                        f"{hlen}-byte header)")
                (stored,) = np.frombuffer(crc_raw, dtype="<u4")
                actual = zlib.crc32(blob)
                if int(stored) != actual:
                    raise PackFormatError(
                        f"{self.path}: header CRC mismatch (stored "
                        f"0x{int(stored):08x}, computed 0x{actual:08x}) — "
                        f"corrupt header")
                crc_bytes = 4
        self.header = header
        self.version = int(version)
        self.data_start = _align(16 + hlen + crc_bytes)
        self.n_layers: int = header["n_layers"]
        self.n_neurons: int = header["n_neurons"]
        self.bundle_width: int = header["bundle_width"]
        self.quantized: bool = header["quantized"]
        self.dtype = np.dtype(_DTYPES[header["dtype"]])
        self.meta: dict = header.get("meta", {})
        self._layers = [
            PackLayer(index=i,
                      placement_offset=lay["placement"],
                      scales_offset=lay.get("scales"),
                      bundles_offset=lay["bundles"],
                      bundles_nbytes=lay["bundles_nbytes"],
                      placement_mode=lay.get("placement_mode", "pack"),
                      edges_used=lay.get("edges_used", 0),
                      search_seconds=lay.get("search_seconds", 0.0),
                      crcs_offset=lay.get("bundle_crcs"),
                      bundles_crc32=lay.get("bundles_crc32"))
            for i, lay in enumerate(header["layers"])
        ]
        last = self._layers[-1] if self._layers else None
        if last is not None and (self.data_start + last.bundles_offset
                                 + last.bundles_nbytes) > size:
            raise PackFormatError(
                f"{self.path}: file is {size} bytes but the header's region "
                f"table needs at least "
                f"{self.data_start + last.bundles_offset + last.bundles_nbytes}"
                f" — truncated pack data")

    @classmethod
    def open(cls, path: Union[str, os.PathLike, "NeuronPack"]) -> "NeuronPack":
        return path if isinstance(path, NeuronPack) else cls(path)

    @property
    def row_bytes(self) -> int:
        """Stored bytes of one neuron bundle (the flash 'sector' unit)."""
        return self.bundle_width * self.dtype.itemsize

    def layer(self, l: int) -> PackLayer:
        return self._layers[l]

    def placement(self, l: int) -> PlacementResult:
        lay = self._layers[l]
        placement = np.fromfile(self.path, dtype="<i8", count=self.n_neurons,
                                offset=self.data_start + lay.placement_offset)
        inverse = np.empty_like(placement)
        inverse[placement] = np.arange(self.n_neurons)
        return PlacementResult(placement=placement, inverse=inverse,
                               edges_used=lay.edges_used,
                               search_seconds=lay.search_seconds,
                               mode=lay.placement_mode)

    def scales(self, l: int) -> Optional[np.ndarray]:
        """Per-neuron dequant scales in PHYSICAL order, or None (float pack)."""
        lay = self._layers[l]
        if lay.scales_offset is None:
            return None
        return np.fromfile(self.path, dtype="<f4", count=self.n_neurons,
                           offset=self.data_start + lay.scales_offset)

    def bundles_file_offset(self, l: int) -> int:
        """Absolute byte offset of layer `l`'s first bundle — physical slot p
        lives at exactly this offset + p * row_bytes."""
        return self.data_start + self._layers[l].bundles_offset

    def bundles_memmap(self, l: int) -> np.ndarray:
        """Lazy [n, w] raw-dtype view over layer `l`'s bundle region."""
        return np.memmap(self.path, dtype=self.dtype, mode="r",
                         offset=self.bundles_file_offset(l),
                         shape=(self.n_neurons, self.bundle_width))

    def row_crcs(self, l: int) -> Optional[np.ndarray]:
        """Per-bundle CRC32 table for layer `l` (uint32[n], physical order),
        or None for a v1 pack — the verification input for
        `FileNeuronStore(verify_checksums=True)`."""
        lay = self._layers[l]
        if lay.crcs_offset is None:
            return None
        return np.fromfile(self.path, dtype="<u4", count=self.n_neurons,
                           offset=self.data_start + lay.crcs_offset)

    def verify_bundles(self, l: int) -> bool:
        """Whole-region integrity check of layer `l`'s bundles against the
        header-recorded CRC32 (v1 packs have none and trivially pass)."""
        expected = self._layers[l].bundles_crc32
        if expected is None:
            return True
        data = np.ascontiguousarray(self.bundles_memmap(l))
        return zlib.crc32(memoryview(data).cast("B")) == int(expected)

    def logical_bundles(self, l: int, dequantize: bool = True) -> np.ndarray:
        """Layer `l`'s full payload back in LOGICAL neuron-id order — the
        exact array an in-memory `NeuronStore` would be built from (the
        round-trip identity tests lean on this)."""
        pl = self.placement(l)
        phys = np.asarray(self.bundles_memmap(l))
        if self.quantized and dequantize:
            phys = dequantize_int8(phys, self.scales(l))
        return phys[pl.inverse]


def write_pack(
    path: Union[str, os.PathLike],
    bundles_per_layer: Sequence[np.ndarray],      # [L][n, w], LOGICAL order
    placements: Sequence[PlacementResult],
    *,
    quantize: str = "none",                       # "none" | "int8"
    meta: Optional[dict] = None,
    version: int = VERSION,
) -> dict:
    """Serialize an offline placement into a NeuronPack file.

    `bundles_per_layer` is given in logical neuron-id order (as produced by
    `make_bundles`); the writer applies each layer's placement so the file
    holds bundles in PHYSICAL order. Returns the header dict augmented with
    `path` and `file_bytes`. `version=2` (the default) writes the checksum
    metadata (header CRC + per-bundle CRC tables); `version=1` writes the
    legacy checksum-free layout byte-identically to older writers.
    """
    if quantize not in ("none", "int8"):
        raise ValueError(f"unknown quantize mode {quantize!r}")
    if version not in READABLE_VERSIONS:
        raise ValueError(f"cannot write NeuronPack version {version!r} "
                         f"(writable: {READABLE_VERSIONS})")
    if len(bundles_per_layer) != len(placements):
        raise ValueError(f"{len(bundles_per_layer)} bundle arrays vs "
                         f"{len(placements)} placements")
    if not bundles_per_layer:
        raise ValueError("cannot write an empty pack")
    n, w = bundles_per_layer[0].shape
    for i, b in enumerate(bundles_per_layer):
        if b.shape != (n, w):
            raise ValueError(f"layer {i} bundle shape {b.shape} != ({n}, {w}):"
                             " packs are geometry-homogeneous across layers")
        if len(placements[i].placement) != n:
            raise ValueError(f"layer {i} placement covers "
                             f"{len(placements[i].placement)} of {n} neurons")

    quantized = quantize == "int8"
    out_dtype = np.int8 if quantized else np.asarray(bundles_per_layer[0]).dtype
    dtype_name = np.dtype(out_dtype).name
    if dtype_name not in _DTYPES:
        raise ValueError(f"unsupported bundle dtype {dtype_name}")

    # physical-order payloads (+ scales, + v2 checksum tables) per layer
    regions: List[tuple] = []   # (placement i64, scales f32|None, rows, crcs)
    for b, pl in zip(bundles_per_layer, placements):
        phys = np.ascontiguousarray(np.asarray(b)[pl.placement])
        scales = None
        if quantized:
            phys, scales = quantize_int8(phys)
        rows = np.ascontiguousarray(phys, dtype=out_dtype)
        crcs = _row_crc32s(rows) if version >= 2 else None
        regions.append((pl.placement.astype("<i8"), scales, rows, crcs))

    # layout pass: offsets relative to data_start, every region aligned
    layers = []
    cursor = 0
    for (placement, scales, rows, crcs), pl in zip(regions, placements):
        entry = {"placement": cursor, "placement_mode": pl.mode,
                 "edges_used": int(pl.edges_used),
                 "search_seconds": float(pl.search_seconds)}
        cursor = _align(cursor + placement.nbytes)
        if scales is not None:
            entry["scales"] = cursor
            cursor = _align(cursor + scales.nbytes)
        entry["bundles"] = cursor
        entry["bundles_nbytes"] = int(rows.nbytes)
        cursor = _align(cursor + rows.nbytes)
        if crcs is not None:
            entry["bundle_crcs"] = cursor
            cursor = _align(cursor + crcs.nbytes)
            entry["bundles_crc32"] = int(
                zlib.crc32(memoryview(rows).cast("B")))
        layers.append(entry)

    header = {
        "version": int(version),
        "n_layers": len(regions),
        "n_neurons": int(n),
        "bundle_width": int(w),
        "dtype": dtype_name,
        "quantized": quantized,
        "layers": layers,
        "meta": dict(meta or {}),
    }
    blob = json.dumps(header).encode("utf-8")
    crc_bytes = 4 if version >= 2 else 0
    data_start = _align(16 + len(blob) + crc_bytes)

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(np.array(len(blob), dtype="<u8").tobytes())
        f.write(blob)
        if crc_bytes:
            f.write(np.array(zlib.crc32(blob), dtype="<u4").tobytes())
        f.write(b"\0" * (data_start - 16 - len(blob) - crc_bytes))
        cursor = 0
        for entry, (placement, scales, rows, crcs) in zip(layers, regions):
            for key, arr in (("placement", placement), ("scales", scales),
                             ("bundles", rows), ("bundle_crcs", crcs)):
                if arr is None:
                    continue
                off = entry[key]
                f.write(b"\0" * (off - cursor))
                f.write(arr.tobytes())
                cursor = off + arr.nbytes
        f.write(b"\0" * (_align(cursor) - cursor))
        total = data_start + _align(cursor)
    return dict(header, path=os.fspath(path), file_bytes=total)
