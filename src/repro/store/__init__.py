"""On-disk NeuronPack artifact + file-backed flash store.

Offline, `build_pack` runs the paper's whole offline stage (trace ->
co-activation stats -> linked placement) and serializes the result as a
NeuronPack: per-layer neuron bundles written in PHYSICAL placement order,
so byte offsets in the file ARE flash positions. Online, `FileNeuronStore`
serves the existing `NeuronStore` contract from that file with one real
positional read per collapsed extent, keeping the calibrated device model's
accounting bit-identical to the in-memory store while adding measured
wall-clock fields.

Fault tolerance (`repro.store.faults`): format v2 packs carry per-bundle
CRC32 tables, `FileNeuronStore` retries transient read failures with
bounded backoff and (opt-in) verifies every extent against the CRCs, and
`FaultPlan`/`FaultInjectingStore` provide the deterministic seed-driven
fault schedules the chaos suite and `benchmarks/fault_bench.py` replay.
"""
from repro.store.faults import (CorruptExtentError, FatalFault, FaultEvent,
                                FaultInjectingStore, FaultPlan, RetryPolicy,
                                TransientIOError, seeded_layer_plans)
from repro.store.file_store import FileNeuronStore, open_layer_stores
from repro.store.format import (MAGIC, READABLE_VERSIONS, VERSION, NeuronPack,
                                PackFormatError, dequantize_int8,
                                quantize_int8, write_pack)
from repro.store.packer import (PackBuildReport, build_pack,
                                extract_dense_ffn_bundles, trace_to_shards)

__all__ = [
    "MAGIC", "VERSION", "READABLE_VERSIONS", "NeuronPack", "PackFormatError",
    "FileNeuronStore", "open_layer_stores",
    "write_pack", "quantize_int8", "dequantize_int8",
    "PackBuildReport", "build_pack", "extract_dense_ffn_bundles",
    "trace_to_shards",
    "FaultPlan", "FaultEvent", "FaultInjectingStore", "RetryPolicy",
    "TransientIOError", "CorruptExtentError", "FatalFault",
    "seeded_layer_plans",
]
