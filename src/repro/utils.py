"""Shared utilities: logging, timing, pytree helpers."""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import time
from typing import Any, Dict, Iterator

import jax
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s %(levelname)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


@contextlib.contextmanager
def timed(name: str, sink: Dict[str, float] | None = None) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[name] = sink.get(name, 0.0) + dt
    logger.debug("%s took %.3fs", name, dt)


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all leaves (works on arrays and ShapeDtypeStructs)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize for l in leaves)


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves)


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def dataclass_to_json(obj: Any) -> str:
    return json.dumps(dataclasses.asdict(obj), indent=2, default=str)


def stable_hash(*ints: int) -> int:
    """Deterministic 64-bit mix (splitmix64-style) for reproducible pseudo-randomness."""
    h = 0x9E3779B97F4A7C15
    for v in ints:
        h ^= (v + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 31
    return h


def stable_uniform(*ints: int) -> float:
    """Deterministic uniform in [0, 1) from integer keys."""
    return stable_hash(*ints) / float(1 << 64)


_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM64_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_M2 = np.uint64(0x94D049BB133111EB)


def stable_hash_array(*keys) -> np.ndarray:
    """Vectorized `stable_hash`: bitwise-identical to the scalar version.

    Each key may be a scalar int or an int array; arrays broadcast. The hot
    use is hashing one (salt, tick) pair against thousands of neuron ids in a
    single call instead of a per-id Python loop.
    """
    with np.errstate(over="ignore"):
        arrs = np.broadcast_arrays(*[np.asarray(k, dtype=np.uint64) for k in keys])
        h = np.full(arrs[0].shape, _SM64_GAMMA, dtype=np.uint64)
        for v in arrs:
            h ^= v + _SM64_GAMMA
            h *= _SM64_M1
            h ^= h >> np.uint64(27)
            h *= _SM64_M2
            h ^= h >> np.uint64(31)
    return h


def stable_uniform_array(*keys) -> np.ndarray:
    """Vectorized `stable_uniform`: uniforms in [0, 1), one per broadcast key."""
    return stable_hash_array(*keys) / float(1 << 64)
