"""Shared utilities: logging, timing, pytree helpers."""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import os
import time
from typing import Any, Dict, Iterator

import jax
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter(
        "[%(asctime)s %(levelname)s %(name)s] %(message)s", "%H:%M:%S"))
    logger.addHandler(_h)
    logger.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO").upper())


def get_logger(name: str = "repro") -> logging.Logger:
    """A child of the shared `repro` logger (handler + level configured
    above, overridable via the REPRO_LOG_LEVEL env var). Pass a bare
    component name ("bench.load") or a fully-qualified one
    ("repro.serving"); both land under the `repro` hierarchy so
    `set_log_level` / `--verbose` control everything at once."""
    if name != "repro" and not name.startswith("repro."):
        name = f"repro.{name}"
    return logging.getLogger(name)


def set_log_level(level: int | str) -> None:
    """Set the level of the whole `repro` logger hierarchy (the `--verbose`
    flag implementation: CLIs call `set_log_level("DEBUG")`)."""
    logger.setLevel(level.upper() if isinstance(level, str) else level)


def add_verbosity_flag(parser) -> None:
    """Attach the shared `-v/--verbose` argparse flag (repeatable)."""
    parser.add_argument(
        "-v", "--verbose", action="count", default=0,
        help="increase log verbosity (-v: DEBUG); default level INFO, "
             "or the REPRO_LOG_LEVEL env var")


def configure_logging(verbose: int = 0) -> None:
    """Apply a parsed `--verbose` count to the shared logger."""
    if verbose > 0:
        set_log_level(logging.DEBUG)


@contextlib.contextmanager
def timed(name: str, sink: Dict[str, float] | None = None) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[name] = sink.get(name, 0.0) + dt
    logger.debug("%s took %.3fs", name, dt)


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all leaves (works on arrays and ShapeDtypeStructs)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize for l in leaves)


def tree_param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves)


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def dataclass_to_json(obj: Any) -> str:
    return json.dumps(dataclasses.asdict(obj), indent=2, default=str)


def stable_hash(*ints: int) -> int:
    """Deterministic 64-bit mix (splitmix64-style) for reproducible pseudo-randomness."""
    h = 0x9E3779B97F4A7C15
    for v in ints:
        h ^= (v + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        h = (h * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 31
    return h


def stable_uniform(*ints: int) -> float:
    """Deterministic uniform in [0, 1) from integer keys."""
    return stable_hash(*ints) / float(1 << 64)


_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM64_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_M2 = np.uint64(0x94D049BB133111EB)


def stable_hash_array(*keys) -> np.ndarray:
    """Vectorized `stable_hash`: bitwise-identical to the scalar version.

    Each key may be a scalar int or an int array; arrays broadcast. The hot
    use is hashing one (salt, tick) pair against thousands of neuron ids in a
    single call instead of a per-id Python loop.
    """
    with np.errstate(over="ignore"):
        arrs = np.broadcast_arrays(*[np.asarray(k, dtype=np.uint64) for k in keys])
        h = np.full(arrs[0].shape, _SM64_GAMMA, dtype=np.uint64)
        for v in arrs:
            h ^= v + _SM64_GAMMA
            h *= _SM64_M1
            h ^= h >> np.uint64(27)
            h *= _SM64_M2
            h ^= h >> np.uint64(31)
    return h


def stable_uniform_array(*keys) -> np.ndarray:
    """Vectorized `stable_uniform`: uniforms in [0, 1), one per broadcast key."""
    return stable_hash_array(*keys) / float(1 << 64)
