"""Data pipeline: synthetic LM corpora, file corpora, packing, batching.

Synthetic corpus is a Zipf-distributed Markov-ish token stream with enough
structure that a ~100M model's loss visibly drops within a few hundred steps
(examples/train_lm.py). File corpora are byte-tokenised text.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    kind: str = "synthetic"     # synthetic | bytes
    path: Optional[str] = None  # for kind="bytes"


class SyntheticCorpus:
    """Order-1 Markov chain over a Zipf vocabulary — learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        # each token has `branching` likely successors
        self.successors = rng.integers(0, vocab_size, (vocab_size, branching))
        zipf = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        self.unigram = zipf / zipf.sum()
        self.branching = branching

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        tok = int(rng.choice(self.vocab, p=self.unigram))
        for i in range(n):
            out[i] = tok
            if rng.random() < 0.8:      # follow the chain
                tok = int(self.successors[tok, rng.integers(self.branching)])
            else:                        # jump via unigram
                tok = int(rng.choice(self.vocab, p=self.unigram))
        return out


def synthetic_batches(cfg: DataConfig) -> Iterator[Dict[str, jnp.ndarray]]:
    corpus = SyntheticCorpus(cfg.vocab_size, cfg.seed)
    rng = np.random.default_rng(cfg.seed + 1)
    while True:
        toks = np.stack([corpus.sample(rng, cfg.seq_len) for _ in range(cfg.batch_size)])
        yield {"tokens": jnp.asarray(toks)}


def byte_batches(cfg: DataConfig) -> Iterator[Dict[str, jnp.ndarray]]:
    """Byte-level tokens from a text file, packed into fixed-length rows."""
    assert cfg.path, "byte corpus needs a path"
    with open(cfg.path, "rb") as f:
        data = np.frombuffer(f.read(), dtype=np.uint8).astype(np.int32)
    if cfg.vocab_size < 256:
        data = data % cfg.vocab_size
    rng = np.random.default_rng(cfg.seed)
    n = len(data) - cfg.seq_len - 1
    if n <= 0:
        raise ValueError("corpus shorter than seq_len")
    while True:
        starts = rng.integers(0, n, cfg.batch_size)
        toks = np.stack([data[s : s + cfg.seq_len] for s in starts])
        yield {"tokens": jnp.asarray(toks)}


def make_data_iter(cfg: DataConfig) -> Iterator[Dict[str, jnp.ndarray]]:
    if cfg.kind == "synthetic":
        return synthetic_batches(cfg)
    if cfg.kind == "bytes":
        return byte_batches(cfg)
    raise ValueError(cfg.kind)
