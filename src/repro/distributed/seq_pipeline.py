"""Sequence-pipelined recurrent prefill (§Perf C4 prototype).

Problem: recurrent mixers (mLSTM/sLSTM/Mamba) cannot shard the time axis the
way attention can — the state recurrence is sequential. The §Perf C-series
showed that tensor parallelism for a 125 M xLSTM is pure collective overhead,
and replication (C3) wastes the model axis entirely.

This prototype pipelines the recurrence over sequence shards instead:
  * the big, embarrassingly-parallel work (q/k/v/gate projections) runs on
    SEQUENCE-SHARDED activations — no collectives at all;
  * only the tiny per-step state recurrence serialises, as a P-stage pipeline
    where each shard scans its local chunk and hands the final state to the
    next shard via collective_permute.

Wall-clock model: projections P-way parallel; recurrence T sequential steps
total (inherent), but the recurrence is O(B·H·hd²) per step vs the
projections' O(B·d·3Hhd) per token — the parallel part dominates FLOPs.

Implemented with shard_map; numerically exact vs ssm.mlstm_forward
(tests/test_seq_pipeline.py validates on 8 forced host devices).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import ssm

Params = Dict[str, jnp.ndarray]


def _select_tree(pred, a, b):
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred, x, y), a, b)


def pipelined_mlstm_forward(
    p: Params,
    x: jnp.ndarray,             # [B, T, d] — T sharded over `axis` outside
    cfg: ModelConfig,
    mesh: Mesh,
    axis: str = "model",
) -> jnp.ndarray:
    """mLSTM over a sequence sharded on `axis`: projections collective-free,
    recurrence as a P-stage state pipeline (one collective_permute per stage,
    payload = one MLSTMState, ~B·H·hd² bytes — vs all-reducing [B,T,d])."""
    n_stages = mesh.shape[axis]

    def local_fn(p_rep, x_local):
        B = x_local.shape[0]
        q, k, v, i_log, f_log, o = ssm._mlstm_gates(p_rep, x_local, cfg)
        tm = lambda a: jnp.moveaxis(a, 1, 0)
        xs = (tm(q), tm(k), tm(v), tm(i_log), tm(f_log))
        idx = jax.lax.axis_index(axis)
        incoming = ssm.mlstm_init_state(B, cfg)     # valid for shard 0 at stage 0
        out_ys = None
        for stage in range(n_stages):
            active = idx == stage
            final_st, ys = jax.lax.scan(ssm._mlstm_step, incoming, xs)
            out_ys = ys if out_ys is None else _select_tree(active, ys, out_ys)
            # hand shard `stage`'s final state to shard `stage`+1
            payload = _select_tree(active, final_st, incoming)
            shifted = jax.tree_util.tree_map(
                lambda a: jax.lax.ppermute(
                    a, axis, [(s, (s + 1) % n_stages) for s in range(n_stages)]),
                payload)
            incoming = _select_tree(idx == stage + 1, shifted, incoming)
        y = jnp.moveaxis(out_ys, 0, 1).reshape(B, x_local.shape[1], -1) * o
        return y @ p_rep["out_proj"].astype(x_local.dtype)

    # batch over the data axes, sequence over `axis`: the pipeline payload is
    # then the LOCAL-batch state (B/dp · H · hd²), not the global one.
    import math
    dp = tuple(a for a in mesh.axis_names if a != axis)
    dp_total = math.prod(mesh.shape[a] for a in dp)
    b_axes = dp if x.shape[0] % dp_total == 0 else None
    spec_x = P(b_axes, axis, None)
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), spec_x), out_specs=spec_x,
                   check_rep=False)
    return fn(p, x)
